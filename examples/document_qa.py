"""Document QA end-to-end driver: the REAL executable pipeline.

Runs the paper's Workflow 2 with actual JAX models (reduced configs on
CPU): hash tokenizer -> chunker (128/10) -> embedding model -> vector DB
(fused top-k kernel) -> cross-encoder reranker -> query-rewriter agent ->
chat generation with KV cache — orchestrated by the HeRo scheduler over
heterogeneous PU executors with wall-clock dispatch.

    PYTHONPATH=src python examples/document_qa.py
"""
import sys

import repro.launch.serve as serve


def main():
    sys.argv = ["document_qa", "--workflow", "2", "--queries", "2",
                "--dataset", "finqabench"]
    serve.main()


if __name__ == "__main__":
    main()
