"""Document QA end-to-end driver: the REAL executable pipeline.

Runs the paper's Workflow 2 with actual JAX models (reduced configs on
CPU): hash tokenizer -> chunker (128/10) -> embedding model -> vector DB
(fused top-k kernel) -> cross-encoder reranker -> query-rewriter agent ->
chat generation with KV cache — dispatched by a live-backend
``HeroSession`` over heterogeneous PU executors with wall-clock dispatch.

    PYTHONPATH=src python examples/document_qa.py
"""
from repro.api import HeroSession
from repro.launch.serve import build_stage_fns
from repro.rag import default_means, sample_traces


def main():
    traces = sample_traces("finqabench", 2, seed=1)
    sess = HeroSession(world="sd8gen4", family="qwen3", backend="live",
                       means=default_means(traces),
                       stage_fns=build_stage_fns())
    done = []
    for tr in traces:
        sess.submit(tr, wf=2,
                    on_stage_done=lambda h, node, t: done.append(node.stage))
    results = sess.run(mode="isolated", timeout=600)
    for res in results:
        top = sorted(res.stage_latency.items(), key=lambda kv: -kv[1])[:3]
        hot = ", ".join(f"{s}={v:.2f}s" for s, v in top)
        print(f"query {res.qid}: {res.n_nodes} sub-stages, "
              f"{res.makespan:.2f}s wall, hottest: {hot}")
    print(f"{len(done)} stage completions streamed via on_stage_done")


if __name__ == "__main__":
    main()
