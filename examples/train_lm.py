"""Train a ~100M-param LM for a few hundred steps with checkpoint/restart.

Uses the qwen1.5 architecture scaled to ~100M params, synthetic data, the
framework's AdamW + cosine schedule, async checkpointing every 50 steps,
and demonstrates restart-from-latest by resuming for 20 more steps.

    PYTHONPATH=src python examples/train_lm.py [--steps 200]
"""
import argparse
import dataclasses
import tempfile

from repro.checkpoint import Checkpointer
from repro.configs import get_config
from repro.launch.train import synthetic_data
from repro.training import AdamWConfig, TrainConfig, train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    args = ap.parse_args()

    # ~100M params: qwen1.5 family topology, 12 x 512 width
    cfg = dataclasses.replace(
        get_config("qwen1.5-0.5b"), num_layers=12, d_model=512,
        num_heads=8, num_kv_heads=8, head_dim=64, d_ff=1408,
        vocab_size=32000, dtype="float32", remat="none")
    n = cfg.param_count()
    print(f"model: {n / 1e6:.1f}M params, {args.steps} steps, "
          f"batch {args.batch} x seq {args.seq}")

    tcfg = TrainConfig(optimizer=AdamWConfig(
        lr=6e-4, warmup_steps=20, total_steps=args.steps))
    with tempfile.TemporaryDirectory() as ckdir:
        ck = Checkpointer(ckdir)
        params, _, hist = train(
            cfg, synthetic_data(cfg, args.batch, args.seq),
            steps=args.steps, tcfg=tcfg, checkpointer=ck,
            checkpoint_every=50, log_every=20)
        for h in hist:
            print(f"  step {h['step']:4d}  loss {h['loss']:.4f}  "
                  f"gnorm {h['grad_norm']:.3f}  {h['wall']:.0f}s")
        print(f"checkpoints: {ck.available_steps()}")
        print("restart-from-latest for 20 more steps...")
        _, _, hist2 = train(
            cfg, synthetic_data(cfg, args.batch, args.seq),
            steps=args.steps + 20, tcfg=tcfg, checkpointer=ck,
            restore=True, log_every=10)
        print(f"  resumed at step {hist2[0]['step']}, "
              f"final loss {hist2[-1]['loss']:.4f}")


if __name__ == "__main__":
    main()
