"""Quickstart: the HeRo scheduler in 60 lines.

Builds the paper's Workflow 2 (Advanced Document QA Bot) for one query,
schedules it on a simulated Snapdragon 8 Elite with all four strategies,
and prints the end-to-end latencies — the core result of the paper in one
script.

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.configs import get_family
from repro.core import (GroundTruthPerf, HeroScheduler, LinearPerfModel,
                        SchedulerConfig, Simulator, snapdragon_8gen4,
                        strategy_config)
from repro.rag import (STAGE_ROLES, build_stages, build_workflow,
                       default_means, make_template, sample_traces)


def main():
    # 1. hardware + stage models (Qwen3 RAG family, INT8)
    soc = snapdragon_8gen4()
    stages = build_stages(get_family("qwen3"))

    # 2. offline profiling: ground truth -> fitted linear perf model (§5)
    gt = GroundTruthPerf(soc, stages)
    perf = LinearPerfModel().fit(gt)

    # 3. one HotpotQA-like query through Workflow 2
    trace = sample_traces("hotpotqa", 1, seed=42)[0]
    means = default_means(sample_traces("hotpotqa", 16, seed=0))
    print(f"query: {trace.n_chunks} chunks to index, "
          f"{trace.n_subqueries} sub-queries, "
          f"{trace.answer_tokens}-token answer\n")

    results = {}
    for strategy in ("llamacpp_gpu", "powerserve_npu", "ayo_like", "hero"):
        if strategy == "hero":
            cfg, tmpl = SchedulerConfig(), make_template(2, means)
        else:
            cfg, tmpl = strategy_config(strategy, STAGE_ROLES), None
        dag = build_workflow(2, trace, fine_grained=cfg.enable_partition)
        sched = HeroScheduler(perf, [p.name for p in soc.pus], soc.dram_bw,
                              cfg, template=tmpl)
        res = Simulator(gt, sched).run(dag)
        results[strategy] = res.makespan
        util = ", ".join(f"{p.name}={res.utilization(p.name) * 100:.0f}%"
                         for p in soc.pus)
        print(f"{strategy:16s} {res.makespan:6.2f}s   util: {util}")

    print(f"\nHeRo speedup vs GPU-only: "
          f"{results['llamacpp_gpu'] / results['hero']:.2f}x"
          f"   vs Ayo-like: {results['ayo_like'] / results['hero']:.2f}x")


if __name__ == "__main__":
    main()
