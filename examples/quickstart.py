"""Quickstart: the HeRo scheduler through the `HeroSession` facade.

Runs the paper's Workflow 2 (Advanced Document QA Bot) for one query on a
simulated Snapdragon 8 Elite with all four strategies and prints the
end-to-end latencies — the core result of the paper in one script.  The
session owns all the wiring (SoC spec, ground-truth profiling, perf-model
fitting, scheduler, simulator); swap ``backend="live"`` to execute the
same script on real worker threads.

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.api import HeroSession
from repro.rag import sample_traces


def main():
    trace = sample_traces("hotpotqa", 1, seed=42)[0]
    from repro.rag import default_means
    means = default_means(sample_traces("hotpotqa", 16, seed=0))
    print(f"query: {trace.n_chunks} chunks to index, "
          f"{trace.n_subqueries} sub-queries, "
          f"{trace.answer_tokens}-token answer\n")

    results = {}
    for strategy in ("llamacpp_gpu", "powerserve_npu", "ayo_like", "hero"):
        sess = HeroSession(world="sd8gen4", family="qwen3",
                           strategy=strategy, means=means)
        sess.submit(trace, wf=2)
        [res] = sess.run()
        results[strategy] = res.makespan
        util = ", ".join(f"{p.name}={res.utilization(p.name) * 100:.0f}%"
                         for p in sess.soc.pus)
        print(f"{strategy:16s} {res.makespan:6.2f}s   util: {util}")

    print(f"\nHeRo speedup vs GPU-only: "
          f"{results['llamacpp_gpu'] / results['hero']:.2f}x"
          f"   vs Ayo-like: {results['ayo_like'] / results['hero']:.2f}x")


if __name__ == "__main__":
    main()
