"""Deep Researcher (Workflow 3) with fault injection.

The most complex paper workflow — search planner, web requests, per-branch
refinement — scheduled by HeRo on the simulator, with stragglers and
outright executor failures injected.  Demonstrates the fault-tolerance
loop: speculative re-dispatch reaps the stragglers, retries recover the
failures, and the makespan degrades gracefully instead of hanging.

    PYTHONPATH=src python examples/deep_researcher.py
"""
import numpy as np

from repro.configs import get_family
from repro.core import (GroundTruthPerf, HeroScheduler, LinearPerfModel,
                        SchedulerConfig, Simulator, snapdragon_8gen4)
from repro.rag import (build_stages, build_workflow, default_means,
                       make_template, sample_traces)


def main():
    soc = snapdragon_8gen4()
    stages = build_stages(get_family("qwen3"))
    gt = GroundTruthPerf(soc, stages)
    perf = LinearPerfModel().fit(gt)
    traces = sample_traces("2wikimqa", 3, seed=7)
    means = default_means(traces)

    print("fault injection on Workflow 3 (Deep Researcher):\n")
    print(f"{'condition':34s} {'makespan':>9s} {'redispatch':>10s}")
    for name, kw in [
        ("healthy", {}),
        ("10% stragglers (4x slow)", dict(straggler_prob=0.1,
                                          straggler_slow=4.0)),
        ("30% stragglers (8x slow)", dict(straggler_prob=0.3,
                                          straggler_slow=8.0)),
        ("10% task failures", dict(fail_prob=0.1)),
    ]:
        lat, red = [], 0
        for i, tr in enumerate(traces):
            dag = build_workflow(3, tr, fine_grained=True)
            sched = HeroScheduler(perf, [p.name for p in soc.pus],
                                  soc.dram_bw,
                                  SchedulerConfig(straggler_factor=2.5),
                                  template=make_template(3, means))
            res = Simulator(gt, sched, seed=i, **kw).run(dag)
            lat.append(res.makespan)
            red += res.redispatches
        print(f"{name:34s} {np.mean(lat):8.2f}s {red:10d}")

    print("\nelastic scale-down mid-fleet (NPU lost):")
    tr = traces[0]
    for pus in (["cpu", "gpu", "npu"], ["cpu", "gpu"]):
        dag = build_workflow(3, tr, fine_grained=True)
        sched = HeroScheduler(perf, pus, soc.dram_bw, SchedulerConfig(),
                              template=make_template(3, means))
        res = Simulator(gt, sched).run(dag)
        print(f"  PUs={pus}: {res.makespan:.2f}s")


if __name__ == "__main__":
    main()
