"""Deep Researcher (Workflow 3) with fault injection, via `HeroSession`.

The most complex paper workflow — search planner, web requests, per-branch
refinement — scheduled by HeRo on the simulator backend, with stragglers
and outright executor failures injected through ``sim_opts``.
Demonstrates the fault-tolerance loop: speculative re-dispatch reaps the
stragglers, retries recover the failures, and the makespan degrades
gracefully instead of hanging.

    PYTHONPATH=src python examples/deep_researcher.py
"""
import numpy as np

from repro.api import HeroSession, SessionOptions
from repro.rag import default_means, sample_traces


def main():
    traces = sample_traces("2wikimqa", 3, seed=7)
    means = default_means(traces)

    print("fault injection on Workflow 3 (Deep Researcher):\n")
    print(f"{'condition':34s} {'makespan':>9s} {'redispatch':>10s}")
    for name, kw in [
        ("healthy", {}),
        ("10% stragglers (4x slow)", dict(straggler_prob=0.1,
                                          straggler_slow=4.0)),
        ("30% stragglers (8x slow)", dict(straggler_prob=0.3,
                                          straggler_slow=8.0)),
        ("10% task failures", dict(fail_prob=0.1)),
    ]:
        lat, red = [], 0
        for i, tr in enumerate(traces):
            sess = HeroSession(
                world="sd8gen4", family="qwen3", means=means,
                options=SessionOptions(
                    cfg_overrides={"straggler_factor": 2.5}),
                sim_opts={"seed": i, **kw})
            sess.submit(tr, wf=3)
            [res] = sess.run()
            lat.append(res.makespan)
            red += res.redispatches
        print(f"{name:34s} {np.mean(lat):8.2f}s {red:10d}")

    print("\nelastic scale-down mid-fleet (NPU lost):")
    tr = traces[0]
    for pus in (["cpu", "gpu", "npu"], ["cpu", "gpu"]):
        sess = HeroSession(world="sd8gen4", family="qwen3", means=means,
                           pus=pus)
        sess.submit(tr, wf=3)
        [res] = sess.run()
        print(f"  PUs={pus}: {res.makespan:.2f}s")


if __name__ == "__main__":
    main()
