"""Cross-query batch coalescing: DAG-level fuse/unfuse semantics, scheduler
grouping rules, sim/live parity, the staggered-arrival makespan regression
bound, and golden determinism of W1–W3 across the four strategies.

Deliberately hypothesis-free: this is the deterministic tier-1 coverage
that runs in every environment.
"""
import numpy as np
import pytest

from repro.api import HeroSession
from repro.api.session import make_world
from repro.api.spec import builtin_spec
from repro.core import DynamicDAG, HeroScheduler, SchedulerConfig, Simulator
from repro.core.dag import Node
from repro.rag import default_means, sample_traces


@pytest.fixture(scope="module")
def traces():
    return sample_traces("hotpotqa", 8, seed=11)


@pytest.fixture(scope="module")
def means(traces):
    return default_means(traces)


# --- DAG-level fused-node semantics ------------------------------------------

def _two_query_dag():
    dag = DynamicDAG()
    a = dag.add(Node("q0/embed", "embed", "batchable", 24))
    b = dag.add(Node("q1/embed", "embed", "batchable", 40))
    sa = dag.add(Node("q0/rerank", "rerank", "batchable", 8,
                      deps={"q0/embed"}))
    sb = dag.add(Node("q1/rerank", "rerank", "batchable", 8,
                      deps={"q1/embed"}))
    return dag, a, b, sa, sb


def test_fuse_ready_hides_members_and_unfuse_restores():
    dag, a, b, _, _ = _two_query_dag()
    fused = dag.fuse_ready([a, b])
    assert fused.workload == 64
    assert fused.status == "ready"
    ready_ids = {n.id for n in dag.ready()}
    assert fused.id in ready_ids
    assert "q0/embed" not in ready_ids and "q1/embed" not in ready_ids
    members = dag.unfuse(fused)
    assert {m.id for m in members} == {"q0/embed", "q1/embed"}
    assert {n.id for n in dag.ready()} == {"q0/embed", "q1/embed"}
    assert fused.id not in dag.nodes


def test_fused_completion_fans_out_to_members():
    dag, a, b, sa, sb = _two_query_dag()
    fused = dag.fuse_ready([a, b])
    dag.mark_running(fused.id, 1.0, ("npu", 32))
    assert sa.status == "pending" and sb.status == "pending"
    dag.mark_done(fused.id, 3.5)
    for m in (a, b):
        assert m.status == "done"
        assert (m.start, m.finish) == (1.0, 3.5)
        assert m.config == ("npu", 32)
        assert m.payload["coalesced"] == fused.id
    assert a.payload["fused_share"] == pytest.approx(24 / 64)
    assert b.payload["fused_share"] == pytest.approx(40 / 64)
    # successors of BOTH member queries released by one completion
    assert sa.status == "ready" and sb.status == "ready"


# --- scheduler grouping rules ------------------------------------------------

def _sched(perf, soc, **cfg):
    return HeroScheduler(perf, [p.name for p in soc.pus], soc.dram_bw,
                         SchedulerConfig(coalesce=True, **cfg))


def test_coalesce_is_cross_query_only():
    soc, gt, perf = make_world("sd8gen4", "qwen3")
    dag = DynamicDAG()
    dag.add(Node("q0/embed_a", "embed", "batchable", 16))
    dag.add(Node("q0/embed_b", "embed", "batchable", 16))
    assert _sched(perf, soc)._coalesce(dag) == []   # same query: no fusion
    dag.add(Node("q1/embed_a", "embed", "batchable", 16))
    [fused] = _sched(perf, soc)._coalesce(dag)
    assert fused.workload == 48


def test_coalesce_respects_no_coalesce_and_window():
    soc, gt, perf = make_world("sd8gen4", "qwen3")
    dag = DynamicDAG()
    n0 = dag.add(Node("q0/embed", "embed", "batchable", 16))
    n1 = dag.add(Node("q1/embed", "embed", "batchable", 16))
    n0.payload["no_coalesce"] = n1.payload["no_coalesce"] = True
    assert _sched(perf, soc)._coalesce(dag) == []
    # window bounds total absorbed workload
    dag2 = DynamicDAG()
    for q in range(4):
        dag2.add(Node(f"q{q}/embed", "embed", "batchable", 100))
    [fused] = _sched(perf, soc, coalesce_window=250)._coalesce(dag2)
    assert fused.workload <= 250
    assert len(fused.payload["members"]) == 2


def test_spec_coalescable_flag_reaches_nodes(traces):
    import dataclasses
    spec = builtin_spec(1)
    statics = tuple(dataclasses.replace(s, coalescable=(s.id != "rerank"))
                    for s in spec.statics)
    dag = dataclasses.replace(spec, statics=statics).build_dag(traces[0])
    assert dag.nodes["rerank"].payload.get("no_coalesce") is True
    assert "no_coalesce" not in dag.nodes["embed_chunks"].payload


# --- end-to-end invariants under coalescing ----------------------------------

def test_coalesced_run_preserves_dependencies_and_workload(traces):
    """Core-level shared-DAG run with coalescing: every dependency is
    respected through fused fan-outs, per-group workload is conserved,
    and fused shares sum to 1."""
    soc, gt, perf = make_world("sd8gen4", "qwen3")
    dag = DynamicDAG()
    spec = builtin_spec(1)
    for q, tr in enumerate(traces[:4]):
        spec.build_dag(tr, prefix=f"q{q}/", dag=dag)
    sched = HeroScheduler(perf, [p.name for p in soc.pus], soc.dram_bw,
                          SchedulerConfig(coalesce=True))
    Simulator(gt, sched).run(dag)
    assert not dag.unfinished()
    fused_nodes = [n for n in dag.nodes.values() if "members" in n.payload
                   and not n.payload.get("decode_round")]
    assert fused_nodes, "no cross-query fusion happened on 4 merged queries"
    for n in dag.nodes.values():
        for d in n.deps:
            assert dag.nodes[d].finish <= n.start + 1e-9, (d, n.id)
    for f in fused_nodes:
        members = f.payload["members"]
        assert sum(m.workload for m in members) == f.workload
        assert sum(m.payload["fused_share"] for m in members) \
            == pytest.approx(1.0)
        assert all(m.finish == f.finish for m in members)
    # decode rounds (continuous batching) follow per-member serving
    # invariants instead; completed rounds nobody depends on are pruned
    # from the graph, so only member-side accounting remains
    served = [n for n in dag.nodes.values()
              if "decode_served" in n.payload]
    assert served, "no continuous decode batching on 4 merged queries"
    for m in served:
        assert m.payload["decode_served"] <= m.payload["decode_total"]
    assert not [n for n in dag.nodes.values()
                if n.payload.get("decode_round") and n.status == "done"
                and not dag._succ.get(n.id)]


def test_sim_live_parity_with_coalesce(means):
    """Same per-query node sets, stages, and coalesced dispatches on both
    substrates."""
    short = sample_traces("finqabench", 3, seed=5)
    by = {}
    for backend in ("sim", "live"):
        sess = HeroSession(world="sd8gen4", family="qwen3", means=means,
                           coalesce=True, backend=backend)
        for tr in short:
            sess.submit(tr, wf=1)
        by[backend] = sess.run(timeout=120)
    for s, l in zip(by["sim"], by["live"]):
        assert s.qid == l.qid
        assert set(s.stage_latency) == set(l.stage_latency)
        # node counts may differ under continuous decode batching (round
        # boundaries land on sim vs wall clocks), but never by stages
        assert s.dispatches >= s.n_nodes
        assert l.dispatches >= l.n_nodes
    assert sum(r.coalesced_nodes for r in by["sim"]) > 0
    assert sum(r.coalesced_nodes for r in by["live"]) > 0


def test_live_multipass_fused_dispatch_not_reaped_as_straggler(means):
    """A fused dispatch runs whole — ceil(L/batch) passes — so the live
    runtime's straggler ETA must scale with the pass count (a per-pass ETA
    would spuriously cancel every large fused dispatch)."""
    big = sample_traces("hotpotqa", 4, seed=7)   # ~40-90 chunks per query
    sess = HeroSession(world="sd8gen4", family="qwen3", means=means,
                       coalesce=True, backend="live")
    for tr in big:
        sess.submit(tr, wf=1)
    res = sess.run(timeout=60)
    assert sum(r.coalesced_nodes for r in res) > 0
    assert sum(r.redispatches for r in res) == 0


def test_coalesced_makespan_not_worse_on_staggered_w1(traces, means):
    """The ISSUE acceptance bar: on a staggered 8-query W1 workload,
    coalescing improves total makespan (throughput) and does not regress
    per-query p99 latency by more than 10%."""
    out = {}
    for coalesce in (False, True):
        sess = HeroSession(world="sd8gen4", family="qwen3", means=means,
                           coalesce=coalesce)
        for qi, tr in enumerate(traces):
            sess.submit(tr, wf=1, arrival_time=qi * 0.25)
        res = sess.run()
        lats = np.array([r.makespan for r in res])
        out[coalesce] = (max(r.finish_time for r in res),
                         float(np.percentile(lats, 99)),
                         sum(r.coalesced_nodes for r in res))
    (base_total, base_p99, _), (co_total, co_p99, co_n) = out[False], out[True]
    assert co_n > 0
    assert co_total <= base_total
    assert co_p99 <= base_p99 * 1.10


# --- golden determinism ------------------------------------------------------

def test_w1_w3_makespans_deterministic_across_strategies(traces, means):
    """Two independent sessions produce bit-identical makespans for every
    (workflow, strategy) cell — the sim and scheduler have no hidden
    nondeterminism for the goldens to drift on."""
    def table():
        out = {}
        for wf in (1, 2, 3):
            for strategy in ("llamacpp_gpu", "powerserve_npu", "ayo_like",
                             "hero"):
                sess = HeroSession(world="sd8gen4", family="qwen3",
                                   strategy=strategy, means=means)
                sess.submit(traces[0], wf=wf)
                [res] = sess.run(mode="isolated")
                out[(wf, strategy)] = res.makespan
        return out

    a, b = table(), table()
    assert a == b
    assert all(v > 0 for v in a.values())


def test_coalesced_shared_run_deterministic(traces, means):
    def once():
        sess = HeroSession(world="sd8gen4", family="qwen3", means=means,
                           coalesce=True)
        for qi, tr in enumerate(traces[:6]):
            sess.submit(tr, wf=2, arrival_time=qi * 0.25)
        return [r.makespan for r in sess.run()]

    assert once() == once()
