"""Paged KV cache subsystem (page table + tiered eviction + prefix reuse).

Covers the ISSUE's required invariants: chain-hash page keys (sharing
iff the full prefix matches), pages never shared across tiers, eviction
respects pins (LRU demotion only ever moves ``refs <= 0`` pages down the
tiers), hit-tokens + remaining-workload == original prefix workload,
page-granular migration pricing, goldens bit-exact with ``kv_pages``
off, and the end-to-end shared-corpus win over the monolithic tracker.
"""
import json
import os

import pytest

from repro.api import HeroSession
from repro.core import SchedulerConfig
from repro.core.dag import Node
from repro.core.kv_pages import (DISK, DRAM, PagedKVCache, chain_hash,
                                 page_keys)
from repro.core.perf_model import LinearPerfModel
from repro.core.scheduler import HeroScheduler
from repro.rag import default_means, sample_traces, shared_corpus_traces

GOLDEN_DIR = os.path.join(os.path.dirname(__file__), "goldens")

STAGE = "chat_decode"


def paged_perf(kv_bytes=1.0, caps=None, sec_per_tok=1e-3,
               fetch_per_tok=2e-3, pus=("cpu", "gpu", "npu")):
    """A LinearPerfModel with handcrafted migration/fetch/tier profiles."""
    m = LinearPerfModel()
    m._tiles = {p: 8 for p in pus}
    m._b0 = 1e9
    m.kv_bytes = {STAGE: kv_bytes}
    m.phi_coef = {STAGE: [1.0, 0.0, 0.0]}     # φ ≡ 1
    for a in pus:
        for b in pus:
            if a != b:
                m.migrate_coef[(STAGE, a, b)] = (0.0, sec_per_tok)
    for p in pus:
        for tier in (DRAM, DISK):
            m.fetch_coef[(STAGE, p, tier)] = (0.0, fetch_per_tok)
            m.fetch_coef[(STAGE, tier, p)] = (0.0, fetch_per_tok)
    m.kv_tiers = dict(caps or {})             # unset tiers are unbounded
    return m


def decode_node(nid, ctx=0, workload=16, **payload):
    return Node(id=nid, stage=STAGE, kind="stream_decode",
                workload=workload, payload={"kv_ctx": ctx, **payload})


def round_node(members, workload=16):
    return Node("dround:x", STAGE, "stream_decode", workload,
                payload={"members": list(members), "decode_round": True})


def prefill_node(nid, segments, stream=None):
    workload = sum(t for _k, t in segments)
    payload = {"prefix_segments": tuple(segments)}
    if stream is not None:
        payload["kv_stream"] = stream
    return Node(id=nid, stage="chat_prefill", kind="stream_prefill",
                workload=workload, payload=payload)


def check_invariants(kv: PagedKVCache):
    """Pages live in exactly one tier, and per-tier byte accounting
    matches the pages actually there."""
    seen = {}
    for tier, pids in kv._tier_pages.items():
        for pid in pids:
            assert pid not in seen, \
                f"page {pid} in both {seen[pid]} and {tier}"
            seen[pid] = tier
            assert kv._pages[pid].tier == tier
    assert set(seen) == set(kv._pages)
    for tier in kv._tier_pages:
        used = sum(kv._page_bytes(kv._pages[p])
                   for p in kv._tier_pages[tier])
        assert kv._tier_used.get(tier, 0.0) == pytest.approx(used)


# --- page keys ---------------------------------------------------------------

def test_page_keys_chain_identity_and_divergence():
    shared = [("ctx:a", 100), ("q:one", 30)]
    a = page_keys(shared, 64)
    b = page_keys([("ctx:a", 100), ("q:one", 30)], 64)
    assert a == b                             # same content, same chain
    assert sum(t for _h, t in a) == 130
    assert [t for _h, t in a] == [64, 64, 2]
    # divergence in a later segment: the pages fully inside the shared
    # head keep their hashes, everything at/after the split differs
    c = page_keys([("ctx:a", 100), ("q:two", 30)], 64)
    assert c[0] == a[0]                       # pure ctx page
    assert c[1] != a[1]                       # page mixing ctx + question
    assert c[2] != a[2]                       # chained past the split
    # divergence in the head invalidates every page (chain hashing)
    d = page_keys([("ctx:b", 100), ("q:one", 30)], 64)
    assert all(x != y for x, y in zip(d, a))


def test_chain_hash_depends_on_prev():
    assert chain_hash(None, "x") != chain_hash("p", "x")
    assert chain_hash("p", "x") == chain_hash("p", "x")


# --- prefix cache: hits, conservation, pinning -------------------------------

def test_prefix_hit_conservation_and_reuse():
    kv = PagedKVCache(paged_perf(), page_tokens=64)
    segs = [("ctx:a", 128), ("q:q0", 40)]
    warm = prefill_node("q0/p", segs)
    kv.apply_prefix_hits(warm)                # cold: nothing resident
    assert "kv_page_hits" not in warm.payload and warm.workload == 168
    kv.on_prefill_done(warm, "gpu")           # cache-only (no kv_stream)
    check_invariants(kv)

    hit = prefill_node("q1/p", segs)
    kv.apply_prefix_hits(hit)
    # trim keeps >= 1 token so the node still anchors its successors
    assert hit.payload["kv_hit_tokens"] + hit.workload == 168
    assert hit.workload == 1
    assert hit.payload["kv_page_hits"] == 3
    assert kv.hits == 3 and kv.hit_tokens == 167
    # hit pages are pinned until prefill completion adopts them
    held = [kv._pages[p] for p in hit.payload["kv_hit_pages"]]
    assert all(pg.refs > 0 for pg in held)
    kv.on_prefill_done(hit, "gpu")
    assert "kv_hit_pages" not in hit.payload  # holds dropped
    assert all(pg.refs == 0 for pg in held)   # cache-only again
    check_invariants(kv)
    # idempotent: re-applying (straggler re-visit) changes nothing
    kv.apply_prefix_hits(hit)
    assert kv.hits == 3


def test_partial_prefix_hits_stop_at_divergence():
    kv = PagedKVCache(paged_perf(), page_tokens=64)
    kv.on_prefill_done(prefill_node("q0/p", [("ctx:a", 128), ("q:q0", 40)]),
                       "gpu")
    other = prefill_node("q1/p", [("ctx:a", 128), ("q:q1", 40)])
    kv.apply_prefix_hits(other)
    # only the two pure-ctx pages match; the mixed page diverges
    assert other.payload["kv_page_hits"] == 2
    assert other.payload["kv_hit_tokens"] == 128
    assert other.workload == 40


def test_prefill_done_links_pages_to_stream():
    kv = PagedKVCache(paged_perf(), page_tokens=64)
    p = prefill_node("q0/p", [("ctx:a", 128)], stream="q0/d")
    kv.on_prefill_done(p, "gpu")
    d = decode_node("q0/d", ctx=128, workload=16)
    d.group = "q0/d"
    st = kv.tracked(d) or kv._streams.get("q0/d")
    assert st is not None and st.ctx_tokens == 128 and len(st.pages) == 2
    assert all(kv._pages[pid].refs == 1 for pid in st.pages)
    # the linked stream re-dispatches on its own PU free of migrations
    assert kv.migrate_for_dispatch(round_node([d]), "gpu") == []
    assert kv.migrations == 0
    kv.release(d)
    # hashed pages survive release at refs == 0 (the prefix cache)
    assert all(kv._pages[pid].refs == 0 for pid in st.pages)
    check_invariants(kv)


# --- tiered store: eviction respects pins ------------------------------------

def test_lru_eviction_demotes_unpinned_only():
    # gpu arena: 12 bytes = 3 pages of 4 tokens at 1 B/token
    kv = PagedKVCache(paged_perf(caps={"gpu": 12.0, "dram": 8.0}),
                      page_tokens=4)
    kv.on_prefill_done(prefill_node("q0/p", [("ctx:a", 12)]), "gpu")
    assert kv.resident_bytes("gpu") == 12.0   # full, all unpinned
    a = decode_node("q0/d", ctx=8, workload=1 << 20)
    kv.migrate_for_dispatch(round_node([a]), "gpu")   # pins 8 B on gpu
    check_invariants(kv)
    # two LRU prefix pages demoted to dram; stream pages stayed
    assert kv.evictions == 2
    assert kv.resident_bytes(DRAM) == 8.0
    assert kv.resident_bytes("gpu") == 12.0
    st = kv.tracked(a)
    assert all(kv._pages[pid].tier == "gpu" for pid in st.pages)
    assert [t for t in kv.drain_transfers()] == [
        (STAGE, "gpu", DRAM, 4), (STAGE, "gpu", DRAM, 4)]
    assert [e for e, _n in kv.drain_events()] == ["kv_evict", "kv_evict"]
    # dram itself is full now: the next demotion cascades to disk
    b = decode_node("q1/d", ctx=4, workload=1 << 20)
    kv.migrate_for_dispatch(round_node([b]), "gpu")
    check_invariants(kv)
    assert kv.resident_bytes(DISK) == 4.0
    # all-pinned arena soft-overflows rather than touching live streams
    c = decode_node("q2/d", ctx=8, workload=1 << 20)
    kv.migrate_for_dispatch(round_node([c]), "gpu")
    check_invariants(kv)
    assert kv.resident_bytes("gpu") > 12.0    # overflow, streams intact
    for st2 in kv._streams.values():
        assert all(kv._pages[pid].tier == "gpu" for pid in st2.pages)


def test_page_granular_migration_and_fetch_accounting():
    kv = PagedKVCache(paged_perf(caps={"gpu": 8.0}), page_tokens=4)
    # 3 pages: arena holds 2, prefix page demotes when the stream pins it
    kv.on_prefill_done(prefill_node("q0/p", [("ctx:a", 4)]), "gpu")
    a = decode_node("q0/d", ctx=8, workload=1 << 20)
    kv.migrate_for_dispatch(round_node([a]), "gpu")
    assert kv.resident_bytes(DRAM) == 4.0
    # a later query hits the demoted page: dispatching its decode fetches
    # it back (a fetch, not a migration) while the stream pages are local
    hit = prefill_node("q1/p", [("ctx:a", 4), ("q:q1", 4)], stream="q1/d")
    kv.apply_prefix_hits(hit)
    assert hit.payload["kv_page_hits"] == 1
    kv.on_prefill_done(hit, "gpu")
    b = decode_node("q1/d", ctx=8, workload=1 << 20)
    b.group = "q1/d"
    moved = kv.migrate_for_dispatch(round_node([b]), "gpu")
    assert [(src, toks) for _m, src, toks, _by in moved] == [(DRAM, 4)]
    assert kv.fetches == 1 and kv.fetched_bytes == 4.0
    assert kv.migrations == 0                 # PU↔PU only
    check_invariants(kv)


def test_migrate_penalty_prices_only_nonresident_pages():
    kv = PagedKVCache(paged_perf(sec_per_tok=1e-3, fetch_per_tok=2e-3),
                      page_tokens=4)
    a = decode_node("q0/d", ctx=16, workload=1 << 20)
    kv.migrate_for_dispatch(round_node([a]), "gpu")
    r = round_node([a])
    assert kv.migrate_penalty(r, "gpu") == (0, 0.0)       # resident: free
    moving, cost = kv.migrate_penalty(r, "cpu")
    assert moving == 1 and cost == pytest.approx(16 * 1e-3)
    # demote one page to dram by hand: the penalty mixes fetch + migrate
    pg = kv._pages[kv.tracked(a).pages[0]]
    pg.refs = 0
    kv._place(pg, DRAM)
    moving, cost = kv.migrate_penalty(r, "cpu")
    assert moving == 1
    assert cost == pytest.approx(12 * 1e-3 + 4 * 2e-3)
    # back on gpu only the dram page pays (page-granular partial move)
    moving, cost = kv.migrate_penalty(r, "gpu")
    assert moving == 1 and cost == pytest.approx(4 * 2e-3)


# --- scheduler gate ----------------------------------------------------------

def test_scheduler_kv_pages_gate():
    perf = paged_perf()
    off = HeroScheduler(perf, ["cpu", "gpu", "npu"], 1e9, SchedulerConfig())
    assert off.kv is None
    on = HeroScheduler(perf, ["cpu", "gpu", "npu"], 1e9,
                       SchedulerConfig(kv_pages=True, kv_page_tokens=32))
    assert isinstance(on.kv, PagedKVCache)
    assert on.kv.page_tokens == 32
    assert on.policy.kv is on.kv


# --- hypothesis properties ---------------------------------------------------

def test_pages_exclusive_tiers_and_pins_respected():
    hyp = pytest.importorskip("hypothesis")
    st_ = pytest.importorskip("hypothesis.strategies")

    PUS = ("cpu", "gpu", "npu")

    @hyp.given(st_.lists(st_.tuples(st_.integers(0, 2),   # stream index
                                    st_.integers(0, 2),   # pu index
                                    st_.integers(0, 3)),  # op selector
                         min_size=1, max_size=50),
               st_.lists(st_.integers(0, 120), min_size=3, max_size=3))
    @hyp.settings(max_examples=50, deadline=None)
    def prop(ops, ctxs):
        # tiny arenas so demotion happens constantly
        kv = PagedKVCache(paged_perf(caps={"cpu": 64.0, "gpu": 64.0,
                                           "npu": 64.0, "dram": 96.0}),
                          page_tokens=8)
        # seed evictable prefix pages
        kv.on_prefill_done(prefill_node("seed/p", [("ctx:s", 40)]), "gpu")
        nodes = [decode_node(f"q{i}/d", ctx=ctxs[i], workload=1 << 20)
                 for i in range(3)]
        for si, pi, op in ops:
            m, pu = nodes[si], PUS[pi]
            before = {pid: (pg.tier, pg.refs)
                      for pid, pg in kv._pages.items()}
            if op in (0, 1):
                kv.migrate_for_dispatch(round_node([m]), pu)
            elif op == 2:
                if kv.tracked(m) is not None:
                    kv.on_boundary(m, pu, 8)
            else:
                kv.release(m)
            check_invariants(kv)
            # eviction respects pins: a page pinned before the op never
            # moved DOWN to a spill tier (PU→PU gathers are fine; pages
            # whose pins were dropped by the op itself are exempt)
            for pid, (tier, refs) in before.items():
                pg = kv._pages.get(pid)
                if pg is None or refs <= 0 or pg.refs <= 0:
                    continue
                if tier not in (DRAM, DISK):
                    assert pg.tier not in (DRAM, DISK)
        for m in nodes:
            kv.release(m)
        check_invariants(kv)
        # only unpinned prefix-cache pages may remain
        assert all(pg.refs == 0 and pg.hash is not None
                   for pg in kv._pages.values())
        # soft-overflow conservation: any breach an all-pinned arena
        # forced mid-run is demoted away at release, so every bounded
        # tier ends back under its capacity
        for tier in ("cpu", "gpu", "npu", DRAM):
            assert kv.resident_bytes(tier) <= kv._capacity(tier) + 1e-9

    prop()


def test_hit_plus_miss_tokens_conserve_prefix():
    hyp = pytest.importorskip("hypothesis")
    st_ = pytest.importorskip("hypothesis.strategies")

    @hyp.given(st_.integers(1, 5),            # shared segments warmed
               st_.lists(st_.integers(1, 90), min_size=1, max_size=6),
               st_.integers(1, 64))           # page size
    @hyp.settings(max_examples=60, deadline=None)
    def prop(warm_k, seg_tokens, page_tokens):
        segs = [(f"s{i}", t) for i, t in enumerate(seg_tokens)]
        kv = PagedKVCache(paged_perf(), page_tokens=page_tokens)
        kv.on_prefill_done(prefill_node("w/p", segs[:warm_k]), "gpu")
        n = prefill_node("q/p", segs)
        total = n.workload
        kv.apply_prefix_hits(n)
        hit = n.payload.get("kv_hit_tokens", 0)
        # conservation: skipped + remaining == the original prefix
        assert hit + n.workload == total
        assert n.workload >= 1
        # hits never exceed the warmed prefix
        assert hit <= sum(t for _k, t in segs[:warm_k])
        if hit:
            assert kv.hit_tokens == hit

    prop()


# --- goldens: kv_pages off is bit-identical ----------------------------------

@pytest.fixture(scope="module")
def traces():
    return sample_traces("hotpotqa", 8, seed=11)


@pytest.fixture(scope="module")
def means(traces):
    return default_means(traces)


def test_goldens_bit_identical_with_pages_off(traces, means):
    """kv_pages=False (the default) keeps both the PR 2 coalesce-off and
    PR 3 continuous-decode goldens bit-exact: no page table, no prefix
    trimming, no tier charges."""
    with open(os.path.join(GOLDEN_DIR, "pr2_coalesce_off.json")) as f:
        pr2 = json.load(f)
    with open(os.path.join(GOLDEN_DIR, "pr3_decode_batch.json")) as f:
        pr3 = json.load(f)
    for coalesce, golden in ((False, pr2["staggered8_w1_makespans"]),
                             (True, pr3["saturated8_w1_decode_makespans"])):
        sess = HeroSession(world="sd8gen4", family="qwen3", means=means,
                           coalesce=coalesce, batch_policy="fixed",
                           kv_pages=False)
        for qi, tr in enumerate(traces):
            sess.submit(tr, wf=1, arrival_time=qi * 0.25)
        got = [r.makespan for r in sess.run()]
        assert got == pytest.approx(golden, rel=1e-12)
        assert sess.last_run.kv_page_hits == 0
        assert sess.last_run.kv_hit_tokens == 0


# --- end-to-end: shared-corpus prefix reuse ----------------------------------

def test_shared_corpus_prefix_reuse_beats_pages_off():
    traces = shared_corpus_traces("hotpotqa", 8, seed=3)
    runs = {}
    for label, kw in (("off", dict(kv_residency=True)),
                      ("pages", dict(kv_pages=True))):
        sess = HeroSession(world="sd8gen4", family="qwen3", strategy="hero",
                           coalesce=True, batch_policy="adaptive", **kw)
        for qi, tr in enumerate(traces):
            sess.submit(tr, wf=1, arrival_time=qi * 0.5)
        res = sess.run()
        runs[label] = (max(r.finish_time for r in res), res, sess.last_run)
    total_off, _res_off, run_off = runs["off"]
    total_on, res_on, run_on = runs["pages"]
    assert run_off.kv_page_hits == 0          # monolith can't hit
    assert run_on.kv_page_hits > 0
    assert run_on.kv_hit_tokens > 0
    # per-query attribution sums to the run total, and at least one
    # later query actually skipped prefill work
    assert sum(r.kv_page_hits for r in res_on) == run_on.kv_page_hits
    assert sum(r.kv_hit_tokens for r in res_on) == run_on.kv_hit_tokens
    assert any(e[1] == "kv_page_hit" for e in run_on.events)
    # the reuse must buy wall-clock, the reason the subsystem exists
    assert total_on < total_off
