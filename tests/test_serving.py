"""Serving runtime tests: engine (chunked prefill + continuous batching)
and the wall-clock HeRo runtime (straggler/fault handling)."""
import time

import jax
import pytest

from repro.configs import get_config, reduced
from repro.core import (GroundTruthPerf, HeroScheduler, LinearPerfModel,
                        SchedulerConfig, StageModel, snapdragon_8gen4)
from repro.core.dag import DynamicDAG, Node
from repro.models import build_model
from repro.serving import HeroRuntime, PUExecutor, ServingEngine


@pytest.fixture(scope="module")
def engine():
    cfg = reduced(get_config("qwen1.5-0.5b"))
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    return ServingEngine(cfg, params, max_len=128, prefill_chunk=16,
                         token_group=4)


def test_engine_continuous_batching(engine):
    rids = [engine.submit([5 + i] * (10 + 7 * i), max_new=5)
            for i in range(3)]
    done = engine.run_to_completion()
    assert sorted(r.rid for r in done) == sorted(rids)
    for r in done:
        assert 1 <= len(r.generated) <= 5
        assert r.prefilled == len(r.prompt_ids)   # chunked prefill completed


def test_engine_chunked_prefill_bounded(engine):
    rid = engine.submit(list(range(4, 64)), max_new=3)
    steps = 0
    while engine.queue or engine.active:
        engine.step()
        steps += 1
        assert steps < 100
    # 60 prompt tokens / 16-token chunks -> at least 4 prefill steps
    assert steps >= 4


@pytest.fixture(scope="module")
def runtime_world():
    soc = snapdragon_8gen4()
    stages = {"a": StageModel("a", int(1e8), 512, "batchable"),
              "b": StageModel("b", int(1e8), 512, "batchable")}
    gt = GroundTruthPerf(soc, stages)
    return soc, LinearPerfModel().fit(gt)


def test_runtime_straggler_rerouting(runtime_world):
    soc, perf = runtime_world
    dag = DynamicDAG()
    dag.add(Node("n1", "a", "batchable", 4))
    dag.add(Node("n2", "b", "batchable", 4, deps={"n1"}))
    calls = {"n": 0}

    def work(node, batch):
        calls["n"] += 1
        time.sleep(2.0 if calls["n"] == 1 else 0.01)
        return node.id

    sched = HeroScheduler(perf, ["cpu", "gpu", "npu"], soc.dram_bw,
                          SchedulerConfig())
    rt = HeroRuntime(sched, {p: PUExecutor(p) for p in ("cpu", "gpu", "npu")},
                     {"a": work, "b": work})
    t0 = time.time()
    res = rt.run(dag, timeout=30)
    assert sorted(res) == ["n1", "n2"]
    assert time.time() - t0 < 1.5          # straggler absorbed, not awaited
    assert any(e[1] == "straggler" for e in rt.events)


def test_runtime_retry_on_exception(runtime_world):
    soc, perf = runtime_world
    dag = DynamicDAG()
    dag.add(Node("n1", "a", "batchable", 4))
    attempts = {"n": 0}

    def flaky(node, batch):
        attempts["n"] += 1
        if attempts["n"] == 1:
            raise RuntimeError("transient")
        return "ok"

    sched = HeroScheduler(perf, ["cpu", "gpu", "npu"], soc.dram_bw,
                          SchedulerConfig())
    rt = HeroRuntime(sched, {p: PUExecutor(p) for p in ("cpu", "gpu", "npu")},
                     {"a": flaky})
    res = rt.run(dag, timeout=30)
    assert res["n1"] == "ok"
    assert attempts["n"] == 2
    assert any(e[1] == "retry" for e in rt.events)


def test_runtime_elastic_membership(runtime_world):
    soc, perf = runtime_world
    sched = HeroScheduler(perf, ["cpu"], soc.dram_bw, SchedulerConfig())
    rt = HeroRuntime(sched, {"cpu": PUExecutor("cpu")},
                     {"a": lambda n, b: n.id})
    rt.add_executor("npu", PUExecutor("npu"))
    assert "npu" in sched.pus
    dag = DynamicDAG()
    dag.add(Node("n1", "a", "batchable", 64))
    res = rt.run(dag, timeout=30)
    assert res["n1"] == "n1"
    rt.remove_executor("npu")
    assert "npu" not in sched.pus
