"""HLO cost-model validation: the roofline's FLOP/byte/collective walker
against analytically-known programs (see EXPERIMENTS.md §Dry-run)."""
import jax
import jax.numpy as jnp
import pytest

from repro.launch.hlo_cost import analyze
from repro.launch.mesh import compat_make_mesh


@pytest.fixture(scope="module")
def mesh():
    # compat_make_mesh pins Auto axis types where the installed jax has
    # jax.sharding.AxisType, and degrades to a plain mesh on versions
    # (like 0.4.x) that predate it
    n = len(jax.devices())
    return compat_make_mesh((1, n), ("data", "model"))


def test_matmul_flops_exact(mesh):
    M, K, N = 256, 128, 512
    comp = jax.jit(lambda x, w: x @ w).lower(
        jax.ShapeDtypeStruct((M, K), jnp.float32),
        jax.ShapeDtypeStruct((K, N), jnp.float32)).compile()
    r = analyze(comp.as_text())
    assert r["flops"] == pytest.approx(2 * M * K * N, rel=0.01)


def test_scan_trip_count_multiplies(mesh):
    L, D = 12, 64

    def f(x, ws):
        def body(c, w):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, ws)
        return y

    comp = jax.jit(f).lower(
        jax.ShapeDtypeStruct((D, D), jnp.float32),
        jax.ShapeDtypeStruct((L, D, D), jnp.float32)).compile()
    r = analyze(comp.as_text())
    want = 2 * D * D * D * L
    assert want <= r["flops"] <= want * 1.1
    # XLA's own analysis undercounts by ~L (the documented failure mode)
    ca = comp.cost_analysis()
    if isinstance(ca, (list, tuple)):   # jax <= 0.4.x: one dict per program
        ca = ca[0] if ca else {}
    xla = float(ca.get("flops", 0.0))
    assert xla < r["flops"] / 2


def test_bytes_positive_and_bounded(mesh):
    M = 512

    def f(x, w):
        return jax.nn.relu(x @ w).sum()

    comp = jax.jit(f).lower(
        jax.ShapeDtypeStruct((M, M), jnp.float32),
        jax.ShapeDtypeStruct((M, M), jnp.float32)).compile()
    r = analyze(comp.as_text())
    lower = 2 * M * M * 4            # must at least read both operands
    upper = 20 * M * M * 4           # and not blow up by orders of magnitude
    assert lower <= r["bytes"] <= upper
