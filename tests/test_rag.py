"""RAG substrate tests: tokenizer/chunker/vectordb/embedder + end-to-end
retrieval sanity, plus workflow-builder structure checks."""
import jax
import numpy as np
import pytest

from repro.configs import get_family, reduced
from repro.models import build_model
from repro.rag import (HashTokenizer, VectorDB, build_workflow,
                       chunk_documents, sample_traces, synth_documents)
from repro.rag.embedder import Embedder, Reranker


def test_tokenizer_deterministic_and_bounded():
    tok = HashTokenizer(1000)
    ids = tok.encode("the quick brown fox", bos=True, eos=True)
    assert ids == tok.encode("the quick brown fox", bos=True, eos=True)
    assert all(0 <= i < 1000 for i in ids)
    assert ids[0] == 1 and ids[-1] == 2


def test_chunker_paper_defaults():
    tok = HashTokenizer(32000)
    docs = synth_documents(3, 400, seed=0)
    chunks = chunk_documents(docs, tok, chunk_size=128, overlap=10)
    assert all(len(c.token_ids) <= 128 for c in chunks)
    # 400 tokens -> ceil((400-10)/118) ~ 4 chunks per doc
    per_doc = {}
    for c in chunks:
        per_doc[c.doc_id] = per_doc.get(c.doc_id, 0) + 1
    assert all(3 <= n <= 5 for n in per_doc.values())


def test_vectordb_exact_search():
    db = VectorDB(dim=16, capacity=1024)
    rng = np.random.default_rng(0)
    vecs = rng.normal(size=(300, 16)).astype(np.float32)
    vecs /= np.linalg.norm(vecs, axis=1, keepdims=True)
    db.add(jax.numpy.asarray(vecs))
    q = vecs[[5, 17]]
    vals, ids = db.search(jax.numpy.asarray(q), k=3)
    assert ids[0, 0] == 5 and ids[1, 0] == 17        # self-match first
    assert vals[0, 0] == pytest.approx(1.0, abs=1e-3)


def test_vectordb_incremental_add_consistency():
    db = VectorDB(dim=8, capacity=512)
    rng = np.random.default_rng(1)
    vecs = rng.normal(size=(100, 8)).astype(np.float32)
    for i in range(0, 100, 10):                      # indexing sub-stages
        db.add(jax.numpy.asarray(vecs[i:i + 10]))
    vals, ids = db.search(jax.numpy.asarray(vecs[[42]]), k=1)
    assert ids[0, 0] == 42


def test_embedder_reranker_pipeline(rng):
    fam = {k: reduced(v) for k, v in get_family("qwen3").items()}
    e_cfg = fam["embed"]
    params = build_model(e_cfg).init(rng)
    emb = Embedder(e_cfg, params, max_tokens=32)
    tok = HashTokenizer(e_cfg.vocab_size)
    texts = ["market revenue growth", "neural retrieval system",
             "market revenue growth quarter"]
    vecs = np.asarray(emb.embed([tok.encode(t) for t in texts]))
    assert vecs.shape == (3, e_cfg.d_model)
    np.testing.assert_allclose(np.linalg.norm(vecs, axis=1), 1.0, atol=1e-3)
    # near-duplicate texts embed closer than unrelated ones
    assert vecs[0] @ vecs[2] > vecs[0] @ vecs[1]

    r_cfg = fam["rerank"]
    rr = Reranker(r_cfg, build_model(r_cfg).init(rng), max_tokens=48)
    scores = rr.score(tok.encode(texts[0]),
                      [tok.encode(t) for t in texts])
    assert scores.shape == (3,)
    assert np.isfinite(scores).all()


@pytest.mark.parametrize("wf", [1, 2, 3])
@pytest.mark.parametrize("fine", [True, False])
def test_workflow_structure(wf, fine):
    tr = sample_traces("hotpotqa", 1, seed=5)[0]
    dag = build_workflow(wf, tr, fine_grained=fine)
    names = set(dag.nodes)
    assert "embed_chunks" in names and "chat_decode" in names
    if wf >= 2:
        assert "rewrite_decode" in names
    if wf >= 3:
        assert "plan_decode" in names
    # graph is a DAG
    order = dag.topo_order()
    assert len(order) == len(dag.nodes)


def test_dynamic_expansion_spawns_branches():
    tr = sample_traces("2wikimqa", 1, seed=2)[0]
    dag = build_workflow(3, tr, fine_grained=True)
    n_before = len(dag.nodes)
    # manually complete the rewrite chain to fire the expander
    for nid in ["embed_chunks", "embed_query", "rewrite_prefill"]:
        dag.nodes[nid].status = "done"
    dag.mark_done("rewrite_decode", 1.0)
    assert len(dag.nodes) > n_before          # sub-query branches appeared
    assert any(n.startswith("vsearch_sq") for n in dag.nodes)
