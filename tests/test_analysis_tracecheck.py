"""repro.analysis.tracecheck — every committed golden/bench artifact
passes; one seeded mutant per violation class fails."""
import copy
import glob
import json
import os

import pytest

from repro.analysis.tracecheck import check_trace

GOLDEN_DIR = os.path.join(os.path.dirname(__file__), "goldens")
BASELINE_DIR = os.path.join(os.path.dirname(__file__), os.pardir,
                            "benchmarks", "baselines")


def _load(path):
    with open(path) as f:
        return json.load(f)


@pytest.fixture(scope="module")
def trace():
    """The continuous-batching trace golden: has rounds + fan-out."""
    return _load(os.path.join(GOLDEN_DIR, "trace_pr3_decode_batch.json"))


@pytest.fixture(scope="module")
def kv_trace():
    return _load(os.path.join(GOLDEN_DIR, "trace_pr6_kv_preempt.json"))


def _rules(doc, path="<t>"):
    return sorted({v.rule for v in check_trace(doc, path)})


# --- committed artifacts all pass --------------------------------------------

@pytest.mark.parametrize("path", sorted(
    glob.glob(os.path.join(GOLDEN_DIR, "*.json"))),
    ids=lambda p: os.path.basename(p))
def test_goldens_pass(path):
    violations = check_trace(_load(path), path)
    assert violations == [], "\n".join(str(v) for v in violations)


@pytest.mark.parametrize("path", sorted(
    glob.glob(os.path.join(BASELINE_DIR, "serving_*.json"))),
    ids=lambda p: os.path.basename(p))
def test_bench_baselines_pass(path):
    violations = check_trace(_load(path), path)
    assert violations == [], "\n".join(str(v) for v in violations)


def test_trace_goldens_have_real_content(trace, kv_trace):
    # the suite must not pass vacuously
    assert any(e[1] == "tokens" for e in trace["events"])
    assert len(trace["dispatches"]) > 10
    assert kv_trace["counters"]["kv_migrations"] > 0
    assert kv_trace["counters"]["kv_page_hits"] > 0


# --- lifecycle mutants -------------------------------------------------------

def _first(doc, ev):
    return next(e for e in doc["events"] if e[1] == ev)


def test_tr101_serve_after_completion(trace):
    m = copy.deepcopy(trace)
    done = _first(m, "done")
    m["events"].append([m["makespan"], "start", done[2]])
    m["counters"]["dispatches"] += 1
    assert "TR101" in _rules(m)


def test_tr102_tokens_on_finished_stream(trace):
    m = copy.deepcopy(trace)
    done = _first(m, "done")
    m["events"].append([m["makespan"], "tokens", done[2]])
    assert "TR102" in _rules(m)


def test_tr104_double_completion(trace):
    m = copy.deepcopy(trace)
    done = _first(m, "done")
    m["events"].append([m["makespan"], "done", done[2]])
    assert "TR104" in _rules(m)


def test_tr105_done_without_start(trace):
    m = copy.deepcopy(trace)
    m["events"].remove(_first(m, "start"))
    rules = _rules(m)
    assert "TR105" in rules and "TR304" in rules   # also a counter drift


def test_tr106_redispatch_on_finished_node(trace):
    m = copy.deepcopy(trace)
    done = _first(m, "done")
    m["events"].append([m["makespan"], "redispatch", done[2]])
    m["counters"]["redispatches"] += 1
    assert "TR106" in _rules(m)


# --- PU serialization mutants ------------------------------------------------

def test_tr202_double_serve(trace):
    m = copy.deepcopy(trace)
    by_pu = {}
    for d in m["dispatches"]:
        if d["pu"] != "io":
            by_pu.setdefault(d["pu"], []).append(d)
    lst = next(sorted(l, key=lambda d: d["t0"])
               for l in by_pu.values() if len(l) >= 2)
    # stretch the first serve interval into the second: a double-serve
    lst[0]["t1"] = lst[1]["t0"] + (lst[1]["t1"] - lst[1]["t0"]) / 2 + 0.01
    assert "TR202" in _rules(m)


def test_tr201_interval_ends_before_start(trace):
    m = copy.deepcopy(trace)
    d = m["dispatches"][0]
    d["t0"], d["t1"] = d["t1"] + 1.0, d["t0"]
    assert "TR201" in _rules(m)


def test_io_concurrency_is_exempt():
    doc = {"schema": "repro.trace/v1", "makespan": 2.0, "events": [],
           "counters": {}, "pu_busy": {},
           "dispatches": [{"node": "a", "pu": "io", "t0": 0.0, "t1": 1.0},
                          {"node": "b", "pu": "io", "t0": 0.5, "t1": 1.5}]}
    assert check_trace(doc) == []


# --- conservation mutants ----------------------------------------------------

def test_tr301_unknown_event_name(trace):
    m = copy.deepcopy(trace)
    m["events"].append([0.0, "kv_migrat", "q0/x"])
    assert "TR301" in _rules(m)


def test_tr302_event_past_makespan(trace):
    m = copy.deepcopy(trace)
    m["makespan"] = m["events"][-1][0] / 2
    assert "TR302" in _rules(m)


def test_tr303_timeline_goes_backwards(trace):
    m = copy.deepcopy(trace)
    ev = copy.deepcopy(m["events"][-1])
    ev[0] = -0.5
    m["events"].append(ev)
    rules = _rules(m)
    assert "TR303" in rules or "TR302" in rules


def test_tr304_counter_event_drift(kv_trace):
    m = copy.deepcopy(kv_trace)
    m["counters"]["kv_migrations"] += 1
    assert "TR304" in _rules(m)


def test_tr305_drained_events_exceed_counter(kv_trace):
    m = copy.deepcopy(kv_trace)
    m["counters"]["kv_page_hits"] = 0
    assert "TR305" in _rules(m)


def test_tr307_bytes_moved_without_migrations(kv_trace):
    m = copy.deepcopy(kv_trace)
    n = m["counters"]["kv_migrations"]
    m["counters"]["kv_migrations"] = 0
    m["events"] = [e for e in m["events"] if e[1] != "kv_migrate"]
    m["dispatches"] = m["dispatches"]
    assert n > 0 and "TR307" in _rules(m)


def test_tr308_accepted_exceeds_drafted():
    spec = _load(os.path.join(GOLDEN_DIR, "trace_pr9_specdec.json"))
    m = copy.deepcopy(spec)
    m["counters"]["accepted_tokens"] = m["counters"]["drafted_tokens"] + 1
    assert "TR308" in _rules(m)


def test_tr309_pu_busy_exceeds_makespan(trace):
    m = copy.deepcopy(trace)
    pu = next(iter(m["pu_busy"]))
    m["pu_busy"][pu] = m["makespan"] * 2
    assert "TR309" in _rules(m)


# --- bench-artifact mutants --------------------------------------------------

@pytest.fixture(scope="module")
def bench():
    return _load(os.path.join(BASELINE_DIR, "serving_specdec.json"))


def _first_row(doc):
    regime = next(iter(doc["regimes"]))
    system = next(iter(doc["regimes"][regime]))
    return doc["regimes"][regime][system]


def test_bn301_negative_metric(bench):
    m = copy.deepcopy(bench)
    _first_row(m)["p50"] = -1.0
    assert "BN301" in _rules(m)


def test_bn302_p50_above_p99(bench):
    m = copy.deepcopy(bench)
    row = _first_row(m)
    row["p50"] = row["p99"] * 2 + 1
    assert "BN302" in _rules(m)


def test_bn303_accepted_above_drafted(bench):
    m = copy.deepcopy(bench)
    row = _first_row(m)
    row["accepted"] = row.get("drafted", 0) + 5
    assert "BN303" in _rules(m)


# --- flat makespan goldens ---------------------------------------------------

def test_gl301_nonpositive_makespan():
    m = _load(os.path.join(GOLDEN_DIR, "pr2_coalesce_off.json"))
    m = copy.deepcopy(m)
    m["staggered8_w1_makespans"][0] = 0.0
    assert "GL301" in _rules(m)


def test_schema_sniffing_rejects_non_object():
    assert [v.rule for v in check_trace([1, 2, 3])] == ["TR000"]
