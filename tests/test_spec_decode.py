"""Speculative decoding (the PR 9 tentpole).

Covers the ISSUE's required invariants: the pass arithmetic and the
accept-rate EWMA (:mod:`repro.core.spec_decode`), draft KV pages are
never pinned and always evicted before verify pages, rejected-token
rollback at a round boundary never moves the draft mirror below the
served verify context, ``spec_decode=False`` through the typed
``SessionOptions`` path stays bit-identical to the PR 2 / PR 3
goldens, and the counter protocol — per-query ``QueryResult`` stamps
sum to the ``BackendRun`` totals with the width grid exercised — on
both backends.
"""
import json
import math
import os
import time

import pytest

from repro.api import DecodeSpec, HeroSession, SessionOptions
from repro.core.dag import Node
from repro.core.kv_pages import DRAFT_KEY, DRAM, PagedKVCache
from repro.core.kv_residency import stream_key
from repro.core.perf_model import LinearPerfModel
from repro.core.spec_decode import (SpecTracker, draft_stage_of,
                                    is_draft_stage, spec_passes)
from repro.rag import default_means, sample_traces

GOLDEN_DIR = os.path.join(os.path.dirname(__file__), "goldens")

STAGE = "chat_decode"
DRAFT = "chat_draft"


# --- leaf arithmetic ----------------------------------------------------------

def test_spec_passes_bounds_and_degradation():
    # alpha = 0 degrades to plain one-token-per-pass decode
    assert spec_passes(64, 4, 0.0) == 64
    # width 0 likewise — nothing drafted, nothing accepted
    assert spec_passes(64, 0, 0.9) == 64
    # the expected-pass formula: ceil(g / (1 + alpha*w))
    assert spec_passes(64, 4, 0.5) == math.ceil(64 / 3.0) == 22
    # never below one pass, alpha clamped into [0, 1]
    assert spec_passes(1, 8, 1.0) == 1
    assert spec_passes(10, 4, 5.0) == 2


def test_draft_stage_naming_convention():
    assert draft_stage_of("chat_decode") == "chat_draft"
    assert draft_stage_of("rewrite_decode") == "rewrite_draft"
    assert draft_stage_of("chat_prefill") is None
    # draft stages never recurse into drafts of drafts
    assert draft_stage_of("chat_draft") is None
    assert is_draft_stage("chat_draft") and not is_draft_stage(STAGE)


def test_spec_tracker_ewma_and_run_totals():
    tr = SpecTracker(init=0.6, weight=0.5)
    assert tr.alpha("s") == 0.6
    # profiled pair prior overrides the tracker-wide init for unseen keys
    assert tr.alpha("s", 0.2) == 0.2
    tr.observe("s", drafted=8, accepted=4)
    assert tr.alpha("s") == pytest.approx(0.5 * 0.6 + 0.5 * 0.5)
    # once observed, the prior no longer applies
    assert tr.alpha("s", 0.2) == tr.alpha("s")
    assert (tr.drafted_tokens, tr.accepted_tokens, tr.rounds) == (8, 4, 1)
    assert tr.accept_rate == pytest.approx(0.5)
    # accepted clamps into [0, drafted]; zero drafted is a no-op
    tr.observe("s", 4, 10)
    assert tr.accepted_tokens == 8 and tr.drafted_tokens == 12
    tr.observe("s", 0, 5)
    assert tr.rounds == 2


# --- draft KV: eviction priority + rollback boundary --------------------------

def spec_perf(caps=None):
    """A LinearPerfModel with 1 byte/token for both the verify stage and
    its draft companion, and handcrafted tier capacities."""
    m = LinearPerfModel()
    m._tiles = {"gpu": 8}
    m._b0 = 1e9
    m.kv_bytes = {STAGE: 1.0, DRAFT: 1.0}
    m.phi_coef = {STAGE: [1.0, 0.0, 0.0], DRAFT: [1.0, 0.0, 0.0]}
    m.kv_tiers = dict(caps or {})
    return m


def member(nid="q0/d", workload=256):
    return Node(id=nid, stage=STAGE, kind="stream_decode",
                workload=workload, payload={})


def check_accounting(kv):
    for tier in kv._tier_pages:
        used = sum(kv._page_bytes(kv._pages[p])
                   for p in kv._tier_pages[tier])
        assert kv._tier_used.get(tier, 0.0) == pytest.approx(used)


def test_draft_pages_never_pinned_and_evicted_before_verify():
    """The draft mirror's pages are unpinned (``refs == 0``) and leave
    the arena before ANY verify page: under pressure the demotion picks
    a draft page even when older verify pages are equally evictable."""
    kv = PagedKVCache(spec_perf(caps={"gpu": 300.0}), page_tokens=64)
    m = member()
    kv.on_boundary(m, "gpu", 128)             # verify: 2 pages, 128 B
    kv.spec_draft_sync(m, DRAFT, "gpu")       # draft mirror: 128 B more
    dst = kv._streams[stream_key(m) + DRAFT_KEY]
    assert dst.ctx_tokens == 128
    dpages = [kv._pages[p] for p in dst.pages]
    assert all(pg.draft and pg.refs == 0 for pg in dpages)
    check_accounting(kv)

    # grow the verify stream past capacity: 256 + 64 > 300 forces one
    # eviction — it must be a draft page, though the verify pages are
    # older (smaller LRU clock) and equally unpinned
    kv.on_boundary(m, "gpu", 64)
    assert kv.evictions == 1
    vst = kv._streams[stream_key(m)]
    assert all(kv._pages[p].tier == "gpu" for p in vst.pages)
    demoted = [pg for pg in kv._pages.values()
               if pg.draft and pg.tier == DRAM]
    assert len(demoted) == 1                  # the victim was draft KV
    check_accounting(kv)


def test_rollback_never_moves_draft_mirror_below_served_boundary():
    """Rejected-token rollback: a speculative tail written ahead of the
    verify boundary trims back exactly to the served context — never
    below it — and forward growth tracks the verify stream."""
    kv = PagedKVCache(spec_perf(), page_tokens=64)
    m = member()
    kv.on_boundary(m, "gpu", 100)
    kv.spec_draft_sync(m, DRAFT, "gpu")
    key = stream_key(m) + DRAFT_KEY
    assert kv._streams[key].ctx_tokens == 100

    # speculative tail in flight: the draft model streamed 37 candidate
    # tokens past the boundary that the verify pass then rejected
    st = kv._streams[key]
    tail = kv._alloc(DRAFT, 37, "gpu", None, m)
    tail.draft = True
    st.pages.append(tail.pid)
    st.ctx_tokens += 37
    kv.spec_draft_sync(m, DRAFT, "gpu")       # boundary: roll the tail back
    assert kv._streams[key].ctx_tokens == 100
    assert sum(kv._pages[p].tokens for p in st.pages) == 100
    check_accounting(kv)

    # forward growth after the rollback still tracks the verify stream
    kv.on_boundary(m, "gpu", 50)
    kv.spec_draft_sync(m, DRAFT, "gpu")
    assert kv._streams[key].ctx_tokens == 150
    check_accounting(kv)

    # terminal release frees BOTH footprints
    kv.release(m)
    assert stream_key(m) not in kv._streams and key not in kv._streams
    assert not any(pg.draft for pg in kv._pages.values())


# --- typed options: validation ------------------------------------------------

def test_session_options_spec_validation():
    with pytest.raises(ValueError, match="spec_decode"):
        SessionOptions(spec_decode=True)          # needs coalesce
    with pytest.raises(ValueError, match="draft_model"):
        SessionOptions(draft_model="qwen1p5_0p5b")  # needs spec_decode
    with pytest.raises(ValueError, match="draft_model"):
        SessionOptions(coalesce=True, spec_decode=True,
                       draft_model="nope_7b")
    ok = SessionOptions(coalesce=True, batch_policy="adaptive",
                        spec_decode=True, draft_model="qwen1p5_0p5b")
    ov = ok.scheduler_overrides()
    assert ov["spec_decode"] is True
    assert ov["draft_model"] == "qwen1p5_0p5b"
    with pytest.raises(ValueError):
        DecodeSpec(draft_model="nope_7b")
    with pytest.raises(ValueError):
        DecodeSpec(draft_width=0)


# --- spec off: bit-identical to the PR 2 / PR 3 goldens -----------------------

@pytest.fixture(scope="module")
def traces():
    return sample_traces("hotpotqa", 8, seed=11)


@pytest.fixture(scope="module")
def means(traces):
    return default_means(traces)


def test_spec_off_reproduces_pr2_goldens_via_options(traces, means):
    """The typed options path with the spec knobs present-and-off must
    reproduce the PR 2 coalesce-off goldens bit-exactly."""
    with open(os.path.join(GOLDEN_DIR, "pr2_coalesce_off.json")) as f:
        golden = json.load(f)
    sess = HeroSession(world="sd8gen4", family="qwen3", means=means,
                       options=SessionOptions(coalesce=False,
                                              batch_policy="fixed"))
    for qi, tr in enumerate(traces):
        sess.submit(tr, wf=1, arrival_time=qi * 0.25)
    got = [r.makespan for r in sess.run()]
    assert got == pytest.approx(golden["staggered8_w1_makespans"], rel=1e-12)


@pytest.mark.parametrize("regime,ia", [("saturated", 0.25),
                                       ("staggered", 2.0)])
def test_spec_off_reproduces_pr3_goldens_via_options(traces, means, regime,
                                                     ia):
    with open(os.path.join(GOLDEN_DIR, "pr3_decode_batch.json")) as f:
        golden = json.load(f)
    sess = HeroSession(world="sd8gen4", family="qwen3", means=means,
                       options=SessionOptions(coalesce=True,
                                              batch_policy="fixed"))
    for qi, tr in enumerate(traces):
        sess.submit(tr, wf=1, arrival_time=qi * ia)
    got = [r.makespan for r in sess.run()]
    assert got == pytest.approx(golden[f"{regime}8_w1_decode_makespans"],
                                rel=1e-12)


# --- counter protocol on both backends ----------------------------------------

SPEC_OPTS = dict(coalesce=True, batch_policy="adaptive", spec_decode=True)


def _spec_session(traces, means, backend="sim", ia=2.0, **kw):
    sess = HeroSession(world="sd8gen4", family="qwen3", means=means,
                       backend=backend,
                       options=SessionOptions(**SPEC_OPTS), **kw)
    for qi, tr in enumerate(traces):
        sess.submit(tr, wf=1, arrival_time=qi * ia)
    return sess


def test_spec_counters_sim_sum_to_run_totals(traces, means):
    """Per-query ``QueryResult`` stamps sum to the ``BackendRun`` totals
    (the preemptions counter contract), the width grid is exercised,
    and the EWMA observed real rounds (drafted > 0)."""
    sess = _spec_session(traces, means)
    results = sess.run()
    run = sess.last_run
    assert run.spec_rounds > 0 and run.drafted_tokens > 0
    assert 0 <= run.accepted_tokens <= run.drafted_tokens
    assert sum(r.drafted_tokens for r in results) == run.drafted_tokens
    assert sum(r.accepted_tokens for r in results) == run.accepted_tokens
    for r in results:
        if r.drafted_tokens:
            assert r.accept_rate == pytest.approx(
                r.accepted_tokens / r.drafted_tokens)
        else:
            assert r.accept_rate is None
    # the width grid was exercised: the histogram counts speculative
    # DISPATCHES; the tracker's rounds count per-member boundary
    # observations, so dispatches never exceed member-rounds
    widths = run.batching.get("spec_width", {})
    assert widths and all(int(w) >= 1 for w in widths)
    assert 0 < sum(widths.values()) <= run.spec_rounds


def test_spec_off_has_no_spec_surface(traces, means):
    sess = HeroSession(world="sd8gen4", family="qwen3", means=means,
                       options=SessionOptions(coalesce=True,
                                              batch_policy="adaptive"))
    for qi, tr in enumerate(traces):
        sess.submit(tr, wf=1, arrival_time=qi * 2.0)
    results = sess.run()
    run = sess.last_run
    assert run.drafted_tokens == run.accepted_tokens == run.spec_rounds == 0
    assert "spec_width" not in run.batching
    assert all(r.drafted_tokens == 0 and r.accept_rate is None
               for r in results)


def test_spec_counters_live_parity(means):
    """The live executor runs the same (draft, verify) pairs: counters
    follow the identical protocol and the width grid is exercised."""
    traces6 = sample_traces("hotpotqa", 6, seed=11)
    sess = _spec_session(
        traces6, default_means(traces6), backend="live", ia=0.0,
        stage_fns={"chat_decode": lambda n, b: time.sleep(0.02)})
    results = sess.run(timeout=180)
    run = sess.last_run
    assert run.spec_rounds > 0 and run.drafted_tokens > 0
    assert sum(r.drafted_tokens for r in results) == run.drafted_tokens
    assert sum(r.accepted_tokens for r in results) == run.accepted_tokens
    widths = run.batching.get("spec_width", {})
    assert widths and 0 < sum(widths.values()) <= run.spec_rounds
