"""Adaptive batching policy (the PR 4 tentpole).

Covers the ISSUE's required invariants: the derived decode width equals
the argmin knee of the profiled per-member marginal-gain curve on
synthetic grids (monotone grids saturate the cap — property-tested when
hypothesis is installed), already-READY members are never truncated below
the knee, the coalesce cap/window derivations are monotone in overhead
and arrival rate, ``batch_policy="fixed"`` reproduces the PR 2 and PR 3
goldens bit-exactly, sim/live parity at 8 mixed W1-W3 queries, and the
decode-round straggler-ETA fix (one token group per dispatch).
"""
import json
import os

import numpy as np
import pytest

from repro.api import HeroSession
from repro.api.session import make_world
from repro.api.spec import builtin_spec
from repro.core import SchedulerConfig
from repro.core.batch_policy import (AdaptiveBatchPolicy, ArrivalTracker,
                                     FixedBatchPolicy, make_policy)
from repro.core.dag import Node
from repro.core.partitioner import ceil_passes, dispatch_passes
from repro.core.perf_model import LinearPerfModel
from repro.rag import default_means, sample_traces

GOLDEN_DIR = os.path.join(os.path.dirname(__file__), "goldens")


# --- synthetic profiled grids -------------------------------------------------

def synthetic_perf(per_member, per_item=None, stage="dec", pu="xpu",
                   group=16):
    """A LinearPerfModel whose profiled tables are handcrafted.

    ``per_member``: {width: per-member latency of one group pass} — the
    decode grid (width 1 entry is the solo baseline).  ``per_item``:
    {batch: per-item latency} for the batchable grid."""
    m = LinearPerfModel()
    m._tiles = {pu: 8}
    m._b0 = 1e9
    m.coef[(stage, pu)] = np.zeros(4)
    # width-1 solo baseline: one group pass at the member's own latency
    m.table[(stage, pu)] = {group: (per_member[1], 0.0)}
    m.decode_table[(stage, pu)] = {
        (w, group): (pm * w, 0.0) for w, pm in per_member.items() if w > 1}
    if per_item is not None:
        m.table[(stage, pu)] = {n: (t * n, 0.0)
                                for n, t in per_item.items()}
        m.table[(stage, pu)].setdefault(group, (per_member[1], 0.0))
    return m


def adaptive(perf, **cfg_kw):
    return AdaptiveBatchPolicy(SchedulerConfig(**cfg_kw), perf)


# --- decode width cap ---------------------------------------------------------

def test_width_cap_is_argmin_of_marginal_gain_curve():
    """Convex per-member curve (gains positive then negative): the derived
    width is the argmin — the knee where marginal gain crosses zero."""
    pm = {1: 1.0, 2: 0.55, 3: 0.40, 4: 0.35, 6: 0.45, 8: 0.60}
    pol = adaptive(synthetic_perf(pm))
    cap = pol.decode_width_cap("dec", "xpu", tau=None)
    curve = {w: v for w, v in pm.items() if w > 1}
    assert cap == min(curve, key=curve.get) == 4


def test_width_cap_saturates_on_monotone_grid():
    """Monotone decreasing per-member latency ⇒ every marginal gain is
    positive ⇒ the cap saturates at the top of the profiled grid."""
    pm = {1: 1.0, 2: 0.5, 3: 0.34, 4: 0.26, 6: 0.18, 8: 0.14}
    pol = adaptive(synthetic_perf(pm))
    assert pol.decode_width_cap("dec", "xpu", tau=None) == 8


def test_width_cap_never_truncates_ready_members_below_knee():
    """READY members ride along for free: a sparse-arrival tau may limit
    the width held open for future members, but the cap never cuts the
    already-ready set below the spill knee."""
    pm = {1: 1.0, 2: 0.5, 3: 0.34, 4: 0.26, 6: 0.18, 8: 0.14}
    pol = adaptive(synthetic_perf(pm))
    sparse = 1e6   # arrivals far slower than any residency
    assert pol.decode_width_cap("dec", "xpu", tau=sparse) == 2
    got = pol.decode_width_cap("dec", "xpu", tau=sparse,
                               remainders=[64, 64, 64, 64, 64, 64])
    assert got == 6
    # ...but past the knee, truncation is correct even for ready members
    pm_spill = {1: 1.0, 2: 0.55, 3: 0.40, 4: 0.35, 6: 0.45, 8: 0.60}
    pol2 = adaptive(synthetic_perf(pm_spill))
    got2 = pol2.decode_width_cap("dec", "xpu", tau=sparse,
                                 remainders=[64] * 8)
    assert got2 == 4


def test_width_cap_monotone_in_tau():
    """Sparser arrivals can only shrink the width held open for members
    who have not arrived yet."""
    pm = {1: 1.0, 2: 0.5, 3: 0.34, 4: 0.26, 6: 0.18, 8: 0.14}
    pol = adaptive(synthetic_perf(pm))
    caps = [pol.decode_width_cap("dec", "xpu", tau=t)
            for t in (None, 0.0, 1.0, 100.0, 1e6)]
    assert caps == sorted(caps, reverse=True)
    assert caps[0] == 8 and caps[-1] == 2


def test_width_cap_hypothesis_monotone_grids_saturate():
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")

    @hyp.given(st.lists(st.floats(0.01, 0.5), min_size=5, max_size=5),
               st.floats(0.5, 2.0))
    @hyp.settings(max_examples=30, deadline=None)
    def prop(drops, start):
        pm, cur = {1: start}, start
        for w, d in zip((2, 3, 4, 6, 8), drops):
            cur = cur * (1.0 - 0.1 - 0.8 * d / 0.5 * 0.1)  # strictly down
            pm[w] = cur
        pol = adaptive(synthetic_perf(pm))
        assert pol.decode_width_cap("dec", "xpu", tau=None) == 8

    prop()


# --- coalesce cap / window ----------------------------------------------------

def test_coalesce_cap_is_per_item_knee():
    per_item = {1: 1.0, 8: 0.4, 16: 0.25, 32: 0.2, 64: 0.3, 128: 0.5}
    pol = adaptive(synthetic_perf({1: 1.0, 2: 0.5}, per_item=per_item))
    assert pol.coalesce_cap("dec") == 32
    assert pol.coalesce_cap("dec", "xpu") == 32


def test_coalesce_window_monotone_and_bounded():
    per_item = {1: 1.0, 8: 0.4, 16: 0.25, 32: 0.2, 64: 0.3}
    pol = adaptive(synthetic_perf({1: 1.0, 2: 0.5}, per_item=per_item))
    cap = pol.coalesce_cap("dec")
    # no arrival history: service-bound, ladder top
    assert pol.coalesce_window("dec", None) == cap * pol.WINDOW_MAX_PASSES
    windows = [pol.coalesce_window("dec", tau)
               for tau in (1e-6, 1.0, 10.0, 1e6)]
    assert windows == sorted(windows, reverse=True)
    for w in windows:
        assert cap <= w <= cap * pol.WINDOW_MAX_PASSES
    assert windows[0] == cap * pol.WINDOW_MAX_PASSES   # saturation opens up
    assert windows[-1] == cap                          # sparse: one pass


def test_dispatch_overhead_recovers_linear_intercept():
    o, c = 0.05, 0.01
    per_item = {n: (o + c * n) / n for n in (1, 2, 4, 8, 16)}
    pol = adaptive(synthetic_perf({1: 1.0, 2: 0.5}, per_item=per_item))
    assert pol.perf.dispatch_overhead("dec", "xpu") == pytest.approx(o)


def test_fixed_policy_returns_config_constants():
    perf = synthetic_perf({1: 1.0, 2: 0.5})
    cfg = SchedulerConfig(coalesce_cap=99, coalesce_window=123,
                          decode_batch_cap=7)
    pol = make_policy(cfg, perf)
    assert isinstance(pol, FixedBatchPolicy) and pol.name == "fixed"
    assert pol.decode_width_cap("dec", None, tau=0.1) == 7
    assert pol.coalesce_cap("dec") == 99
    assert pol.coalesce_window("dec", 0.1) == 123
    with pytest.raises(KeyError):
        make_policy(SchedulerConfig(batch_policy="nope"), perf)


# --- arrival EWMA -------------------------------------------------------------

def test_arrival_tracker_ewma():
    tr = ArrivalTracker(alpha=0.5)
    key = ("chat_decode", "stream_decode")
    assert tr.tau(key) is None
    tr.observe(key, 1.0)
    assert tr.tau(key) is None          # one arrival: no gap yet
    tr.observe(key, 3.0)
    assert tr.tau(key) == pytest.approx(2.0)
    tr.observe(key, 4.0)                # gap 1.0 -> ewma 1.5
    assert tr.tau(key) == pytest.approx(1.5)
    assert tr.tau(("other", "stream_decode")) is None
    # singleton arrivals: both estimates coincide (the PR 4 estimator)
    assert tr.tau_event(key) == pytest.approx(1.5)


def test_arrival_tracker_burst_dealiasing():
    """A W2 rewriter releasing 4 sub-queries at once is ONE arrival
    event of batch size 4: the per-member estimate converges to gap/4
    instead of aliasing the burst as a single arrival."""
    tr = ArrivalTracker(alpha=0.5)
    key = ("refine_decode", "stream_decode")
    t = 0.0
    for _ in range(12):                 # bursts of 4 every 10 s
        for _ in range(4):
            tr.observe(key, t)
        t += 10.0
    assert tr.tau(key) == pytest.approx(10.0 / 4, rel=0.05)
    # the event estimate keeps the raw view the coalesce window needs:
    # zero gaps inside the burst pull it far below the 10 s event gap
    assert tr.tau_event(key) < 10.0


def test_arrival_tracker_interleaved_reentries_keep_ratio_sane():
    """A boundary re-entry landing between a fresh burst and its closing
    gap flushes the burst early — but tau is a RATIO of marginal EWMAs
    (mean gap / mean batch), which pairing cannot bias: with 4-member
    bursts every 10 s plus one re-entry 0.5 s after each, the true
    per-member inter-arrival is 10/5 = 2 s and the estimate stays on
    that order instead of collapsing toward the re-entry gap."""
    tr = ArrivalTracker(alpha=0.3)
    key = ("chat_decode", "stream_decode")
    t = 0.0
    for _ in range(30):
        for _ in range(4):
            tr.observe(key, t)
        tr.observe(key, t + 0.5, fresh=False)
        t += 10.0
    assert 1.0 < tr.tau(key) < 3.0
    # and the event estimate still reflects raw observation gaps
    assert tr.tau_event(key) < tr.tau(key) * 4


def test_arrival_tracker_reentries_stay_individual():
    """Decode residents re-entering at a boundary (fresh=False) keep the
    PR 4 semantics bit-for-bit: zero-gap observations, no burst batch."""
    new = ArrivalTracker(alpha=0.3)
    key = ("chat_decode", "stream_decode")
    times = [0.0, 2.0, 2.0, 2.0, 5.0, 5.0, 9.0]
    legacy_tau = None
    last = None
    for t in times:
        new.observe(key, t, fresh=False)
        if last is not None:
            gap = max(t - last, 0.0)
            legacy_tau = (gap if legacy_tau is None
                          else 0.7 * legacy_tau + 0.3 * gap)
        last = t
    assert new.tau(key) == pytest.approx(legacy_tau)
    assert new.tau_event(key) == pytest.approx(legacy_tau)


# --- per-round group selection (horizon policy) -------------------------------

def _round_node(remainders, stage="chat_decode"):
    members = [Node(f"q{i}/d", stage, "stream_decode", r)
               for i, r in enumerate(remainders)]
    return Node("dround:x", stage, "stream_decode", max(remainders),
                payload={"members": members, "decode_round": True,
                         "decode_width": len(members)})


def test_round_group_candidates_align_to_remainders():
    _soc, _gt, perf = make_world("sd8gen4", "qwen3")
    pol = AdaptiveBatchPolicy(SchedulerConfig(batch_policy="adaptive"), perf)
    node = _round_node([5, 40, 80])
    cands = pol.round_group_candidates(node)
    grid = perf.decode_group_grid("chat_decode",
                                  pol._anchor_pu("chat_decode"))
    # the shortest member's remainder anchors a candidate at (or below)
    # its grid floor, so it can leave at the next boundary unpadded
    assert min(cands) <= 5
    assert all(g in grid or g <= 5 for g in cands)
    assert cands == sorted(cands)


def test_round_passes_mean_completion_vs_fixed_horizon():
    node = _round_node([4, 16, 64])
    fixed = FixedBatchPolicy(SchedulerConfig(), None)
    ada = AdaptiveBatchPolicy.__new__(AdaptiveBatchPolicy)  # no perf needed
    ada.cfg = SchedulerConfig()
    assert fixed.round_passes(node, 16) == ceil_passes(64, 16) == 4
    # mean over member remainders: (1 + 1 + 4) / 3
    assert AdaptiveBatchPolicy.round_passes(ada, node, 16) \
        == pytest.approx(2.0)


def test_round_passes_quantile_scores_the_tail():
    """round_score="quantile": the p99-aware variant charges a high
    quantile of member completion — the slowest member at small widths —
    instead of the mean an early leaver can hide behind."""
    node = _round_node([4, 16, 64])
    ada = AdaptiveBatchPolicy.__new__(AdaptiveBatchPolicy)
    ada.cfg = SchedulerConfig(round_score="quantile")
    assert AdaptiveBatchPolicy.round_passes(ada, node, 16) == 4.0
    ada.cfg = SchedulerConfig(round_score="mean")
    assert AdaptiveBatchPolicy.round_passes(ada, node, 16) \
        == pytest.approx(2.0)
    with pytest.raises(KeyError):
        make_policy(SchedulerConfig(round_score="p42"),
                    synthetic_perf({1: 1.0, 2: 0.5}))


def test_dispatch_passes_round_serves_one_group():
    """The straggler-ETA fix: a decode round's dispatch serves exactly one
    token group, so its predicted drain is one pass even when the node
    still carries the residents' horizon (or a stale trim)."""
    node = _round_node([200, 120])
    node.workload = 200
    assert dispatch_passes(node, 16) == 1
    solo = Node("q0/d", "chat_decode", "stream_decode", 200)
    assert dispatch_passes(solo, 16) == ceil_passes(200, 16) == 13


# --- goldens: fixed policy is bit-identical to PR 2 / PR 3 --------------------

@pytest.fixture(scope="module")
def traces():
    return sample_traces("hotpotqa", 8, seed=11)


@pytest.fixture(scope="module")
def means(traces):
    return default_means(traces)


def test_fixed_policy_reproduces_pr2_coalesce_off_goldens(traces, means):
    with open(os.path.join(GOLDEN_DIR, "pr2_coalesce_off.json")) as f:
        golden = json.load(f)
    sess = HeroSession(world="sd8gen4", family="qwen3", means=means,
                       coalesce=False, batch_policy="fixed")
    for qi, tr in enumerate(traces):
        sess.submit(tr, wf=1, arrival_time=qi * 0.25)
    got = [r.makespan for r in sess.run()]
    assert got == pytest.approx(golden["staggered8_w1_makespans"], rel=1e-12)


@pytest.mark.parametrize("regime,ia", [("saturated", 0.25),
                                       ("staggered", 2.0)])
def test_fixed_policy_reproduces_pr3_decode_goldens(traces, means, regime,
                                                    ia):
    """The PR 3 continuous-decode-batching behavior, captured before the
    adaptive policy landed: batch_policy="fixed" must reproduce it
    bit-exactly (every adaptive code path dormant)."""
    with open(os.path.join(GOLDEN_DIR, "pr3_decode_batch.json")) as f:
        golden = json.load(f)
    sess = HeroSession(world="sd8gen4", family="qwen3", means=means,
                       coalesce=True, batch_policy="fixed")
    for qi, tr in enumerate(traces):
        sess.submit(tr, wf=1, arrival_time=qi * ia)
    got = [r.makespan for r in sess.run()]
    assert got == pytest.approx(golden[f"{regime}8_w1_decode_makespans"],
                                rel=1e-12)


# --- end-to-end: mixed W1-W3 --------------------------------------------------

def _mixed_session(traces, means, backend="sim", **kw):
    sess = HeroSession(world="sd8gen4", family="qwen3", means=means,
                       coalesce=True, batch_policy="adaptive",
                       backend=backend, **kw)
    for qi, tr in enumerate(traces):
        sess.submit(tr, wf=(1, 2, 3)[qi % 3], arrival_time=qi * 0.5)
    return sess


@pytest.mark.slow
def test_sim_live_parity_8_mixed_w1_w3(means):
    """The ISSUE's parity bar: 8 mixed W1-W3 queries under the adaptive
    policy produce the same per-query stage sets on both substrates, with
    continuous decode batching active on both."""
    import time as _time
    traces8 = sample_traces("hotpotqa", 8, seed=11)
    by = {}
    for backend in ("sim", "live"):
        kw = {}
        if backend == "live":
            kw["stage_fns"] = {"chat_decode":
                               lambda n, b: _time.sleep(0.02)}
        sess = _mixed_session(traces8, means, backend=backend, **kw)
        for h in sess.queries:
            h.arrival_time = h.qid * 0.05   # wall-clock friendly stagger
        by[backend] = sess.run(timeout=180)
    for s, live in zip(by["sim"], by["live"]):
        assert s.qid == live.qid and s.workflow == live.workflow
        assert set(s.stage_latency) == set(live.stage_latency)
        assert s.makespan > 0 and live.makespan > 0
    assert sum(r.decode_rounds for r in by["sim"]) > 0
    assert sum(r.decode_rounds for r in by["live"]) > 0


def test_adaptive_beats_fixed_caps_on_mixed(means):
    """The acceptance bar the CI ablation leg enforces, in-tree: on the
    mixed W1-W3 regime the adaptive policy's p99 beats the fixed caps."""
    traces9 = sample_traces("hotpotqa", 9, seed=11)
    out = {}
    for pol in ("fixed", "adaptive"):
        sess = HeroSession(world="sd8gen4", family="qwen3", means=means,
                           coalesce=True, batch_policy=pol)
        for qi, tr in enumerate(traces9):
            sess.submit(tr, wf=(1, 2, 3)[qi % 3], arrival_time=qi * 0.5)
        res = sess.run(timeout=7200)
        out[pol] = float(np.percentile([r.makespan for r in res], 99))
    assert out["adaptive"] < out["fixed"]


def test_adaptive_deterministic(means):
    traces6 = sample_traces("hotpotqa", 6, seed=11)

    def once():
        sess = _mixed_session(traces6, means)
        return [r.makespan for r in sess.run(timeout=7200)]

    assert once() == once()


def test_session_reports_chosen_shapes(traces, means):
    sess = HeroSession(world="sd8gen4", family="qwen3", means=means,
                       coalesce=True, batch_policy="adaptive")
    for qi, tr in enumerate(traces):
        sess.submit(tr, wf=1, arrival_time=qi * 0.25)
    sess.run()
    b = sess.last_run.batching
    assert sum(b["decode_width"].values()) > 0
    assert sum(b["decode_group"].values()) > 0
    assert all(w >= 2 for w in b["decode_width"])


def test_builtin_spec_accepts_names():
    assert builtin_spec("w2").name == builtin_spec(2).name == "w2"
    assert builtin_spec("W3").name == "w3"
