import jax
import pytest

# Tests run on the single real CPU device — the 512-device override is
# strictly dryrun.py-local (per the harness contract).
jax.config.update("jax_enable_x64", False)


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)
