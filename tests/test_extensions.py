"""Coverage for the beyond-paper extensions: chunked attention, multi-query
DAG namespacing, Eq.3 optimality property, template priors, vector-db
ordering property.

Requires ``hypothesis`` (CI installs it); skips cleanly where it is absent.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.dag import DynamicDAG, WorkflowTemplate
from repro.core.partitioner import DEFAULT_BATCH_CANDIDATES, best_batch
from repro.models.layers import mha, mha_chunked
from repro.rag import sample_traces
from repro.rag.workflow import build_w3


@pytest.mark.parametrize("sq,sk,h,n,blk", [
    (64, 64, 8, 4, 16),
    (48, 96, 4, 4, 32),     # non-multiple of block
    (128, 128, 8, 2, 128),
])
@pytest.mark.parametrize("causal", [True, False])
def test_mha_chunked_matches_reference(sq, sk, h, n, blk, causal):
    if causal and sq != sk:
        pytest.skip("positions align only for sq == sk here")
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (2, sq, h, 64))
    k = jax.random.normal(jax.random.fold_in(key, 1), (2, sk, n, 64))
    v = jax.random.normal(jax.random.fold_in(key, 2), (2, sk, n, 64))
    out = mha_chunked(q, k, v, causal=causal, block=blk)
    ref = mha(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


def test_multiquery_namespacing_isolated_expansion():
    tr = sample_traces("2wikimqa", 1, seed=2)[0]
    dag = DynamicDAG()
    build_w3(tr, True, prefix="q0/", dag=dag)
    build_w3(tr, True, prefix="q1/", dag=dag)
    for nid in ["q0/embed_chunks", "q0/embed_query", "q0/rewrite_prefill"]:
        dag.nodes[nid].status = "done"
    dag.mark_done("q0/rewrite_decode", 1.0)
    assert any(x.startswith("q0/vsearch_sq") for x in dag.nodes)
    assert not any(x.startswith("q1/vsearch_sq") for x in dag.nodes)
    # stage names stay un-namespaced (perf-model keys)
    assert all("/" not in node.stage for node in dag.nodes.values())


@settings(max_examples=40, deadline=None)
@given(L=st.integers(1, 400))
def test_eq3_never_worse_than_any_candidate(L):
    """best_batch minimizes ceil(L/n)*p0(n) over the candidate set."""
    from repro.core import GroundTruthPerf, LinearPerfModel, StageModel, \
        snapdragon_8gen4
    soc = snapdragon_8gen4()
    stages = {"embed": StageModel("embed", int(6e8), 1024, "batchable")}
    perf = LinearPerfModel().fit(GroundTruthPerf(soc, stages))
    n_star, t_star = best_batch(perf, "embed", "npu", L)
    for n in DEFAULT_BATCH_CANDIDATES:
        nn = min(n, L)
        t = -(-L // nn) * perf.p0("embed", "npu", nn)
        assert t_star <= t + 1e-9


def test_template_prior_ema_update():
    t = WorkflowTemplate()
    t.add_stage("web", "web", "io", 1.0, prob=0.5)
    for _ in range(20):
        t.update_history("web", activated=True, workload=3.0)
    assert t.stages["web"].prob > 0.85
    assert 1.0 < t.stages["web"].mean_workload <= 3.0
    for _ in range(40):
        t.update_history("web", activated=False)
    assert t.stages["web"].prob < 0.15


@settings(max_examples=15, deadline=None)
@given(n=st.integers(10, 200), k=st.integers(1, 8), seed=st.integers(0, 99))
def test_vectordb_scores_sorted_and_valid(n, k, seed):
    from repro.rag import VectorDB
    rng = np.random.default_rng(seed)
    vecs = rng.normal(size=(n, 16)).astype(np.float32)
    db = VectorDB(dim=16, capacity=1024)
    db.add(jnp.asarray(vecs))
    vals, ids = db.search(jnp.asarray(vecs[:2]), k=min(k, n))
    assert (np.diff(vals, axis=1) <= 1e-5).all()     # descending scores
    assert (ids >= 0).all() and (ids < n).all()


def test_multiquery_benchmark_smoke():
    from benchmarks.multiquery import run
    seq, par = run(csv=lambda *_: None, k=2, wf=1)
    assert seq > 0 and par > 0


def test_grid_search_smoke():
    from benchmarks.grid_search import ALPHAS, BETAS
    assert 0.35 in ALPHAS and 0.6 in BETAS   # deployed defaults in the grid


def test_perf_model_save_load_roundtrip(tmp_path):
    from repro.core import (GroundTruthPerf, LinearPerfModel, StageModel,
                            snapdragon_8gen4)
    soc = snapdragon_8gen4()
    stages = {"embed": StageModel("embed", int(6e8), 1024, "batchable")}
    perf = LinearPerfModel().fit(GroundTruthPerf(soc, stages))
    p = str(tmp_path / "profile.json")
    perf.save(p)
    loaded = LinearPerfModel.load(p)
    for n in (1, 22, 64, 256):
        assert loaded.p0("embed", "npu", n) == pytest.approx(
            perf.p0("embed", "npu", n))
        assert loaded.bandwidth("embed", "npu", n) == pytest.approx(
            perf.bandwidth("embed", "npu", n))
    assert loaded.phi("embed", 0.8 * soc.dram_bw) == pytest.approx(
        perf.phi("embed", 0.8 * soc.dram_bw))
