"""Per-architecture smoke tests: reduced config, one forward + one train
step on CPU, asserting output shapes and finiteness; prefill+decode
consistency against the full-sequence forward."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, list_archs, reduced
from repro.models import build_model

# the big-architecture reduced configs still cost 5-25 s each to trace and
# compile on CPU; they run in CI's parallel slow job
SLOW_ARCHS = {"deepseek-v3-671b", "deepseek-v2-236b", "llama-3.2-vision-90b",
              "mistral-large-123b", "zamba2-1.2b"}
ARCHS = [pytest.param(a, marks=pytest.mark.slow) if a in SLOW_ARCHS else a
         for a in list_archs()]


def _batch(cfg, B, S, key):
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}
    batch["labels"] = batch["tokens"]
    if cfg.vlm.enabled:
        batch["vision_embeds"] = jax.random.normal(
            key, (B, cfg.vlm.vision_tokens, cfg.vlm.vision_dim))
    if cfg.encdec.enabled:
        batch["audio_frames"] = jax.random.normal(
            key, (B, cfg.encdec.source_positions, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_train_step(arch, rng):
    cfg = reduced(get_config(arch))
    model = build_model(cfg)
    params = model.init(rng)
    B, S = 2, 32
    batch = _batch(cfg, B, S, jax.random.fold_in(rng, 1))
    logits, aux, _ = model.apply(params, batch, mode="train")
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())
    (loss, metrics), grads = jax.value_and_grad(
        model.loss_fn, has_aux=True)(params, batch)
    assert bool(jnp.isfinite(loss))
    assert all(bool(jnp.isfinite(g).all()) for g in jax.tree.leaves(grads))


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_matches_forward(arch, rng):
    cfg = reduced(get_config(arch))
    model = build_model(cfg)
    params = model.init(rng)
    B, S, P = 2, 40, 32
    batch = _batch(cfg, B, S, jax.random.fold_in(rng, 2))
    toks = batch["tokens"]
    extras = {k: v for k, v in batch.items()
              if k not in ("tokens", "labels")}
    full, _, _ = model.apply(params, batch, mode="train")
    cache = model.init_cache(B, S)
    pre, cache = model.prefill(params, {"tokens": toks[:, :P], **extras},
                               cache)
    assert float(jnp.abs(pre[:, P - 1] - full[:, P - 1]).max()) < 1e-3
    errs = []
    for t in range(P, S):
        lg, cache = model.decode_step(params, toks[:, t:t + 1], cache)
        errs.append(float(jnp.abs(lg - full[:, t]).max()))
    assert max(errs) < 1e-3, f"decode divergence {max(errs)}"


def test_param_counts_match_published():
    expected = {
        "deepseek-v3-671b": 671e9, "deepseek-v2-236b": 236e9,
        "zamba2-1.2b": 1.2e9, "qwen1.5-0.5b": 0.62e9,
        "granite-3-2b": 2.5e9, "codeqwen1.5-7b": 7.25e9,
        "mistral-large-123b": 123e9, "llama-3.2-vision-90b": 90e9,
        "xlstm-350m": 0.35e9, "whisper-large-v3": 1.54e9,
    }
    for arch, want in expected.items():
        got = get_config(arch).param_count()
        assert abs(got - want) / want < 0.20, (arch, got, want)


def test_moe_active_params():
    cfg = get_config("deepseek-v3-671b")
    assert abs(cfg.active_param_count() - 37e9) / 37e9 < 0.1


def test_zamba2_windowed_long_context_cache():
    """Ring cache keeps memory bounded at 500k context."""
    from repro.models import lm
    cfg = reduced(get_config("zamba2-1.2b"))
    # force the long-context window path
    cache = lm.init_cache(cfg, 1, 40000)
    assert "pos" in cache["attn"]
    assert cache["attn"]["k"].shape[2] == 4096   # window, not 40000
