"""Predictive tier prefetch (PR 7) + the three accounting bugfixes.

Tentpole coverage: speculative staging of spill-resident pages under a
compute-overlap credit (``PagedKVCache.prefetch``) — budget debiting,
headroom clipping, pin safety (prefetch never demotes a pinned page and
never soft-overflows an arena), prefetch-hit accounting at the dispatch
gather, hit-frequency-weighted eviction, and the scheduler/session
gates.  Bugfix regressions: the hit-or-recompute rule declining
fetch-dominated prefix hits, soft overflows counted + recovered at
release, and the ``kv_stage`` convention trap (unit + spec level).
Plus sim/live accounting-protocol parity and a hypothesis
bytes-conservation property on a single-PU tier stack.
"""
import warnings

import pytest

from repro.api import HeroSession
from repro.api.spec import DecodeSpec, StageSpec, WorkflowSpec
from repro.core import SchedulerConfig
from repro.core.dag import Node
from repro.core.kv_pages import (DISK, DRAM, PagedKVCache, decode_stage_for)
from repro.core.scheduler import HeroScheduler
from repro.rag import shared_corpus_traces
from test_kv_pages import (STAGE, check_invariants, decode_node, paged_perf,
                           prefill_node, round_node)


def warm_pages(kv, key, tokens, pu="gpu", nid="w/p"):
    """Seed unpinned (refs == 0) hashed prefix pages and return their pids
    in prefix order."""
    before = set(kv._pages)
    kv.on_prefill_done(prefill_node(nid, [(key, tokens)]), pu)
    return sorted(set(kv._pages) - before)


# --- speculative staging -----------------------------------------------------

def test_prefetch_stages_spill_group_under_credit():
    kv = PagedKVCache(paged_perf(), page_tokens=4, prefetch=True)
    pids = warm_pages(kv, "ctx:a", 8)
    for pid in pids:
        kv._place(kv._pages[pid], DRAM)       # demoted between reuses
    n = decode_node("q0/d", ctx=0)
    spent = kv.prefetch(n, "gpu", 1.0, pids=pids)
    # fitted line: 8 tokens at 2e-3 s/tok, fully inside the budget
    assert spent == pytest.approx(8 * 2e-3)
    assert all(kv._pages[pid].tier == "gpu" for pid in pids)
    assert all(pid in kv._prefetched for pid in pids)
    assert kv.prefetches == 1 and kv.prefetch_bytes == 8.0
    assert n.payload["kv_prefetches"] == 1
    assert n.payload["kv_prefetch_bytes"] == 8.0
    assert [e for e, _n in kv.drain_events()] == ["kv_prefetch"]
    # the backend contract: one (stage, src, dst, tokens, credit) group
    assert kv.drain_prefetches() == [
        (STAGE, DRAM, "gpu", 8, pytest.approx(8 * 2e-3))]
    check_invariants(kv)


def test_prefetch_gates_off_and_zero_budget():
    n = decode_node("q0/d", ctx=0)
    for flag, budget in ((False, 1.0), (True, 0.0)):
        kv = PagedKVCache(paged_perf(), page_tokens=4, prefetch=flag)
        pids = warm_pages(kv, "ctx:a", 8)
        for pid in pids:
            kv._place(kv._pages[pid], DRAM)
        assert kv.prefetch(n, "gpu", budget, pids=pids) == 0.0
        assert kv.prefetches == 0 and not kv._prefetched
        assert all(kv._pages[pid].tier == DRAM for pid in pids)
        assert kv.drain_prefetches() == []


def test_prefetch_skips_resident_and_already_staged_pages():
    kv = PagedKVCache(paged_perf(), page_tokens=4, prefetch=True)
    pids = warm_pages(kv, "ctx:a", 8)
    kv._place(kv._pages[pids[1]], DRAM)       # only page 1 is in spill
    n = decode_node("q0/d", ctx=0)
    spent = kv.prefetch(n, "gpu", 1.0, pids=pids)
    assert spent == pytest.approx(4 * 2e-3)   # PU-resident page 0 is free
    assert kv.prefetches == 1 and kv.prefetch_bytes == 4.0
    # idempotent: the staged page is skipped until a gather consumes it
    assert kv.prefetch(n, "gpu", 1.0, pids=pids) == 0.0
    assert kv.prefetches == 1


def test_prefetch_budget_caps_credit_and_group_order():
    kv = PagedKVCache(paged_perf(), page_tokens=4, prefetch=True)
    pids = warm_pages(kv, "ctx:a", 8)
    kv._place(kv._pages[pids[0]], DISK)
    kv._place(kv._pages[pids[1]], DRAM)
    n = decode_node("q0/d", ctx=0)
    # budget covers exactly the disk group (sorted first): the staging
    # still completes, its credit is clipped to the window, and the dram
    # group waits for the next pass — the serial transfer-queue model
    spent = kv.prefetch(n, "gpu", 8 * 1e-3, pids=pids)
    assert spent == pytest.approx(8 * 1e-3)
    assert kv.prefetches == 1
    assert kv._pages[pids[0]].tier == "gpu"
    assert kv._pages[pids[1]].tier == DRAM
    assert kv.drain_prefetches() == [
        (STAGE, DISK, "gpu", 4, pytest.approx(8 * 1e-3))]


def test_prefetch_clips_group_to_headroom():
    # gpu arena: 12 B; a live stream pins 8 B, so headroom is 4 B — the
    # 3-page (12 B) spill group is clipped to its first page, the tail
    # left for the on-path gather (not skipped, not forced)
    kv = PagedKVCache(paged_perf(caps={"gpu": 12.0}), page_tokens=4,
                      prefetch=True)
    d = decode_node("q0/d", ctx=8, workload=1 << 20)
    kv.migrate_for_dispatch(round_node([d]), "gpu")
    pids = warm_pages(kv, "ctx:a", 12, pu=DRAM)
    n = decode_node("q1/d", ctx=0)
    spent = kv.prefetch(n, "gpu", 1.0, pids=pids)
    assert spent == pytest.approx(4 * 2e-3)
    assert kv._pages[pids[0]].tier == "gpu"
    assert [kv._pages[p].tier for p in pids[1:]] == [DRAM, DRAM]
    assert kv.prefetch_bytes == 4.0
    assert kv.soft_overflows == 0 and kv.evictions == 0
    check_invariants(kv)


def test_prefetch_never_demotes_pinned_pages_or_overflows():
    # arena exactly full of pinned stream pages: zero headroom, so the
    # staging is a no-op — prefetch must never evict a live stream's
    # pages or soft-overflow an arena to make room for speculation
    kv = PagedKVCache(paged_perf(caps={"gpu": 8.0}), page_tokens=4,
                      prefetch=True)
    d = decode_node("q0/d", ctx=8, workload=1 << 20)
    kv.migrate_for_dispatch(round_node([d]), "gpu")
    stream_pages = list(kv.tracked(d).pages)
    pids = warm_pages(kv, "ctx:a", 4, pu=DRAM)
    n = decode_node("q1/d", ctx=0)
    assert kv.prefetch(n, "gpu", 1.0, pids=pids) == 0.0
    assert kv.prefetches == 0 and kv.soft_overflows == 0
    assert kv._pages[pids[0]].tier == DRAM
    assert all(kv._pages[p].tier == "gpu" for p in stream_pages)
    check_invariants(kv)


def test_prefetch_hit_and_thrash_accounting_at_gather():
    kv = PagedKVCache(paged_perf(), page_tokens=4, prefetch=True)
    pids = warm_pages(kv, "ctx:a", 8)
    for pid in pids:
        kv._place(kv._pages[pid], DISK)
    # a new query hits the disk-resident prefix, the scheduler stages it
    hit = prefill_node("q1/p", [("ctx:a", 8), ("q:q1", 4)], stream="q1/d")
    kv.apply_prefix_hits(hit)
    assert hit.payload["kv_page_hits"] == 2
    kv.prefetch(hit, "gpu", 1.0, pids=hit.payload["kv_hit_pages"])
    kv.on_prefill_done(hit, "gpu")
    d = decode_node("q1/d", ctx=12, workload=1 << 20)
    d.group = "q1/d"
    moved = kv.migrate_for_dispatch(round_node([d]), "gpu")
    # the gather finds the staged pages resident: prefetch hits, and no
    # on-path fetch is paid for them
    assert moved == []
    assert kv.prefetch_hits == 2 and kv.fetches == 0
    assert d.payload["kv_prefetch_hits"] == 2
    assert not kv._prefetched                 # consumed, not re-counted
    check_invariants(kv)


def test_prefetch_staged_to_wrong_pu_is_thrash_not_hit():
    kv = PagedKVCache(paged_perf(), page_tokens=4, prefetch=True)
    pids = warm_pages(kv, "ctx:a", 4)
    kv._place(kv._pages[pids[0]], DRAM)
    hit = prefill_node("q1/p", [("ctx:a", 4), ("q:q1", 4)], stream="q1/d")
    kv.apply_prefix_hits(hit)
    kv.prefetch(hit, "cpu", 1.0, pids=hit.payload["kv_hit_pages"])
    kv.on_prefill_done(hit, "cpu")
    d = decode_node("q1/d", ctx=8, workload=1 << 20)
    d.group = "q1/d"
    # the decode lands elsewhere: the staged page is NOT a hit — it pays
    # the PU->PU gather like any other misplaced page
    kv.migrate_for_dispatch(round_node([d]), "gpu")
    assert kv.prefetch_hits == 0
    assert kv.migrations == 1
    assert not kv._prefetched
    assert all(kv._pages[p].tier == "gpu" for p in kv.tracked(d).pages)


def test_hit_frequency_eviction_prefers_cold_pages():
    def build(prefetch):
        kv = PagedKVCache(paged_perf(caps={"gpu": 8.0}), page_tokens=4,
                          prefetch=prefetch)
        [a] = warm_pages(kv, "ctx:hot", 4, nid="w0/p")
        hot = prefill_node("h/p", [("ctx:hot", 4), ("q:h", 4)])
        kv.apply_prefix_hits(hot)             # the hot page earns hits
        kv.on_prefill_done(hot, "gpu")
        [b] = warm_pages(kv, "ctx:cold", 4, nid="w1/p")
        assert kv._pages[a].hits > 0 and kv._pages[b].hits == 0
        assert kv._pages[a].last_use < kv._pages[b].last_use
        d = decode_node("q0/d", ctx=4, workload=1 << 20)
        kv.migrate_for_dispatch(round_node([d]), "gpu")  # needs 4 B
        return kv, a, b

    # prefetch on: the cold page demotes even though it is more recent
    kv, a, b = build(True)
    assert kv._pages[b].tier == DRAM and kv._pages[a].tier == "gpu"
    # prefetch off: plain LRU (the PR 6 behaviour) demotes the older page
    kv, a, b = build(False)
    assert kv._pages[a].tier == DRAM and kv._pages[b].tier == "gpu"


# --- bugfix regressions ------------------------------------------------------

def recompute_perf():
    """Profile where re-prefilling is cheap and disk fetches are ruinous:
    a handcrafted prefill grid (table-first, so the exact queried token
    counts must be present) plus 1 s/token disk fetch lines."""
    m = paged_perf()
    for p in ("cpu", "gpu", "npu"):
        m.fetch_coef[(STAGE, DISK, p)] = (0.0, 1.0)
    m.table[("chat_prefill", "gpu")] = {64: (0.01, 0.0), 128: (0.02, 0.0)}
    m.coef[("chat_prefill", "gpu")] = None    # key presence only
    return m


def test_hit_or_recompute_declines_fetch_dominated_hits():
    """Bugfix: a disk-resident 'hit' whose fetch costs more than the
    prefill it skips is declined, not blindly taken."""
    kv = PagedKVCache(recompute_perf(), page_tokens=64)
    segs = [("ctx:a", 128)]
    pids = warm_pages(kv, "ctx:a", 128)
    for pid in pids:
        kv._place(kv._pages[pid], DISK)
    n = prefill_node("q1/p", segs)
    kv.apply_prefix_hits(n)
    assert n.workload == 128                  # nothing trimmed
    assert "kv_page_hits" not in n.payload
    assert n.payload["kv_hit_declined"] == 2
    assert kv.hit_declined == 2 and kv.hits == 0
    assert "kv_hit_declined" in [e for e, _n in kv.drain_events()]
    assert all(kv._pages[pid].refs == 0 for pid in pids)  # not pinned


def test_hit_or_recompute_keeps_the_profitable_prefix():
    # page 0 stays PU-resident (free to hit); page 1 is on disk and
    # costs 64 s to fetch vs 0.02 s to recompute — keep 1, decline 1
    kv = PagedKVCache(recompute_perf(), page_tokens=64)
    pids = warm_pages(kv, "ctx:a", 128)
    kv._place(kv._pages[pids[1]], DISK)
    n = prefill_node("q1/p", [("ctx:a", 128)])
    kv.apply_prefix_hits(n)
    assert n.payload["kv_page_hits"] == 1
    assert n.payload["kv_hit_tokens"] == 64
    assert n.workload == 64
    assert n.payload["kv_hit_declined"] == 1
    assert kv.hits == 1 and kv.hit_declined == 1


def test_soft_overflow_counted_and_recovered_on_release():
    """Bugfix: an all-pinned arena breach is counted and emitted (not
    silent), and release demotes the excess so every tier returns under
    capacity once the pins drop."""
    kv = PagedKVCache(paged_perf(caps={"gpu": 8.0}), page_tokens=4)
    p = prefill_node("q0/p", [("ctx:a", 16)], stream="q0/d")
    kv.on_prefill_done(p, "gpu")              # 16 B pinned into an 8 B arena
    assert kv.resident_bytes("gpu") == 16.0
    assert kv.soft_overflows == 2             # pages 3 and 4 each breached
    assert kv.evictions == 0                  # the stream was never touched
    events = [e for e, _n in kv.drain_events()]
    assert events.count("kv_soft_overflow") == 2
    d = decode_node("q0/d", ctx=16)
    d.group = "q0/d"
    kv.release(d)
    # hashed pages survive at refs == 0, but the overflow excess demotes
    assert kv.resident_bytes("gpu") <= 8.0
    assert kv.resident_bytes(DRAM) == 8.0
    assert kv.evictions == 2
    assert "kv_evict" in [e for e, _n in kv.drain_events()]
    check_invariants(kv)


def test_kv_stage_override_and_convention_trap_warns_once():
    """Bugfix: stages that do not follow the *_prefill naming convention
    are warned-and-skipped (once per stage pair) instead of silently
    paged under a guessed decode shape; the explicit override re-enables
    reuse under the right profile."""
    n = decode_node("q0/d", ctx=0)
    assert decode_stage_for(n) == STAGE
    n.payload["kv_decode_stage"] = "other_decode"
    assert decode_stage_for(n) == "other_decode"

    kv = PagedKVCache(paged_perf(), page_tokens=64)

    def odd(nid, **extra):
        return Node(nid, "oddgen", "stream_prefill", 64,
                    payload={"prefix_segments": (("ctx:a", 64),), **extra})

    with pytest.warns(RuntimeWarning, match="kv_stage"):
        kv.apply_prefix_hits(odd("q0/g"))
    with warnings.catch_warnings():           # warn once, then silent
        warnings.simplefilter("error")
        kv.apply_prefix_hits(odd("q1/g"))
    # the override pages the cache under the profiled decode shape
    warm = odd("q2/g", kv_decode_stage=STAGE)
    kv.apply_prefix_hits(warm)                # cold
    kv.on_prefill_done(warm, "gpu")
    again = odd("q3/g", kv_decode_stage=STAGE)
    kv.apply_prefix_hits(again)
    assert again.payload["kv_page_hits"] == 1
    assert again.workload == 1


def test_spec_level_kv_stage_trap_and_override():
    trace = {"context_tokens": 64, "chunk_ids": (1, 2)}

    def mk(kv_stage):
        return WorkflowSpec("odd", statics=(
            StageSpec("gen_ctx", "oddgen", "stream_prefill",
                      lambda v: v.context_tokens,
                      shared_ctx=lambda v: v.context_tokens,
                      decode=(DecodeSpec(kv_stage=kv_stage)
                              if kv_stage else None)),
            StageSpec("gen", "oddgen_d", "stream_decode", lambda v: 8,
                      deps=("gen_ctx",)),
        ))

    with pytest.warns(RuntimeWarning, match="kv_stage"):
        dag = mk(None).build_dag(trace)
    assert "prefix_segments" not in dag.nodes["gen_ctx"].payload
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        dag = mk(STAGE).build_dag(trace)
    n = dag.nodes["gen_ctx"]
    assert n.payload["decode_spec"].kv_stage == STAGE
    assert decode_stage_for(n) == STAGE
    assert sum(t for _k, t in n.payload["prefix_segments"]) == n.workload


def test_stagespec_kv_stage_kwarg_deprecated_shim():
    """PR 9 shim: the legacy ``StageSpec(kv_stage=...)`` kwarg warns and
    folds into the typed ``decode=DecodeSpec(...)``; a conflicting pair
    still raises."""
    with pytest.warns(DeprecationWarning, match="kv_stage is deprecated"):
        s = StageSpec("gen_ctx", "oddgen", "stream_prefill",
                      lambda v: 64, kv_stage=STAGE)
    assert s.decode == DecodeSpec(kv_stage=STAGE)
    with pytest.warns(DeprecationWarning):
        s2 = StageSpec("gen_ctx", "oddgen", "stream_prefill",
                       lambda v: 64, kv_stage=STAGE,
                       decode=DecodeSpec(draft_width=2))
    assert s2.decode.kv_stage == STAGE
    assert s2.decode.draft_width == 2
    with pytest.warns(DeprecationWarning):
        with pytest.raises(ValueError, match="conflicts"):
            StageSpec("gen_ctx", "oddgen", "stream_prefill",
                      lambda v: 64, kv_stage=STAGE,
                      decode=DecodeSpec(kv_stage="other_decode"))


# --- gates + backend accounting protocol -------------------------------------

def test_scheduler_prefetch_gate_requires_pages():
    perf = paged_perf()
    on = HeroScheduler(perf, ["cpu", "gpu", "npu"], 1e9,
                       SchedulerConfig(kv_pages=True, kv_prefetch=True))
    assert on.kv.prefetch_on
    off = HeroScheduler(perf, ["cpu", "gpu", "npu"], 1e9,
                        SchedulerConfig(kv_pages=True))
    assert not off.kv.prefetch_on             # off = the PR 6 behaviour


def test_prefetch_off_counters_stay_zero_e2e():
    traces = shared_corpus_traces("hotpotqa", 4, seed=5)
    sess = HeroSession(world="sd8gen4", family="qwen3", strategy="hero",
                       coalesce=True, batch_policy="adaptive", kv_pages=True)
    for qi, tr in enumerate(traces):
        sess.submit(tr, wf=1, arrival_time=qi * 0.5)
    res = sess.run()
    run = sess.last_run
    assert run.kv_prefetches == 0 and run.kv_prefetch_hits == 0
    assert run.kv_prefetch_bytes == 0.0
    assert all(r.kv_prefetches == 0 for r in res)


@pytest.mark.parametrize("backend", ["sim", "live"])
def test_prefetch_counter_protocol_parity(backend):
    """Both backends drain the same prefetch queue (the simulator charges
    the overlap residual, the live runtime records) and surface the same
    counter protocol: run totals come from the shared tracker and the
    per-query payload attribution sums back to them exactly."""
    traces = shared_corpus_traces("hotpotqa", 3, seed=3)
    sess = HeroSession(world="sd8gen4", family="qwen3", strategy="hero",
                       coalesce=True, batch_policy="adaptive",
                       kv_pages=True, kv_prefetch=True, backend=backend)
    for qi, tr in enumerate(traces):
        sess.submit(tr, wf=1, arrival_time=qi * 0.5)
    res = sess.run(timeout=120)
    run = sess.last_run
    assert len(res) == 3 and all(r.makespan > 0 for r in res)
    assert run.kv_prefetches == sum(r.kv_prefetches for r in res)
    assert run.kv_prefetch_hits == sum(r.kv_prefetch_hits for r in res)
    assert run.kv_prefetch_bytes == pytest.approx(
        sum(r.kv_prefetch_bytes for r in res))
    assert run.kv_hit_declined == sum(r.kv_hit_declined for r in res)
    assert run.kv_page_hits > 0               # the shared corpus still hits


# --- hypothesis: bytes conservation ------------------------------------------

def test_prefetch_bytes_conservation_single_pu():
    """On a single-PU tier stack every spill->PU byte crossing is either
    a prefetch staging or an on-path fetch (no PU->PU moves exist), so
    ``prefetch_bytes + fetched_bytes`` must equal the bytes observed
    moving up — and speculation never soft-overflows the arena."""
    hyp = pytest.importorskip("hypothesis")
    st_ = pytest.importorskip("hypothesis.strategies")

    @hyp.given(st_.lists(st_.tuples(st_.integers(0, 3),     # op selector
                                    st_.integers(0, 7),     # page pick
                                    st_.floats(0.0, 1.0)),  # budget
                         min_size=1, max_size=40))
    @hyp.settings(max_examples=40, deadline=None)
    def prop(ops):
        kv = PagedKVCache(paged_perf(caps={"gpu": 48.0, "dram": 64.0},
                                     pus=("gpu",)),
                          page_tokens=8, prefetch=True)
        for i in range(3):
            warm_pages(kv, f"ctx:{i}", 16, nid=f"w{i}/p")
        d = decode_node("q0/d", ctx=16, workload=1 << 20)
        d.group = "q0/d"
        up = 0.0
        shadow = {pid: pg.tier for pid, pg in kv._pages.items()}

        def sync():
            nonlocal up
            for pid, pg in kv._pages.items():
                if shadow.get(pid) in (DRAM, DISK) and pg.tier == "gpu":
                    up += kv._page_bytes(pg)
            shadow.clear()
            shadow.update({pid: pg.tier for pid, pg in kv._pages.items()})

        for op, pick, budget in ops:
            if op == 0:        # demotion pressure (unpinned pages only)
                pids = sorted(pid for pid, pg in kv._pages.items()
                              if pg.refs <= 0 and pg.tier == "gpu")
                if pids:
                    kv._place(kv._pages[pids[pick % len(pids)]],
                              (DRAM, DISK)[pick % 2])
            elif op == 1:      # speculative staging
                before = kv.soft_overflows
                kv.prefetch(d, "gpu", budget, pids=sorted(kv._pages))
                assert kv.soft_overflows == before
            elif op == 2:      # on-path gather
                kv.migrate_for_dispatch(round_node([d]), "gpu")
            else:              # prefix reuse of a warmed corpus
                n = prefill_node(f"h{pick}/p", [(f"ctx:{pick % 3}", 16)])
                kv.apply_prefix_hits(n)
                kv.on_prefill_done(n, "gpu")
            sync()
            check_invariants(kv)
            assert kv.prefetch_bytes + kv.fetched_bytes \
                == pytest.approx(up)
        kv.release(d)
        check_invariants(kv)

    prop()
