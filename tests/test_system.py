"""End-to-end behaviour tests: paper-claim validation on the simulator and
the full executable RAG pipeline under the HeRo runtime."""
import dataclasses
import time

import jax
import numpy as np
import pytest

from repro.configs import get_family, reduced
from repro.core import (GroundTruthPerf, HeroScheduler, LinearPerfModel,
                        SchedulerConfig, Simulator, snapdragon_8gen3,
                        snapdragon_8gen4, strategy_config, tpu_v5e_slices)
from repro.rag import (STAGE_ROLES, build_stages, build_workflow,
                       default_means, make_template, sample_traces)


def run_strategy(strat, soc, family, wf, ds, n=4, seed=1):
    stages = build_stages(get_family(family))
    gt = GroundTruthPerf(soc, stages)
    perf = LinearPerfModel().fit(gt)
    traces = sample_traces(ds, n, seed=seed)
    means = default_means(traces)
    lat = []
    for tr in traces:
        if strat == "hero":
            cfg, tmpl = SchedulerConfig(), make_template(wf, means)
        else:
            cfg, tmpl = strategy_config(strat, STAGE_ROLES), None
        dag = build_workflow(wf, tr, fine_grained=cfg.enable_partition)
        sched = HeroScheduler(perf, [p.name for p in soc.pus], soc.dram_bw,
                              cfg, template=tmpl)
        lat.append(Simulator(gt, sched).run(dag).makespan)
    return float(np.mean(lat))


@pytest.mark.parametrize("wf", [1, 2, 3])
def test_hero_beats_all_baselines(wf):
    """Paper §6.2: HeRo delivers consistent improvements over all baselines."""
    soc = snapdragon_8gen4()
    hero = run_strategy("hero", soc, "qwen3", wf, "hotpotqa")
    for strat in ("llamacpp_gpu", "powerserve_npu", "ayo_like"):
        base = run_strategy(strat, soc, "qwen3", wf, "hotpotqa")
        assert hero < base, (wf, strat, hero, base)


def test_speedup_magnitudes_in_paper_range():
    """Headline ranges: multi-x vs GPU-only; >1 vs Ayo-like."""
    soc = snapdragon_8gen3()
    hero = run_strategy("hero", soc, "qwen3", 3, "2wikimqa")
    gpu = run_strategy("llamacpp_gpu", soc, "qwen3", 3, "2wikimqa")
    ayo = run_strategy("ayo_like", soc, "qwen3", 3, "2wikimqa")
    assert gpu / hero > 3.0        # paper: up to 10.94x
    assert 1.2 < ayo / hero < 4.0  # paper: 1.5x (text) / 3.2x (Table 3)


def test_ablation_ordering_matches_table3():
    """Table 3: each technique helps; ALL is best."""
    soc = snapdragon_8gen4()
    stages = build_stages(get_family("bge"))
    gt = GroundTruthPerf(soc, stages)
    perf = LinearPerfModel().fit(gt)
    traces = sample_traces("2wikimqa", 3, seed=3)
    means = default_means(traces)

    def run(flags):
        lat = []
        for tr in traces:
            tmpl = None
            if flags == "ayo":
                cfg = strategy_config("ayo_like", STAGE_ROLES)
            elif flags == "all":
                cfg, tmpl = SchedulerConfig(), make_template(3, means)
            elif flags == "crit":
                cfg = dataclasses.replace(
                    strategy_config("ayo_like", STAGE_ROLES),
                    enable_criticality=True, static_map=None)
                tmpl = make_template(3, means)
            elif flags == "part":
                cfg = dataclasses.replace(
                    strategy_config("ayo_like", STAGE_ROLES),
                    enable_partition=True)
            dag = build_workflow(3, tr, fine_grained=cfg.enable_partition)
            sched = HeroScheduler(perf, [p.name for p in soc.pus],
                                  soc.dram_bw, cfg, template=tmpl)
            lat.append(Simulator(gt, sched).run(dag).makespan)
        return float(np.mean(lat))

    base = run("ayo")
    part, crit, full = run("part"), run("crit"), run("all")
    assert part < base * 1.02          # partition alone helps (C2 regime)
    assert crit < base                 # criticality alone helps
    assert full <= min(part, crit) * 1.05  # ALL is best (within noise)


def test_tpu_slice_deployment_runs():
    """The TPU-pod PU-group deployment: same scheduler, v5e slices."""
    soc = tpu_v5e_slices({"slice_s": 8, "slice_m": 32, "slice_l": 216})
    stages = build_stages(get_family("qwen3"))
    gt = GroundTruthPerf(soc, stages)
    perf = LinearPerfModel().fit(gt)
    tr = sample_traces("hotpotqa", 1, seed=0)[0]
    dag = build_workflow(2, tr, fine_grained=True)
    sched = HeroScheduler(perf, [p.name for p in soc.pus], soc.dram_bw,
                          SchedulerConfig())
    res = Simulator(gt, sched).run(dag)
    assert not dag.unfinished()
    assert res.makespan < 5.0          # a pod is far faster than a phone


@pytest.mark.slow
def test_executable_pipeline_end_to_end():
    """The real JAX pipeline (tiny models) under the HeRo wall-clock
    runtime: chunk -> embed -> index -> search -> rerank -> agents -> chat."""
    import sys
    import repro.launch.serve as serve_mod
    argv = sys.argv
    sys.argv = ["serve", "--workflow", "2", "--queries", "1"]
    try:
        serve_mod.main()
    finally:
        sys.argv = argv
