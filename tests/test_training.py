"""Training substrate: optimizer, grad accumulation, compression,
checkpoint roundtrip + crash-restart semantics."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import Checkpointer
from repro.configs import get_config, reduced
from repro.training import (AdamWConfig, TrainConfig, adamw_init,
                            adamw_update, compressed_psum, make_train_step,
                            train)


def _data(cfg, B=4, S=32):
    k = 0
    while True:
        k += 1
        t = jax.random.randint(jax.random.PRNGKey(k), (B, S), 0,
                               cfg.vocab_size)
        yield {"tokens": t, "labels": t}


def test_loss_decreases_on_fixed_batch():
    cfg = reduced(get_config("qwen1.5-0.5b"))
    init, step = make_train_step(cfg, TrainConfig(
        optimizer=AdamWConfig(lr=1e-3, warmup_steps=1)))
    step = jax.jit(step)
    params, opt = init(jax.random.PRNGKey(0))
    batch = next(_data(cfg))
    losses = []
    for _ in range(12):
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.5, losses


@pytest.mark.slow
def test_grad_accum_equivalence():
    cfg = reduced(get_config("granite-3-2b"))
    batch = next(_data(cfg, B=4))
    outs = []
    for accum in (1, 2, 4):
        init, step = make_train_step(cfg, TrainConfig(grad_accum=accum))
        params, opt = init(jax.random.PRNGKey(0))
        p1, _, m = step(params, opt, batch)
        outs.append(np.concatenate(
            [np.asarray(x).ravel() for x in jax.tree.leaves(p1)][:5]))
    np.testing.assert_allclose(outs[0], outs[1], atol=1e-5)
    np.testing.assert_allclose(outs[0], outs[2], atol=1e-5)


def test_adamw_state_dtype_halves_memory():
    cfg = reduced(get_config("qwen1.5-0.5b"))
    from repro.models import build_model
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    s32 = adamw_init(params, AdamWConfig(state_dtype="float32"))
    s16 = adamw_init(params, AdamWConfig(state_dtype="bfloat16"))
    b32 = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(s32.m))
    b16 = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(s16.m))
    assert b16 * 2 == b32


def test_compressed_psum_single_device():
    """Compression roundtrip under shard_map on a 1-device mesh."""
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map
    mesh = jax.make_mesh((1,), ("pod",))
    x = jax.random.normal(jax.random.PRNGKey(0), (64, 64))

    f = shard_map(lambda a: compressed_psum(a, "pod"), mesh=mesh,
                  in_specs=P(), out_specs=P())
    out = f(x)
    # single participant: quantize->dequantize error only
    rel = float(jnp.abs(out - x).max() / jnp.abs(x).max())
    assert rel < 0.02


def test_checkpoint_roundtrip_and_gc():
    cfg = reduced(get_config("xlstm-350m"))
    from repro.models import build_model
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    with tempfile.TemporaryDirectory() as d:
        ck = Checkpointer(d, keep=2)
        for s in (1, 2, 3, 4):
            ck.save(params, s, block=True)
        assert ck.available_steps() == [3, 4]       # gc keeps newest 2
        assert ck.latest_step() == 4
        restored, step = ck.restore_latest(params)
        assert step == 4
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_restart_from_latest_after_crash():
    cfg = reduced(get_config("qwen1.5-0.5b"))
    with tempfile.TemporaryDirectory() as d:
        ck = Checkpointer(d)
        train(cfg, _data(cfg), steps=4, checkpointer=ck, checkpoint_every=2)
        # simulate crash + restart: resumes from step 4
        _, _, hist = train(cfg, _data(cfg), steps=6, checkpointer=ck,
                           checkpoint_every=10, restore=True, log_every=1)
        assert hist[0]["step"] == 4


def test_manifest_ignores_partial_writes():
    with tempfile.TemporaryDirectory() as d:
        ck = Checkpointer(d)
        tree = {"w": jnp.ones((4, 4))}
        ck.save(tree, 1, block=True)
        # a torn write (no manifest update) must not be visible
        with open(os.path.join(d, "step_00000099.npz"), "wb") as f:
            f.write(b"garbage")
        assert ck.latest_step() == 1
        restored, step = ck.restore_latest(tree)
        assert step == 1
