"""repro.analysis.lint — one positive and one negative case per rule,
plus the gate the CI job enforces: the real tree lints clean."""
import ast
import os

from repro.analysis.lint import (check_config_gates, check_core_determinism,
                                 check_counter_pairing, check_event_literals,
                                 check_fit_rng_order, lint_paths)

SRC = os.path.join(os.path.dirname(__file__), os.pardir, "src")

SIM = "src/repro/core/simulator.py"        # an event module, in core/
PM = "src/repro/core/perf_model.py"
SCHED = "src/repro/core/scheduler.py"
BACK = "src/repro/api/backends.py"
RES = "src/repro/api/results.py"


def _rules(violations):
    return [v.rule for v in violations]


def _evt(src):
    return check_event_literals(ast.parse(src), "core/simulator.py", SIM)


# --- EVT001 / EVT002 ---------------------------------------------------------

def test_evt001_raw_string_in_note():
    vs = _evt("self._note(timeline, t, 'done', n)")
    assert _rules(vs) == ["EVT001"]


def test_evt001_raw_string_in_emit():
    vs = _evt("self._emit(t, 'start', n)")
    assert _rules(vs) == ["EVT001"]


def test_evt001_raw_string_on_events_queue():
    vs = _evt("self._events.append(('kv_evict', node))")
    assert _rules(vs) == ["EVT001"]


def test_evt001_comparison_against_event_literal():
    vs = _evt("if ev == 'redispatch':\n    pass")
    assert _rules(vs) == ["EVT001"]


def test_evt001_membership_tuple_literal():
    vs = _evt("if ev in ('start', EV_DONE):\n    pass")
    assert _rules(vs) == ["EVT001"]


def test_evt_negative_constants_are_clean():
    vs = _evt("self._note(timeline, t, EV_DONE, n)\n"
              "self._emit(t, EV_START, n)\n"
              "self._events.append((EV_KV_EVICT, node))\n"
              "if ev in (EV_START, EV_DONE):\n    pass")
    assert vs == []


def test_evt002_typo_flagged():
    vs = _evt("if ev == 'kv_migrat':\n    pass")
    assert _rules(vs) == ["EVT002"]


def test_evt002_negative_unrelated_string():
    # not within edit distance 1 of any event name
    vs = _evt("if mode == 'shared':\n    pass")
    assert vs == []


def test_evt_rules_only_apply_to_event_modules():
    tree = ast.parse("self._note(timeline, t, 'done', n)")
    assert check_event_literals(tree, "rag/workflow.py",
                                "src/repro/rag/workflow.py") == []


# --- CFG001 / CFG002 ---------------------------------------------------------

def _cfg(sched_src, extra=None):
    trees = {SCHED: ast.parse(sched_src)}
    if extra is not None:
        trees["src/repro/api/other.py"] = ast.parse(extra)
    return check_config_gates(trees)


def test_cfg001_default_on_knob_flagged():
    vs = _cfg("BASELINE_ON_KNOBS = frozenset({'decode_batch'})\n"
              "class SchedulerConfig:\n"
              "    sneaky: bool = True\n"
              "    decode_batch: bool = True\n"
              "if cfg.sneaky: pass\n"
              "if cfg.decode_batch: pass\n")
    assert _rules(vs) == ["CFG001"]
    assert "sneaky" in vs[0].message


def test_cfg001_negative_baseline_declared():
    vs = _cfg("BASELINE_ON_KNOBS = frozenset({'decode_batch'})\n"
              "class SchedulerConfig:\n"
              "    decode_batch: bool = True\n"
              "if cfg.decode_batch: pass\n")
    assert vs == []


def test_cfg002_unread_gate_flagged():
    vs = _cfg("BASELINE_ON_KNOBS = frozenset()\n"
              "class SchedulerConfig:\n"
              "    ghost_feature: bool = False\n")
    assert _rules(vs) == ["CFG002"]


def test_cfg002_negative_boolean_read_anywhere_in_tree():
    vs = _cfg("BASELINE_ON_KNOBS = frozenset()\n"
              "class SchedulerConfig:\n"
              "    coalesce: bool = False\n",
              extra="if cfg.coalesce and ready:\n    pass\n")
    assert vs == []


def test_cfg002_negative_keyword_passthrough_counts_as_read():
    # the scheduler's own idiom: PagedKVCache(..., prefetch=cfg.kv_prefetch)
    vs = _cfg("BASELINE_ON_KNOBS = frozenset()\n"
              "class SchedulerConfig:\n"
              "    kv_prefetch: bool = False\n"
              "kv = PagedKVCache(prefetch=self.cfg.kv_prefetch)\n")
    assert vs == []


# --- RNG001 / RNG002 ---------------------------------------------------------

GOOD_FIT = """
class M:
    def _fit_noisy(self, rng):
        return rng.normal()

    def fit(self, seed=0):
        rng = np.random.default_rng(seed)
        for s in self.stages:
            self._fit_noisy(rng)
        self._grid = [self.solve(x) for x in self.xs]
        return self
"""

BAD_FIT = """
class M:
    def _fit_noisy(self, rng):
        return rng.normal()

    def fit(self, seed=0):
        rng = np.random.default_rng(seed)
        self._grid = [self.solve(x) for x in self.xs]
        for s in self.stages:
            self._fit_noisy(rng)
        return self
"""


def test_rng001_noiseless_grid_before_noisy_loop():
    vs = check_fit_rng_order(ast.parse(BAD_FIT), "core/perf_model.py", PM)
    assert _rules(vs) == ["RNG001"]


def test_rng001_negative_correct_order():
    vs = check_fit_rng_order(ast.parse(GOOD_FIT), "core/perf_model.py", PM)
    assert vs == []


def test_rng002_unseeded_or_rebound_rng():
    src = GOOD_FIT.replace("rng = np.random.default_rng(seed)",
                           "rng = make_rng()")
    vs = check_fit_rng_order(ast.parse(src), "core/perf_model.py", PM)
    assert "RNG002" in _rules(vs)


def test_rng_rules_only_apply_to_perf_model():
    assert check_fit_rng_order(ast.parse(BAD_FIT), "core/other.py",
                               "src/repro/core/other.py") == []


# --- DET001 / DET002 / DET003 ------------------------------------------------

def _det(src, key="core/simulator.py"):
    return check_core_determinism(ast.parse(src), key, SIM)


def test_det001_time_and_random_imports():
    assert _rules(_det("import time")) == ["DET001"]
    assert _rules(_det("from random import choice")) == ["DET001"]


def test_det002_legacy_global_stream():
    assert _rules(_det("x = np.random.normal(0, 1)")) == ["DET002"]


def test_det003_unseeded_default_rng():
    assert _rules(_det("rng = np.random.default_rng()")) == ["DET003"]


def test_det_negative_seeded_rng_and_math():
    assert _det("import math\n"
                "rng = np.random.default_rng(7)\n"
                "x = rng.normal(0, 1)\n") == []


def test_det_rules_only_apply_to_core():
    assert check_core_determinism(ast.parse("import time"),
                                  "serving/executor.py",
                                  "src/repro/serving/executor.py") == []


# --- CNT001 ------------------------------------------------------------------

def _cnt(back_src, res_src):
    return check_counter_pairing({BACK: ast.parse(back_src),
                                  RES: ast.parse(res_src)})


def test_cnt001_orphan_counter_flagged():
    vs = _cnt("RUN_ONLY_COUNTERS = frozenset({'kv_evictions'})\n"
              "class BackendRun:\n"
              "    makespan: float\n"
              "    events: list\n"
              "    batching: dict\n"
              "    kv_evictions: int = 0\n"
              "    orphan_count: int = 0\n",
              "class QueryResult:\n"
              "    makespan: float\n")
    assert _rules(vs) == ["CNT001"]
    assert "orphan_count" in vs[0].message


def test_cnt001_negative_paired_or_declared():
    vs = _cnt("RUN_ONLY_COUNTERS = frozenset({'kv_evictions'})\n"
              "class BackendRun:\n"
              "    makespan: float\n"
              "    events: list\n"
              "    batching: dict\n"
              "    kv_evictions: int = 0\n"
              "    kv_fetches: int = 0\n",
              "class QueryResult:\n"
              "    makespan: float\n"
              "    kv_fetches: int = 0\n")
    assert vs == []


# --- the CI gate: the real tree is clean -------------------------------------

def test_real_tree_lints_clean():
    violations = lint_paths([SRC])
    assert violations == [], "\n".join(str(v) for v in violations)
