"""LinearPerfModel property tests (satellite of the coalescing PR).

Runs everywhere: the deterministic property sweeps below draw hundreds of
seeded samples without needing hypothesis.  When hypothesis IS installed
(CI), the same properties are additionally explored generatively.
"""
import numpy as np
import pytest

from repro.core import (Config, GroundTruthPerf, LinearPerfModel, StageModel,
                        snapdragon_8gen4)

try:
    from hypothesis import given, settings, strategies as st
    HAS_HYPOTHESIS = True
except ImportError:                                  # pragma: no cover
    HAS_HYPOTHESIS = False


@pytest.fixture(scope="module")
def world():
    soc = snapdragon_8gen4()
    stages = {
        "embed": StageModel("embed", int(6e8), 1024, "batchable",
                            item_tokens=128),
        "rerank": StageModel("rerank", int(6e8), 1024, "batchable",
                             item_tokens=160),
        "search": StageModel("search", 0, 1024, "search"),
        "prefill": StageModel("prefill", int(4e9), 2560, "stream_prefill"),
        "decode": StageModel("decode", int(4e9), 2560, "stream_decode"),
    }
    gt = GroundTruthPerf(soc, stages)
    perf = LinearPerfModel().fit(gt)
    return soc, stages, gt, perf


def _pairs(perf):
    return sorted(perf.coef)


# --- positivity + grid exactness --------------------------------------------

def test_p0_and_bandwidth_positive_everywhere(world):
    """p0 and bandwidth stay strictly positive on and far off the profiled
    grid (the log-space fit guarantees this by construction)."""
    soc, stages, gt, perf = world
    rng = np.random.default_rng(7)
    batches = np.unique(rng.integers(1, 513, size=200))
    for stage, pu in _pairs(perf):
        for n in batches:
            assert perf.p0(stage, pu, int(n)) > 0.0, (stage, pu, n)
            assert perf.bandwidth(stage, pu, int(n)) > 0.0, (stage, pu, n)


def test_profiled_grid_points_exact(world):
    """Every profiled (stage, pu, batch) point reproduces the measurement
    exactly — the lookup table short-circuits the regression."""
    soc, stages, gt, perf = world
    for (sname, pname), tab in perf.table.items():
        stage, pu = stages[sname], soc.pu(pname)
        for n in tab:
            assert perf.p0(sname, pname, n) == gt.p0(
                stage, pu, Config(pname, n)), (sname, pname, n)
            assert perf.bandwidth(sname, pname, n) == gt.bandwidth(
                stage, pu, Config(pname, n)), (sname, pname, n)


# --- phi monotonicity --------------------------------------------------------

def test_phi_monotone_in_bandwidth(world):
    """φ(B) ≥ 1 and non-decreasing in B — including the below-knee region
    where the raw quadratic fit may dip (the projection must flatten it)."""
    soc, stages, gt, perf = world
    rng = np.random.default_rng(13)
    for sname in stages:
        Bs = np.sort(rng.uniform(0.0, 2.5 * soc.dram_bw, size=300))
        phis = [perf.phi(sname, float(B)) for B in Bs]
        assert min(phis) >= 1.0
        assert all(b >= a for a, b in zip(phis, phis[1:])), sname


# --- persistence -------------------------------------------------------------

def test_save_load_roundtrip_bit_exact(world, tmp_path):
    """save/load reproduces every prediction bit-exactly: table hits,
    off-grid regression values, and φ."""
    soc, stages, gt, perf = world
    path = str(tmp_path / "profile.json")
    perf.save(path)
    loaded = LinearPerfModel.load(path)
    rng = np.random.default_rng(23)
    batches = np.unique(np.concatenate([
        rng.integers(1, 600, size=64),
        [1, 8, 16, 32, 64, 128, 256]]))          # on-grid and off-grid
    for stage, pu in _pairs(perf):
        for n in batches:
            n = int(n)
            assert loaded.p0(stage, pu, n) == perf.p0(stage, pu, n)
            assert loaded.bandwidth(stage, pu, n) == \
                perf.bandwidth(stage, pu, n)
    for sname in stages:
        for B in rng.uniform(0, 2 * soc.dram_bw, size=32):
            assert loaded.phi(sname, float(B)) == perf.phi(sname, float(B))


# --- generative variants (CI: hypothesis installed) --------------------------

if HAS_HYPOTHESIS:

    @settings(max_examples=60, deadline=None)
    @given(batch=st.integers(1, 2048))
    def test_p0_positive_generative(batch):
        soc = snapdragon_8gen4()
        stages = {"embed": StageModel("embed", int(6e8), 1024, "batchable")}
        perf = LinearPerfModel().fit(GroundTruthPerf(soc, stages))
        for pu in ("cpu", "gpu", "npu"):
            assert perf.p0("embed", pu, batch) > 0.0
            assert perf.bandwidth("embed", pu, batch) > 0.0

    @settings(max_examples=60, deadline=None)
    @given(b1=st.floats(0, 2.5), b2=st.floats(0, 2.5))
    def test_phi_monotone_generative(b1, b2):
        soc = snapdragon_8gen4()
        stages = {"decode": StageModel("decode", int(4e9), 2560,
                                       "stream_decode")}
        perf = LinearPerfModel().fit(GroundTruthPerf(soc, stages))
        lo, hi = sorted((b1, b2))
        assert 1.0 <= perf.phi("decode", lo * soc.dram_bw) \
            <= perf.phi("decode", hi * soc.dram_bw)
