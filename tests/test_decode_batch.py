"""Continuous decode batching across queries (the serving tentpole).

Covers the ISSUE's required invariants: join/leave at token-group
boundaries (membership never exceeds the cap, no token group served
twice), per-query token-stream ordering through ``on_token``, sim/live
parity at 8 staggered W1 queries, the p99 improvement over PR 2's
stage-coalescing-only scheduler, and bit-identical coalesce-off behavior
vs the committed PR 2 goldens.
"""
import json
import os

import numpy as np
import pytest

from repro.api import HeroSession
from repro.api.session import make_world
from repro.core import DynamicDAG, HeroScheduler, SchedulerConfig
from repro.core.dag import Node
from repro.rag import default_means, sample_traces

GOLDENS = os.path.join(os.path.dirname(__file__), "goldens",
                       "pr2_coalesce_off.json")


@pytest.fixture(scope="module")
def traces():
    return sample_traces("hotpotqa", 8, seed=11)


@pytest.fixture(scope="module")
def means(traces):
    return default_means(traces)


# --- DAG-level round semantics ----------------------------------------------

def _decode_pair():
    dag = DynamicDAG()
    a = dag.add(Node("q0/chat_decode", "chat_decode", "stream_decode", 40))
    b = dag.add(Node("q1/chat_decode", "chat_decode", "stream_decode", 12))
    sa = dag.add(Node("q0/post", "post", "batchable", 1,
                      deps={"q0/chat_decode"}))
    sb = dag.add(Node("q1/post", "post", "batchable", 1,
                      deps={"q1/chat_decode"}))
    return dag, a, b, sa, sb


def test_decode_round_advances_and_releases_members():
    """One boundary: the short stream leaves (successors release
    immediately — per-member early release), the long stream rejoins the
    ready pool with its served tokens subtracted."""
    dag, a, b, sa, sb = _decode_pair()
    fused = dag.fuse_decode([a, b])
    assert fused.payload["decode_width"] == 2
    assert fused.workload == 40            # horizon = longest member
    fused.workload = 16                    # scheduler trims to the group
    dag.mark_running(fused.id, 1.0, ("gpu", 16))
    dag.mark_done(fused.id, 3.0)
    # leave: b (12 ≤ 16 tokens) finished at the boundary, successor READY
    assert b.status == "done" and b.finish == 3.0
    assert sb.status == "ready"
    assert b.payload["decode_served"] == b.payload["decode_total"] == 12
    # a advanced by one group and is schedulable again (join next round)
    assert a.status == "ready" and a.workload == 24
    assert a.payload["decode_served"] == 16
    assert a.payload["last_slice"] == 16
    assert sa.status == "pending"
    # round accounting sums to the round's residency
    acc_a = a.payload["pu_busy_acc"]["gpu"]
    acc_b = b.payload["pu_busy_acc"]["gpu"]
    assert acc_a + acc_b == pytest.approx(2.0)


def test_undispatched_round_dissolves():
    dag, a, b, _, _ = _decode_pair()
    fused = dag.fuse_decode([a, b])
    members = dag.unfuse(fused)
    assert {m.id for m in members} == {"q0/chat_decode", "q1/chat_decode"}
    assert a.status == b.status == "ready"
    assert a.workload == 40 and b.workload == 12   # nothing served


def test_membership_never_exceeds_cap():
    soc, gt, perf = make_world("sd8gen4", "qwen3")
    dag = DynamicDAG()
    for q in range(6):
        dag.add(Node(f"q{q}/chat_decode", "chat_decode", "stream_decode", 64))
    sched = HeroScheduler(perf, [p.name for p in soc.pus], soc.dram_bw,
                          SchedulerConfig(coalesce=True, decode_batch_cap=4))
    [fused] = sched._coalesce(dag)
    assert len(fused.payload["members"]) == 4
    assert fused.payload["decode_width"] == 4


def test_decode_batch_needs_cross_query_and_toggle():
    soc, gt, perf = make_world("sd8gen4", "qwen3")
    dag = DynamicDAG()
    dag.add(Node("q0/chat_decode", "chat_decode", "stream_decode", 64))
    dag.add(Node("q0/refine", "chat_decode", "stream_decode", 64))
    sched = HeroScheduler(perf, [p.name for p in soc.pus], soc.dram_bw,
                          SchedulerConfig(coalesce=True))
    assert sched._coalesce(dag) == []      # same query: no decode batch
    dag.add(Node("q1/chat_decode", "chat_decode", "stream_decode", 64))
    off = HeroScheduler(perf, [p.name for p in soc.pus], soc.dram_bw,
                        SchedulerConfig(coalesce=True, decode_batch=False))
    assert off._coalesce(dag) == []        # toggle gates the feature
    [fused] = sched._coalesce(dag)
    assert fused.payload["decode_round"] is True


# --- end-to-end invariants ----------------------------------------------------

def _staggered_run(traces, means, **kw):
    sess = HeroSession(world="sd8gen4", family="qwen3", means=means,
                       coalesce=True, **kw)
    for qi, tr in enumerate(traces):
        sess.submit(tr, wf=1, arrival_time=qi * 0.25)
    return sess


def test_no_token_group_served_twice(traces, means):
    """Every decode stream is served exactly once: per-member served
    counters never exceed the stream total, and every query's answer is
    streamed token-for-token through on_token (no duplicates, no gaps)."""
    got = {h: 0 for h in range(len(traces))}
    sess = _staggered_run(traces, means)
    for h in sess.queries:
        h.on_token = (lambda hh, n, t: got.__setitem__(
            hh.qid, got[hh.qid] + n))
    res = sess.run()
    assert sum(r.decode_rounds for r in res) > 0, "no continuous batching"
    for r, tr in zip(res, traces):
        assert got[r.qid] == tr.answer_tokens, (r.qid, got[r.qid])


def test_on_token_stream_ordered_and_attributed(traces, means):
    """Per-query token streams arrive in non-decreasing time order and
    only ever carry the owning query's prefix."""
    events = {i: [] for i in range(4)}
    sess = _staggered_run(traces[:4], means)
    for h in sess.queries:
        h.on_token = lambda hh, n, t: events[hh.qid].append((t, n))
    sess.run()
    for qid, evs in events.items():
        assert evs, f"query {qid} streamed nothing"
        times = [t for t, _ in evs]
        assert times == sorted(times)
        assert all(n > 0 for _, n in evs)


def test_mid_flight_join(traces, means):
    """A decode stream that becomes READY while a resident batch is
    running joins at the next token-group boundary: a later round's
    membership contains both an already-resident query and one absent
    from an earlier round."""
    sess = _staggered_run(traces, means)
    sess.run()
    # reconstruct round memberships from the event stream: a round's
    # member "start" events are fanned out contiguously after its own
    rounds = []
    for i, (t, event, nid) in enumerate(sess.last_run.events):
        if event != "start" or not nid.startswith("dround:"):
            continue
        members = set()
        for t2, ev2, nid2 in sess.last_run.events[i + 1:]:
            if t2 != t or ev2 != "start" or "/" not in nid2:
                break
            members.add(nid2.split("/", 1)[0])
        rounds.append(members)
    assert len(rounds) >= 2, "expected multiple decode-round boundaries"
    joined = any(
        earlier & later and later - earlier
        for i, earlier in enumerate(rounds) for later in rounds[i + 1:])
    assert joined, f"no mid-flight join observed in rounds {rounds}"


def test_sim_live_parity_8_staggered_w1(traces, means):
    """The ISSUE's parity bar: 8 staggered W1 queries, same per-query
    stage sets and continuous batching active on both substrates.  The
    live decode fn costs real wall time so streams overlap (instant dry
    fns would drain each stream before the next query arrives)."""
    import time as _time
    by = {}
    for backend in ("sim", "live"):
        sess = HeroSession(world="sd8gen4", family="qwen3", means=means,
                           coalesce=True, backend=backend,
                           stage_fns={"chat_decode":
                                      lambda n, b: _time.sleep(0.02)})
        for qi, tr in enumerate(traces):
            sess.submit(tr, wf=1, arrival_time=qi * 0.05)
        by[backend] = sess.run(timeout=120)
    for s, l in zip(by["sim"], by["live"]):
        assert s.qid == l.qid
        assert set(s.stage_latency) == set(l.stage_latency)
        assert s.makespan > 0 and l.makespan > 0
    assert sum(r.decode_rounds for r in by["sim"]) > 0
    assert sum(r.decode_rounds for r in by["live"]) > 0


def test_decode_batching_improves_p99_over_coalesce_only(traces, means):
    """The acceptance bar: at 8 staggered W1 queries, continuous decode
    batching beats PR 2's stage-coalescing-only p99 AND total makespan."""
    out = {}
    for label, overrides in (("coalesce_only", {"decode_batch": False}),
                             ("decode_batch", None)):
        sess = _staggered_run(traces, means, cfg_overrides=overrides)
        res = sess.run()
        lats = np.array([r.makespan for r in res])
        out[label] = (float(np.percentile(lats, 99)),
                      max(r.finish_time for r in res))
    assert out["decode_batch"][0] < out["coalesce_only"][0]
    assert out["decode_batch"][1] < out["coalesce_only"][1]


def test_shared_run_with_decode_batching_deterministic(traces, means):
    def once():
        sess = _staggered_run(traces[:6], means)
        return [r.makespan for r in sess.run()]

    assert once() == once()


# --- coalesce-off bit-identical regression vs PR 2 goldens -------------------

def test_coalesce_off_matches_pr2_goldens(traces, means):
    """With coalescing off, every code path added for continuous batching
    is dormant: single-query makespans for W1-W3 × all four strategies and
    the staggered-8 shared run reproduce the committed PR 2 goldens."""
    with open(GOLDENS) as f:
        golden = json.load(f)
    for wf in (1, 2, 3):
        for strategy in ("llamacpp_gpu", "powerserve_npu", "ayo_like",
                         "hero"):
            sess = HeroSession(world="sd8gen4", family="qwen3",
                               strategy=strategy, means=means)
            sess.submit(traces[0], wf=wf)
            [res] = sess.run(mode="isolated")
            assert res.makespan == pytest.approx(
                golden[f"w{wf}/{strategy}"], rel=1e-12), (wf, strategy)
    sess = HeroSession(world="sd8gen4", family="qwen3", means=means,
                       coalesce=False)
    for qi, tr in enumerate(traces):
        sess.submit(tr, wf=1, arrival_time=qi * 0.25)
    got = [r.makespan for r in sess.run()]
    assert got == pytest.approx(golden["staggered8_w1_makespans"],
                                rel=1e-12)
