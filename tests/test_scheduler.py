"""HeRo core unit tests + hypothesis property tests on scheduler invariants.

Requires ``hypothesis`` (CI installs it); skips cleanly where it is absent.
Deterministic scheduler coverage that must run everywhere lives in
``test_coalesce.py`` / ``test_perf_model.py``.
"""
import dataclasses

import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import (Config, DynamicDAG, GroundTruthPerf, HeroScheduler,
                        LinearPerfModel, SchedulerConfig, Simulator,
                        StageModel, snapdragon_8gen4, strategy_config)
from repro.core.dag import Node
from repro.core.partitioner import best_batch, shape_aware_configs


@pytest.fixture(scope="module")
def world():
    soc = snapdragon_8gen4()
    stages = {
        "embed": StageModel("embed", int(6e8), 1024, "batchable",
                            item_tokens=128),
        "rerank": StageModel("rerank", int(6e8), 1024, "batchable",
                             item_tokens=160),
        "search": StageModel("search", 0, 1024, "search"),
        "prefill": StageModel("prefill", int(4e9), 2560, "stream_prefill"),
        "decode": StageModel("decode", int(4e9), 2560, "stream_decode"),
    }
    gt = GroundTruthPerf(soc, stages)
    perf = LinearPerfModel().fit(gt)
    return soc, stages, gt, perf


# --- perf model -------------------------------------------------------------

def test_regression_accuracy_on_and_off_grid(world):
    soc, stages, gt, perf = world
    for pu in soc.pus:
        for sname, stage in stages.items():
            if not gt.supported(stage, pu):
                continue
            for n in [1, 8, 11, 22, 64, 100, 256]:
                true = gt.p0(stage, pu, Config(pu.name, n))
                est = perf.p0(sname, pu.name, n)
                assert est > 0
                assert abs(est - true) / true < 0.8, (sname, pu.name, n)


def test_phi_monotone(world):
    soc, stages, gt, perf = world
    b0 = soc.dram_bw
    xs = np.linspace(0, 2 * b0, 30)
    for sname in stages:
        phis = [perf.phi(sname, x) for x in xs]
        assert phis[0] >= 1.0 - 1e-6
        assert all(b >= a - 1e-9 for a, b in zip(phis, phis[1:]))


def test_affinity_embed_npu_generation_gpu(world):
    """Fig. 2: encoder stages favour NPU; decode favours GPU."""
    soc, stages, gt, perf = world
    assert perf.p0("embed", "npu", 32) < perf.p0("embed", "gpu", 32)
    assert perf.p0("embed", "npu", 32) < perf.p0("embed", "cpu", 32)
    assert perf.p0("decode", "gpu", 16) < perf.p0("decode", "npu", 16)


def test_eq3_batch_choice(world):
    soc, stages, gt, perf = world
    n, t = best_batch(perf, "embed", "npu", 100)
    # Eq. 3 should beat the single monolithic pass
    assert t <= perf.p0("embed", "npu", 100) + 1e-9
    assert n <= 100


# --- DAG / scheduler properties (hypothesis) --------------------------------

@st.composite
def dag_strategy(draw):
    """Random layered DAGs over the stage catalog."""
    n_layers = draw(st.integers(1, 4))
    stages_pool = ["embed", "rerank", "prefill", "decode", "search"]
    kinds = {"embed": "batchable", "rerank": "batchable",
             "prefill": "stream_prefill", "decode": "stream_decode",
             "search": "search"}
    nodes = []
    layers = []
    for li in range(n_layers):
        width = draw(st.integers(1, 3))
        layer = []
        for wi in range(width):
            stage = draw(st.sampled_from(stages_pool))
            wl = draw(st.integers(1, 64))
            nid = f"n{li}_{wi}"
            deps = []
            if li > 0:
                deps = draw(st.lists(st.sampled_from(layers[li - 1]),
                                     max_size=len(layers[li - 1]),
                                     unique=True))
            nodes.append((nid, stage, kinds[stage], wl, deps))
            layer.append(nid)
        layers.append(layer)
    return nodes


def build_dag(spec):
    dag = DynamicDAG()
    for nid, stage, kind, wl, deps in spec:
        dag.add(Node(nid, stage, kind, wl, deps=set(deps)))
    return dag


@settings(max_examples=15, deadline=None)
@given(spec=dag_strategy(),
       strat=st.sampled_from(["hero", "ayo_like", "powerserve_npu"]))
def test_scheduler_invariants(spec, strat):
    soc = snapdragon_8gen4()
    stages = {
        "embed": StageModel("embed", int(6e8), 1024, "batchable"),
        "rerank": StageModel("rerank", int(6e8), 1024, "batchable"),
        "search": StageModel("search", 0, 1024, "search"),
        "prefill": StageModel("prefill", int(4e9), 2560, "stream_prefill"),
        "decode": StageModel("decode", int(4e9), 2560, "stream_decode"),
    }
    gt = GroundTruthPerf(soc, stages)
    perf = LinearPerfModel().fit(gt)
    roles = {"embed": "embed", "rerank": "rerank", "search": "search",
             "prefill": "chat", "decode": "chat"}
    cfg = strategy_config(strat, roles)
    dag = build_dag(spec)
    total_workload = {n.id: n.workload for n in dag.nodes.values()}
    sched = HeroScheduler(perf, [p.name for p in soc.pus], soc.dram_bw, cfg)
    res = Simulator(gt, sched).run(dag, max_time=7200)

    # 1. every node (and spawned sub-stage) completed
    assert not dag.unfinished()
    # 2. dependencies respected: finish(dep) <= start(node)
    for n in dag.nodes.values():
        for d in n.deps:
            assert dag.nodes[d].finish <= n.start + 1e-9, (d, n.id)
    # 3. no PU ran two sub-stages at once
    by_pu = {}
    for n in dag.nodes.values():
        if n.config is None or n.config[0] == "io":
            continue
        by_pu.setdefault(n.config[0], []).append((n.start, n.finish))
    for pu, spans in by_pu.items():
        spans.sort()
        for (s1, f1), (s2, f2) in zip(spans, spans[1:]):
            assert f1 <= s2 + 1e-9, (pu, (s1, f1), (s2, f2))
    # 4. workload conservation: sub-stage pieces of a group sum to parent
    sums = {}
    for n in dag.nodes.values():
        key = n.group or n.id
        sums[key] = sums.get(key, 0) + n.workload
    for nid, wl in total_workload.items():
        assert sums.get(nid, wl) == wl
    # 5. makespan = max finish
    assert res.makespan == pytest.approx(
        max(n.finish for n in dag.nodes.values()))
    # 6. static maps only use their pinned PUs
    if cfg.static_map is not None:
        for n in dag.nodes.values():
            if n.config and n.config[0] != "io":
                assert n.config[0] == cfg.static_map[n.stage]


def test_deferral_avoids_slow_idle_pu(world):
    """Queue-aware mapping: a critical stage queues for the fast busy PU
    instead of grabbing the catastrophically slow idle one."""
    soc, stages, gt, perf = world
    dag = DynamicDAG()
    dag.add(Node("e1", "embed", "batchable", 64))
    dag.add(Node("e2", "embed", "batchable", 64))
    sched = HeroScheduler(perf, ["cpu", "npu"], soc.dram_bw,
                          SchedulerConfig())
    res = Simulator(gt, sched).run(dag)
    # both stages should run on the NPU (cpu embed is ~100x slower)
    assert all(n.config[0] == "npu" for n in dag.nodes.values())


def test_elastic_pu_membership(world):
    soc, stages, gt, perf = world
    sched = HeroScheduler(perf, ["cpu", "gpu"], soc.dram_bw,
                          SchedulerConfig())
    sched.add_pu("npu")
    assert "npu" in sched.pus
    sched.remove_pu("gpu")
    dag = DynamicDAG()
    dag.add(Node("e1", "embed", "batchable", 32))
    res = Simulator(gt, sched).run(dag)
    assert dag.nodes["e1"].config[0] in ("cpu", "npu")


def test_straggler_redispatch(world):
    soc, stages, gt, perf = world
    dag = DynamicDAG()
    dag.add(Node("e1", "embed", "batchable", 32))
    sched = HeroScheduler(perf, [p.name for p in soc.pus], soc.dram_bw,
                          SchedulerConfig(straggler_factor=2.0))
    sim = Simulator(gt, sched, straggler_prob=1.0, straggler_slow=50.0,
                    seed=1)
    res = sim.run(dag)
    assert not dag.unfinished()
    assert res.redispatches >= 1


def test_failure_recovery(world):
    """A node that never completes is reaped and re-dispatched."""
    soc, stages, gt, perf = world
    dag = DynamicDAG()
    dag.add(Node("e1", "embed", "batchable", 16))
    dag.add(Node("e2", "rerank", "batchable", 8, deps={"e1"}))
    sched = HeroScheduler(perf, [p.name for p in soc.pus], soc.dram_bw,
                          SchedulerConfig(straggler_factor=2.0))
    sim = Simulator(gt, sched, fail_prob=0.3, seed=3)
    res = sim.run(dag)
    assert not dag.unfinished()
