"""KV-residency subsystem (the PR 5 tentpole).

Covers the ISSUE's required invariants: footprint accounting across
join / boundary / leave / re-fuse, migration-cost monotonicity in
context length (and agreement with the ground truth on the profiled
grid), deterministic prefer-PU resolution under conflicting batch_pu
history, sim/live parity of the kv_migrations accounting, bit-exactness
of the legacy goldens with the subsystem disabled, and a hypothesis
property (total bytes charged == the sum of footprints at each
migration, reconstructed from boundary deltas).
"""
import json
import os

import pytest

from repro.api import HeroSession
from repro.api.session import make_world
from repro.core import SchedulerConfig
from repro.core.dag import DynamicDAG, Node
from repro.core.kv_residency import KVResidency, stream_key
from repro.core.perf_model import LinearPerfModel
from repro.core.scheduler import HeroScheduler
from repro.rag import default_means, sample_traces

GOLDEN_DIR = os.path.join(os.path.dirname(__file__), "goldens")


@pytest.fixture(scope="module")
def world():
    return make_world("sd8gen4", "qwen3")


@pytest.fixture()
def perf(world):
    return world[2]


def synthetic_perf(kv_bytes=100.0, sec_per_tok=1e-3, stage="chat_decode",
                   pus=("cpu", "gpu", "npu")):
    """A LinearPerfModel with a handcrafted migration profile."""
    m = LinearPerfModel()
    m._tiles = {p: 8 for p in pus}
    m._b0 = 1e9
    m.kv_bytes = {stage: kv_bytes}
    m.phi_coef = {stage: [1.0, 0.0, 0.0]}     # φ ≡ 1
    for a in pus:
        for b in pus:
            if a != b:
                m.migrate_coef[(stage, a, b)] = (0.0, sec_per_tok)
    return m


def decode_node(nid, ctx=100, workload=64, stage="chat_decode", **payload):
    return Node(id=nid, stage=stage, kind="stream_decode",
                workload=workload, payload={"kv_ctx": ctx, **payload})


# --- migration-cost model -----------------------------------------------------

def test_migrate_cost_monotone_in_context(perf):
    pairs = {(s, a, b) for (s, a, b) in perf.migrate_coef}
    assert pairs, "qwen3 profile must include a migration grid"
    for (s, a, b) in pairs:
        costs = [perf.migrate_cost(s, a, b, ctx)
                 for ctx in (128, 1024, 8192, 65536)]
        assert all(c > 0 for c in costs)
        assert costs == sorted(costs)
        assert costs[-1] > costs[0]


def test_migrate_cost_matches_ground_truth_on_grid(world):
    soc, gt, perf = world
    stage = gt.stages["chat_decode"]
    for ctx in LinearPerfModel.MIGRATE_CTX:
        got = perf.migrate_cost("chat_decode", "gpu", "cpu", ctx)
        want = gt.migrate_cost(stage, soc.pu("gpu"), soc.pu("cpu"), ctx)
        assert got == pytest.approx(want, rel=1e-9)
    # same PU is free; unknown pairs fall back to None (legacy constant)
    assert perf.migrate_cost("chat_decode", "gpu", "gpu", 4096) == 0.0
    assert perf.migrate_cost("chat_decode", "gpu", "nope", 4096) is None


def test_migrate_cost_scales_with_kv_bytes(world):
    """chat (qwen3-4B) carries a heavier per-token cache than the search
    model (qwen3-1.7B), so the same context costs more to move."""
    _soc, _gt, perf = world
    assert perf.kv_bytes["chat_decode"] > perf.kv_bytes["rewrite_decode"]
    c = perf.migrate_cost("chat_decode", "gpu", "cpu", 4096)
    r = perf.migrate_cost("rewrite_decode", "gpu", "cpu", 4096)
    assert c > r


def test_migrate_profile_save_load_roundtrip(tmp_path, perf):
    path = str(tmp_path / "profile.json")
    perf.save(path)
    loaded = LinearPerfModel.load(path)
    assert loaded.migrate_coef == {
        k: tuple(v) for k, v in perf.migrate_coef.items()}
    assert loaded.kv_bytes == perf.kv_bytes
    # pre-residency blobs (no migration grid) still load and degrade
    with open(path) as f:
        blob = json.load(f)
    blob.pop("migrate_coef")
    blob.pop("kv_bytes")
    with open(path, "w") as f:
        json.dump(blob, f)
    old = LinearPerfModel.load(path)
    assert old.migrate_cost("chat_decode", "gpu", "cpu", 4096) is None


# --- footprint accounting -----------------------------------------------------

def test_footprint_join_boundary_leave():
    kv = KVResidency(synthetic_perf(kv_bytes=10.0))
    a = decode_node("q0/d", ctx=100, workload=64)
    b = decode_node("q1/d", ctx=50, workload=32)
    round_ = Node("dround:x", "chat_decode", "stream_decode", 64,
                  payload={"members": [a, b], "decode_round": True,
                           "decode_width": 2})
    assert kv.migrate_for_dispatch(round_, "gpu") == []   # first join: free
    assert kv.resident_bytes("gpu") == (100 + 50) * 10.0
    kv.on_boundary(a, "gpu", 16)
    kv.on_boundary(b, "gpu", 16)
    assert kv.resident_bytes("gpu") == (116 + 66) * 10.0
    kv.on_boundary(b, "gpu", 16, left=True)               # leave frees
    assert kv.resident_bytes("gpu") == 116 * 10.0
    assert kv.resident_bytes() == 116 * 10.0


def test_refuse_migration_counts_bytes_and_payload():
    kv = KVResidency(synthetic_perf(kv_bytes=10.0))
    a = decode_node("q0/d", ctx=100, workload=64)
    b = decode_node("q1/d", ctx=50, workload=64)
    r1 = Node("dround:1", "chat_decode", "stream_decode", 64,
              payload={"members": [a, b], "decode_round": True})
    kv.migrate_for_dispatch(r1, "gpu")
    kv.on_boundary(a, "gpu", 16)
    kv.on_boundary(b, "gpu", 16)
    # re-fuse on another PU: both caches move at their boundary-grown size
    r2 = Node("dround:2", "chat_decode", "stream_decode", 48,
              payload={"members": [a, b], "decode_round": True})
    moved = kv.migrate_for_dispatch(r2, "cpu")
    assert [(m.id, src) for m, src, _c, _b in moved] == [
        ("q0/d", "gpu"), ("q1/d", "gpu")]
    assert kv.migrations == 2
    assert kv.bytes_moved == (116 + 66) * 10.0
    assert a.payload["kv_migrations"] == 1
    assert a.payload["kv_bytes_moved"] == 116 * 10.0
    # re-dispatch on the same PU is free (idempotent)
    assert kv.migrate_for_dispatch(r2, "cpu") == []
    assert kv.migrations == 2


def test_solo_stream_tracks_across_chain_pieces():
    """Sub-stage chaining mints fresh node ids; the stream key (group)
    keeps residency continuous, and each piece charges its token group
    into the context exactly once."""
    kv = KVResidency(synthetic_perf(kv_bytes=1.0))
    head = decode_node("q0/d", ctx=100, workload=16)
    head.group = "q0/d"
    kv.migrate_for_dispatch(head, "gpu")
    assert kv.tracked(head).ctx_tokens == 116      # kv_ctx + served group
    kv.migrate_for_dispatch(head, "gpu")           # straggler re-dispatch
    assert kv.tracked(head).ctx_tokens == 116      # idempotent per piece
    rest = decode_node("q0/d.r#1", ctx=100, workload=16)
    rest.group = "q0/d"
    assert stream_key(rest) == stream_key(head)
    moved = kv.migrate_for_dispatch(rest, "cpu")   # chain hops PU: priced
    assert len(moved) == 1 and moved[0][1] == "gpu"
    assert kv.tracked(rest).ctx_tokens == 132
    assert kv.bytes_moved == 116.0                 # footprint before growth


def test_migrate_penalty_prices_only_movers():
    kv = KVResidency(synthetic_perf(kv_bytes=10.0, sec_per_tok=1e-3))
    a = decode_node("q0/d", ctx=100, workload=64, batch_pu="gpu")
    b = decode_node("q1/d", ctx=50, workload=64, batch_pu="cpu")
    r = Node("dround:1", "chat_decode", "stream_decode", 64,
             payload={"members": [a, b], "decode_round": True})
    moving, cost = kv.migrate_penalty(r, "gpu")
    assert moving == 1 and cost == pytest.approx(50 * 1e-3)   # b moves
    moving, cost = kv.migrate_penalty(r, "npu")
    assert moving == 2 and cost == pytest.approx(150 * 1e-3)  # both move
    # unknown pair: None — the scheduler falls back to the constant
    kv2 = KVResidency(synthetic_perf(pus=("cpu", "gpu")))
    assert kv2.migrate_penalty(r, "npu") is None


# --- prefer-PU resolution under conflicting history --------------------------

def test_fuse_decode_prefers_largest_footprint_on_conflict():
    dag = DynamicDAG()
    kv = KVResidency(synthetic_perf(kv_bytes=1.0))
    dag.kv = kv
    small = dag.add(decode_node("q0/d", ctx=10, workload=64,
                                batch_pu="gpu"))
    big = dag.add(decode_node("q1/d", ctx=1000, workload=64,
                              batch_pu="cpu"))
    fused = dag.fuse_decode([small, big])
    assert fused.payload["prefer_pu"] == "cpu"     # big cache anchors
    # agreement still short-circuits (legacy path)
    dag2 = DynamicDAG()
    a = dag2.add(decode_node("q0/e", ctx=10, workload=64, batch_pu="npu"))
    b = dag2.add(decode_node("q1/e", ctx=10, workload=64, batch_pu="npu"))
    assert dag2.fuse_decode([a, b]).payload["prefer_pu"] == "npu"


def test_fuse_decode_conflict_without_tracker_stays_legacy():
    dag = DynamicDAG()          # no dag.kv: legacy — no preference at all
    a = dag.add(decode_node("q0/d", ctx=10, workload=64, batch_pu="gpu"))
    b = dag.add(decode_node("q1/d", ctx=10, workload=64, batch_pu="cpu"))
    assert "prefer_pu" not in dag.fuse_decode([a, b]).payload


def test_prefer_pu_deterministic_tie_break():
    kv = KVResidency(synthetic_perf(kv_bytes=1.0))
    a = decode_node("q0/d", ctx=100, workload=64, batch_pu="gpu")
    b = decode_node("q1/d", ctx=100, workload=64, batch_pu="cpu")
    # equal footprints: smallest PU name wins, independent of member order
    assert kv.prefer_pu([a, b]) == kv.prefer_pu([b, a]) == "cpu"
    assert kv.prefer_pu([decode_node("q2/d", workload=8)]) is None


# --- scheduler integration ----------------------------------------------------

def test_scheduler_kv_gate_and_validation(perf):
    sched = HeroScheduler(perf, ["cpu", "gpu", "npu"], 1e9,
                          SchedulerConfig())
    assert sched.kv is None                       # off by default
    on = HeroScheduler(perf, ["cpu", "gpu", "npu"], 1e9,
                       SchedulerConfig(kv_residency=True))
    assert isinstance(on.kv, KVResidency)
    assert on.policy.kv is on.kv
    with pytest.raises(KeyError):
        HeroScheduler(perf, ["cpu"], 1e9,
                      SchedulerConfig(migrate_pricing="nope"))


# --- end-to-end: goldens off, parity on ---------------------------------------

@pytest.fixture(scope="module")
def traces():
    return sample_traces("hotpotqa", 8, seed=11)


@pytest.fixture(scope="module")
def means(traces):
    return default_means(traces)


def test_goldens_bit_identical_with_kv_off(traces, means):
    """kv_residency=False (the default) keeps the PR 3 continuous-decode
    behavior bit-exact: no tracking, no physics, the legacy constant."""
    with open(os.path.join(GOLDEN_DIR, "pr3_decode_batch.json")) as f:
        golden = json.load(f)
    sess = HeroSession(world="sd8gen4", family="qwen3", means=means,
                       coalesce=True, batch_policy="fixed",
                       kv_residency=False)
    for qi, tr in enumerate(traces):
        sess.submit(tr, wf=1, arrival_time=qi * 0.25)
    got = [r.makespan for r in sess.run()]
    assert got == pytest.approx(golden["saturated8_w1_decode_makespans"],
                                rel=1e-12)
    assert sess.last_run.kv_migrations == 0
    assert sess.last_run.kv_bytes_moved == 0.0


def _kv_session(traces, means, backend="sim", **kw):
    sess = HeroSession(world="sd8gen4", family="qwen3", means=means,
                       coalesce=True, batch_policy="adaptive",
                       kv_residency=True, backend=backend, **kw)
    for qi, tr in enumerate(traces):
        sess.submit(tr, wf=(1, 3)[qi % 2], arrival_time=qi * 0.05)
    return sess


@pytest.mark.slow
def test_sim_live_parity_of_kv_accounting(means):
    """Both substrates register migrations through the same tracker hook:
    run totals equal the kv_migrate events in the timeline AND the
    per-query sums, with bytes moved iff something migrated."""
    import time as _time
    traces6 = sample_traces("hotpotqa", 6, seed=11)
    for backend in ("sim", "live"):
        kw = {}
        if backend == "live":
            kw["stage_fns"] = {"chat_decode":
                               lambda n, b: _time.sleep(0.01)}
        sess = _kv_session(traces6, means, backend=backend, **kw)
        res = sess.run(timeout=180)
        run = sess.last_run
        events = sum(1 for e in run.events if e[1] == "kv_migrate")
        assert run.kv_migrations == events
        assert sum(r.kv_migrations for r in res) == run.kv_migrations
        assert (run.kv_bytes_moved > 0) == (run.kv_migrations > 0)
        assert sum(r.kv_bytes_moved for r in res) == pytest.approx(
            run.kv_bytes_moved)


def test_sim_kv_on_runs_and_accounts(traces, means):
    """The sim backend with residency on: consistent counters and the
    same per-query stage coverage as the goldens path."""
    sess = _kv_session(traces, means)
    res = sess.run(timeout=7200)
    run = sess.last_run
    assert all(r.makespan > 0 for r in res)
    assert run.kv_migrations == sum(
        1 for e in run.events if e[1] == "kv_migrate")
    assert sum(r.kv_migrations for r in res) == run.kv_migrations
    assert sum(r.kv_bytes_moved for r in res) == pytest.approx(
        run.kv_bytes_moved)


# --- hypothesis: bytes charged == Σ footprints at migration -------------------

def test_total_bytes_charged_equals_boundary_deltas():
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")

    KVB = 8.0
    PUS = ("cpu", "gpu", "npu")

    @hyp.given(st.lists(st.tuples(st.integers(0, 2),    # stream index
                                  st.integers(0, 2),    # pu index
                                  st.integers(0, 3)),   # op selector
                        min_size=1, max_size=60),
               st.lists(st.integers(0, 500), min_size=3, max_size=3))
    @hyp.settings(max_examples=60, deadline=None)
    def prop(ops, ctxs):
        kv = KVResidency(synthetic_perf(kv_bytes=KVB))
        nodes = [decode_node(f"q{i}/d", ctx=ctxs[i], workload=1 << 20)
                 for i in range(3)]
        expect_bytes, expect_migs = 0.0, 0
        shadow = {}     # stream -> (pu, ctx): independent reconstruction
        for si, pi, op in ops:
            m, pu = nodes[si], PUS[pi]
            cur = shadow.get(si)
            if op == 3 and cur is not None:
                kv.on_boundary(m, cur[0], 0, left=True)
                del shadow[si]
                continue
            if op in (0, 1):      # a round dispatch serving m on pu
                r = Node(f"r{si}", m.stage, "stream_decode", 16,
                         payload={"members": [m], "decode_round": True})
                if cur is None:
                    shadow[si] = (pu, ctxs[si])
                elif cur[0] != pu:
                    expect_bytes += cur[1] * KVB
                    expect_migs += 1
                    shadow[si] = (pu, cur[1])
                kv.migrate_for_dispatch(r, pu)
            else:                 # boundary: +16 tokens on pu
                if cur is None:
                    shadow[si] = (pu, ctxs[si] + 16)
                else:
                    shadow[si] = (pu, cur[1] + 16)
                kv.on_boundary(m, pu, 16)
        assert kv.migrations == expect_migs
        assert kv.bytes_moved == pytest.approx(expect_bytes)
        # terminal conservation: mark_done releases every stream (even
        # ones whose final boundary never fired), so nothing stays
        # registered once all streams have finished
        for m in nodes:
            kv.release(m)
        assert kv.resident_bytes() == 0.0

    prop()
