"""Preemptible fused dispatches + SLO-class admission + the typed
SessionOptions surface.

Covers the ISSUE 8 checklist: boundary-yield semantics (the fused split
keeps executed work and releases the tail), the preemption-cheaper-than-
cancellation pricing invariant, residency-aware re-placement of released
members, class-aware Eq. 5 gate piercing, bit-exactness with the new
subsystems off, sim/live preemption-counter parity, user-facing
cancellation, and deprecation-shim equivalence of the old HeroSession
kwargs with SessionOptions.
"""
import time

import pytest

from repro.api import HeroSession, SessionOptions
from repro.api.session import make_world
from repro.core import DynamicDAG, HeroScheduler, SchedulerConfig, Simulator
from repro.core.dag import Node
from repro.core.kv_residency import KVResidency
from repro.core.partitioner import fused_boundary_index
from repro.rag import default_means, sample_traces


@pytest.fixture(scope="module")
def traces():
    return sample_traces("hotpotqa", 8, seed=11)


@pytest.fixture(scope="module")
def means(traces):
    return default_means(traces)


# --- boundary-yield semantics ------------------------------------------------

def test_fused_boundary_index_picks_next_member_boundary():
    # nothing executed yet: the in-progress (first) member still finishes
    assert fused_boundary_index([400, 8], 0.0) == 1
    # mid-first-member: the boundary after it is the next one
    assert fused_boundary_index([400, 8], 0.5) == 1
    # past the first member's share: it is done, keep through the second
    assert fused_boundary_index([400, 8], 0.99) == 2
    assert fused_boundary_index([10, 10, 10], 0.34) == 2
    # finished (or over): nothing left to release
    assert fused_boundary_index([10, 10, 10], 1.0) == 3
    assert fused_boundary_index([10, 10, 10], 7.0) == 3
    assert fused_boundary_index([], 0.5) == 1   # degenerate: keep >= 1


def test_preempt_fused_releases_tail_with_state_in_place():
    dag = DynamicDAG()
    ms = [dag.add(Node(f"q{i}/embed", "embed", "batchable", 16 * (i + 1)))
          for i in range(3)]
    fused = dag.fuse_ready(ms)
    dag.mark_running(fused.id, 1.0, ("cpu", 32))
    released = dag.preempt_fused(fused, 1, prefer_pu="cpu")
    assert [m.id for m in released] == ["q1/embed", "q2/embed"]
    for m in released:
        assert m.status == "ready"
        assert "fused_into" not in m.payload
        assert m.payload["preemptions"] == 1
        assert m.payload["preempt_prefer_pu"] == "cpu"
    # the kept slice shrank to the kept member's workload and completes
    # only for it
    assert fused.workload == 16
    assert fused.payload["members"] == [ms[0]]
    dag.mark_done(fused.id, 2.0)
    assert ms[0].status == "done"
    assert ms[1].status == "ready" and ms[2].status == "ready"
    # splitting past the last member releases nothing
    fused2 = dag.fuse_ready([ms[1], ms[2]])
    dag.mark_running(fused2.id, 3.0, ("cpu", 32))
    assert dag.preempt_fused(fused2, 5) == []
    assert len(fused2.payload["members"]) == 2


# --- pricing invariant -------------------------------------------------------

def test_preemption_priced_strictly_cheaper_than_cancellation():
    soc, gt, perf = make_world("sd8gen4", "qwen3")
    sched = HeroScheduler(perf, [p.name for p in soc.pus], soc.dram_bw,
                          SchedulerConfig(coalesce=True, preempt=True))
    dag = DynamicDAG()
    a = dag.add(Node("q0/embed", "embed", "batchable", 64))
    b = dag.add(Node("q1/embed", "embed", "batchable", 64))
    fused = dag.fuse_ready([a, b])
    dag.mark_running(fused.id, 2.0, ("cpu", 32))
    for now in (2.0, 2.5, 10.0):
        pre = sched.preempt_price(fused, now)
        can = sched.cancel_price(fused, now)
        assert pre < can, (now, pre, can)
    # cancellation discards completed work: its price grows with runtime
    assert sched.cancel_price(fused, 10.0) > sched.cancel_price(fused, 2.5)
    # preemption keeps it: price does not
    assert sched.preempt_price(fused, 10.0) == sched.preempt_price(fused,
                                                                   2.5)


# --- residency-aware re-placement --------------------------------------------

def _symmetric_sched(perf, pus, **cfg):
    return HeroScheduler(perf, pus, 100.0,
                         SchedulerConfig(coalesce=True, preempt=True, **cfg))


def test_replacement_prefers_kv_resident_pu():
    """Two identical PUs score identically, so only the preemption
    re-placement nudge can break the tie — and it must anchor to the KV
    tracker's resident PU, overriding the split-point stamp."""
    from repro.core import tpu_v5e_slices
    soc, gt, perf = make_world(tpu_v5e_slices({"s0": 8, "s1": 8}), "qwen3")
    # s1 first in the PU list: without the nudge the strict-< argmin
    # keeps the first candidate, so a win for s0 is the nudge's doing
    sched = _symmetric_sched(perf, ["s1", "s0"], kv_residency=True)
    dag = DynamicDAG()
    n = dag.add(Node("q0/embed", "embed", "batchable", 32))
    n.payload["preempt_prefer_pu"] = "s1"       # split off s1 ...
    sched.kv.on_boundary(n, "s0", 64)           # ... but KV resides on s0
    assert sched.kv.resident_pu(n) == "s0"
    [d] = sched.dispatch_pass(dag, 0.0, ["s1", "s0"], 0.0)
    assert d.pu == "s0"
    # without tracked residency the stamp itself is the anchor
    sched2 = _symmetric_sched(perf, ["s1", "s0"])
    dag2 = DynamicDAG()
    n2 = dag2.add(Node("q0/embed", "embed", "batchable", 32))
    n2.payload["preempt_prefer_pu"] = "s0"
    [d2] = sched2.dispatch_pass(dag2, 0.0, ["s1", "s0"], 0.0)
    assert d2.pu == "s0"
    # and with no stamp at all, first-wins stands (the nudge is inert)
    sched3 = _symmetric_sched(perf, ["s1", "s0"])
    dag3 = DynamicDAG()
    dag3.add(Node("q0/embed", "embed", "batchable", 32))
    [d3] = sched3.dispatch_pass(dag3, 0.0, ["s1", "s0"], 0.0)
    assert d3.pu == "s1"


# --- class-aware Eq. 5 gate ---------------------------------------------------

def _classed_sched(perf, soc, classes):
    sched = HeroScheduler(perf, [p.name for p in soc.pus], soc.dram_bw,
                          SchedulerConfig(coalesce=True, slo_admission=True))
    sched.slo_classes = classes
    return sched


def test_slo_class_resolution_and_gate_piercing():
    soc, gt, perf = make_world("sd8gen4", "qwen3")
    sched = _classed_sched(perf, soc, {"q0": "batch", "q1": "interactive"})
    batch_n = Node("q0/chat", "chat", "stream_decode", 64,
                   status="running", config=("gpu", 8))
    inter_n = Node("q1/chat", "chat", "stream_decode", 64)
    assert sched._slo_rank(batch_n) == 0
    assert sched._slo_rank(inter_n) == 1
    # payload stamp wins over the query map; unknown queries default
    # interactive
    stamped = Node("q1/x", "chat", "stream_decode", 8,
                   payload={"slo": "batch"})
    assert sched._slo_rank(stamped) == 0
    assert sched._slo_rank(Node("q9/x", "chat", "stream_decode", 8)) == 1
    # a fused node ranks as its most sensitive member
    fused = Node("f", "chat", "stream_decode", 64,
                 payload={"members": [batch_n, inter_n]})
    assert sched._slo_rank(fused) == 1
    # interactive candidate pierces the gate a batch v* would impose
    assert sched._gate_for(inter_n, batch_n, batch_n, False) is None
    # equal-class traffic keeps the classic gate
    peer = Node("q1/embed", "embed", "batchable", 16)
    assert sched._gate_for(peer, inter_n, inter_n, False) is inter_n
    # batch candidate loses the batched-mode stand-down: it faces the
    # gate of the running interactive critical node
    inter_star = Node("q1/chat2", "chat", "stream_decode", 64,
                      status="running", config=("gpu", 8))
    assert sched._gate_for(batch_n, None, inter_star, True) is inter_star
    # ... but not of running io / config-less work
    io_star = Node("q1/admit", "admit", "io", 1, status="running",
                   config=("io", 1))
    assert sched._gate_for(batch_n, None, io_star, True) is None
    # slo_admission off: dispatch path never calls this (gate_v falls
    # back to gate_star verbatim) — guarded by the bit-exactness test


def test_batch_defers_while_interactive_waits_until_floor():
    soc, gt, perf = make_world("sd8gen4", "qwen3")
    sched = _classed_sched(perf, soc, {"q0": "batch", "q1": "interactive"})
    dag = DynamicDAG()
    b = dag.add(Node("q0/embed", "embed", "batchable", 16))
    i = dag.add(Node("q1/embed", "embed", "batchable", 16))
    idle = [p.name for p in soc.pus]
    # interactive waiting + no batch tau yet -> defer
    sched._ready_since[b.id] = 0.0
    assert sched._defer_batch(b, [b, i], idle, now=5.0)
    # nothing interactive waiting -> no deferral (no starvation for its
    # own sake)
    assert not sched._defer_batch(b, [b], idle, now=5.0)
    # waited past the floor (slo_floor_mult x batch-class tau) -> admit
    sched.arrivals.observe(("slo", "batch"), 0.0)
    sched.arrivals.observe(("slo", "batch"), 1.0)
    tau = sched.arrivals.tau(("slo", "batch"))
    assert tau is not None
    long_wait = sched.cfg.slo_floor_mult * tau + 1.0
    assert not sched._defer_batch(b, [b, i], idle, now=long_wait)
    assert sched._defer_batch(b, [b, i], idle,
                              now=0.5 * sched.cfg.slo_floor_mult * tau)


# --- bit-exactness with the new subsystems off -------------------------------

def test_slo_labels_inert_without_slo_admission(traces, means):
    """Submitting slo=/deadline= labels must not perturb scheduling while
    ``slo_admission``/``preempt`` are off — the whole new surface has to
    be dormant by default (the PR 2/PR 3 goldens pin the rest)."""
    def run(labelled):
        sess = HeroSession(world="sd8gen4", family="qwen3", means=means,
                           options=SessionOptions(coalesce=True))
        for qi, tr in enumerate(traces[:6]):
            kw = ({"slo": ("batch" if qi % 2 else "interactive"),
                   "deadline": 500.0} if labelled else {})
            sess.submit(tr, wf=1, arrival_time=qi * 0.25, **kw)
        return [r.makespan for r in sess.run()]

    assert run(False) == run(True)


def test_preempt_off_runs_emit_no_preemptions(traces, means):
    sess = HeroSession(world="sd8gen4", family="qwen3", means=means,
                       options=SessionOptions(coalesce=True))
    for qi, tr in enumerate(traces[:4]):
        sess.submit(tr, wf=1, slo="batch" if qi % 2 else "interactive")
    res = sess.run()
    assert sess.last_run.preemptions == 0
    assert all(r.preemptions == 0 for r in res)
    assert [r.slo_class for r in res] == ["interactive", "batch"] * 2


# --- sim/live preemption parity ----------------------------------------------

def _preempt_scenario(perf, dram_bw):
    """One PU; a long batch-class fused embed dispatch (two members, the
    second tiny) is in flight when an interactive query's admission timer
    fires — the scheduler must flag the split, and the boundary (true
    progress is well inside member one) releases exactly the tail member.
    Deterministic on both substrates."""
    dag = DynamicDAG()
    dag.add(Node("q0/embed", "embed", "batchable", 400))
    dag.add(Node("q1/embed", "embed", "batchable", 8))
    gate = dag.add(Node("q2/admit", "admit", "io", 1,
                        payload={"arrival": 0.05}))
    dag.add(Node("q2/embed", "embed", "batchable", 64, deps={gate.id}))
    sched = HeroScheduler(perf, ["cpu"], dram_bw,
                          SchedulerConfig(coalesce=True, preempt=True,
                                          slo_admission=True))
    sched.slo_classes = {"q0": "batch", "q1": "batch", "q2": "interactive"}
    return dag, sched


def test_sim_live_preemption_counter_parity():
    soc, gt, perf = make_world("sd8gen4", "qwen3")
    # sim
    dag_s, sched_s = _preempt_scenario(perf, soc.dram_bw)
    res = Simulator(gt, sched_s).run(dag_s)
    sim_preempts = sum(1 for e in res.timeline if e[1] == "preempt")
    assert not dag_s.unfinished()
    # live (wall clock: the fused sleep outlives the timer, so the split
    # lands mid-flight exactly as in the sim)
    from repro.serving.executor import HeroRuntime, PUExecutor

    dag_l, sched_l = _preempt_scenario(perf, soc.dram_bw)
    ex = {"cpu": PUExecutor("cpu")}
    rt = HeroRuntime(sched_l, ex,
                     {"embed": lambda n, b: time.sleep(0.4)})
    try:
        rt.run(dag_l, timeout=30.0)
    finally:
        for x in ex.values():
            x.shutdown()
    live_preempts = sum(1 for e in rt.events if e[1] == "preempt")
    assert sim_preempts == live_preempts == 1
    for d in (dag_s, dag_l):
        # payload attribution matches the event count, and the released
        # member re-ran to completion
        assert sum(n.payload.get("preemptions", 0)
                   for n in d.nodes.values()) == 1
        assert d.nodes["q1/embed"].payload["preemptions"] == 1
        assert d.nodes["q1/embed"].status == "done"


def test_session_payload_preemptions_sum_to_backend_total(means):
    """End-to-end through HeroSession on the sim backend: saturating
    batch traffic + later interactive arrivals forces splits, and the
    per-query attributed counts sum to the BackendRun total."""
    trs = sample_traces("finqabench", 6, seed=3)
    # two PUs keep batch fusions in flight long enough that the later
    # interactive arrivals always find them blocking
    sess = HeroSession(world="sd8gen4", family="qwen3", means=means,
                       pus=["cpu", "gpu"],
                       options=SessionOptions(coalesce=True, preempt=True,
                                              slo_admission=True))
    for qi, tr in enumerate(trs):
        interactive = qi >= 4
        sess.submit(tr, wf=1,
                    slo="interactive" if interactive else "batch",
                    arrival_time=1.5 if interactive else 0.0)
    res = sess.run()
    total = sess.last_run.preemptions
    assert total > 0, "scenario produced no preemptions"
    assert sum(r.preemptions for r in res) == total
    assert all(r.preemptions == 0 for r in res if r.slo_class
               == "interactive")


# --- cancellation ------------------------------------------------------------

def test_cancel_before_run_drops_query(means):
    trs = sample_traces("finqabench", 2, seed=9)
    sess = HeroSession(world="sd8gen4", family="qwen3", means=means)
    h0 = sess.submit(trs[0], wf=1)
    h1 = sess.submit(trs[1], wf=1)
    h1.cancel()
    res = sess.run()
    assert [r.qid for r in res] == [h0.qid]


def test_cancel_mid_run_collapses_query_on_sim(means):
    trs = sample_traces("finqabench", 3, seed=9)
    sess = HeroSession(world="sd8gen4", family="qwen3", means=means,
                       options=SessionOptions(coalesce=True))
    handles = {}

    def on_done(h, node, t):
        # first completed stage of q0 withdraws q1 mid-run
        if not handles["h1"].cancelled:
            handles["h1"].cancel()

    h0 = sess.submit(trs[0], wf=1, on_stage_done=on_done)
    handles["h1"] = sess.submit(trs[1], wf=1)
    h2 = sess.submit(trs[2], wf=1)
    res = sess.run()
    by_qid = {r.qid: r for r in res}
    assert by_qid[handles["h1"].qid].cancelled
    assert not by_qid[h0.qid].cancelled and not by_qid[h2.qid].cancelled
    # the cancelled query's chain was reaped, not executed to completion
    assert by_qid[handles["h1"].qid].finish_time <= by_qid[h0.qid].finish_time
    assert sum(1 for e in sess.last_run.events if e[1] == "cancelled") > 0
    # surviving queries still ran fully
    assert by_qid[h0.qid].n_nodes > 0 and by_qid[h2.qid].n_nodes > 0


def test_cancel_mid_run_on_live_backend(means):
    trs = sample_traces("finqabench", 2, seed=9)
    sess = HeroSession(world="sd8gen4", family="qwen3", means=means,
                       backend="live")
    handles = {}

    def on_done(h, node, t):
        if not handles["h1"].cancelled:
            handles["h1"].cancel()

    sess.submit(trs[0], wf=1, on_stage_done=on_done)
    handles["h1"] = sess.submit(trs[1], wf=1)
    res = sess.run(timeout=60)
    assert {r.cancelled for r in res} == {False, True}


def test_deadline_met_reported(means):
    trs = sample_traces("finqabench", 2, seed=1)
    sess = HeroSession(world="sd8gen4", family="qwen3", means=means)
    sess.submit(trs[0], wf=1, deadline=1e6)
    sess.submit(trs[1], wf=1, deadline=1e-6)
    met, missed = sess.run()
    assert met.deadline_met is True
    assert missed.deadline_met is False
    # no deadline -> None
    sess.submit(trs[0], wf=1)
    [r] = sess.run()
    assert r.deadline_met is None


def test_reset_clears_last_run_and_handles(means):
    trs = sample_traces("finqabench", 1, seed=1)
    sess = HeroSession(world="sd8gen4", family="qwen3", means=means)
    h = sess.submit(trs[0], wf=1)
    sess.run()
    assert sess.last_run is not None
    sess.submit(trs[0], wf=1)
    sess.reset()
    assert sess.last_run is None
    assert sess.queries == []
    assert h._dag is None


# --- SessionOptions + deprecation shims --------------------------------------

def test_session_options_validates_combinations():
    with pytest.raises(ValueError, match="kv_prefetch"):
        SessionOptions(kv_prefetch=True)
    with pytest.raises(ValueError, match="preempt"):
        SessionOptions(preempt=True)
    with pytest.raises(ValueError, match="batch_policy"):
        SessionOptions(batch_policy="magic")
    with pytest.raises(ValueError, match="not.*SchedulerConfig"):
        SessionOptions(cfg_overrides={"no_such_knob": 1})
    # effective values: a typed requirement satisfied via cfg_overrides
    # is accepted (and vice versa rejected)
    SessionOptions(kv_prefetch=True, cfg_overrides={"kv_pages": True})
    SessionOptions(preempt=True, coalesce=True)
    with pytest.raises(ValueError):
        SessionOptions(cfg_overrides={"kv_prefetch": True})


def test_session_options_scheduler_overrides_precedence():
    assert SessionOptions().scheduler_overrides() == {}
    opts = SessionOptions(coalesce=True, batch_policy="adaptive",
                          cfg_overrides={"straggler_factor": 2.5,
                                         "coalesce": False})
    ov = opts.scheduler_overrides()
    # the typed field wins over the same key in cfg_overrides
    assert ov["coalesce"] is True
    assert ov["batch_policy"] == "adaptive"
    assert ov["straggler_factor"] == 2.5


def test_deprecated_kwargs_warn_and_match_options(traces, means):
    def run(sess):
        for qi, tr in enumerate(traces[:4]):
            sess.submit(tr, wf=1, arrival_time=qi * 0.25)
        return [r.makespan for r in sess.run()]

    with pytest.warns(DeprecationWarning, match="deprecated"):
        legacy = HeroSession(world="sd8gen4", family="qwen3", means=means,
                             coalesce=True, batch_policy="adaptive")
    typed = HeroSession(world="sd8gen4", family="qwen3", means=means,
                        options=SessionOptions(coalesce=True,
                                               batch_policy="adaptive"))
    assert run(legacy) == run(typed)
    # the shim and the typed path resolve to the same scheduler patch
    assert legacy.cfg_overrides == typed.cfg_overrides
    # both surfaces at once with DISAGREEING values is ambiguous
    with pytest.warns(DeprecationWarning):
        with pytest.raises(ValueError, match="not both"):
            HeroSession(world="sd8gen4", family="qwen3", coalesce=True,
                        options=SessionOptions())
    # ...but a kwarg merely repeating the options= value is redundant,
    # not fatal (ported callers that still forward old kwargs keep
    # working) — PR 9 regression: this combination used to raise
    with pytest.warns(DeprecationWarning, match="redundant"):
        sess = HeroSession(
            world="sd8gen4", family="qwen3", coalesce=True,
            options=SessionOptions(coalesce=True, batch_policy="adaptive"))
    assert sess.options.batch_policy == "adaptive"
    # invalid combos surface at construction, not deep in the scheduler
    with pytest.warns(DeprecationWarning):
        with pytest.raises(ValueError, match="kv_prefetch"):
            HeroSession(world="sd8gen4", family="qwen3", kv_prefetch=True)


def test_submit_validates_slo_and_deadline(means):
    sess = HeroSession(world="sd8gen4", family="qwen3", means=means)
    tr = sample_traces("finqabench", 1, seed=1)[0]
    with pytest.raises(ValueError, match="slo"):
        sess.submit(tr, wf=1, slo="bulk")
    with pytest.raises(ValueError, match="deadline"):
        sess.submit(tr, wf=1, deadline=0.0)
