"""Per-kernel validation: shape/dtype sweeps, interpret=True vs the pure-jnp
oracles in kernels/ref.py."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention
from repro.kernels.flash_attention import flash_attention
from repro.kernels.int8_matmul import int8_matmul, quantize_int8
from repro.kernels.mamba2_scan import ssd_chunk
from repro.kernels.topk_retrieval import topk_retrieval


def _tol(dtype):
    return dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 \
        else dict(atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("sq,sk,h,n,e", [
    (128, 128, 8, 4, 64),
    (256, 128, 4, 4, 128),
    (64, 192, 8, 2, 64),
    (128, 128, 8, 8, 128),   # MHA (no grouping)
])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(sq, sk, h, n, e, causal, dtype):
    if causal and sq > sk:
        pytest.skip("causal needs sq <= sk alignment in this sweep")
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (2, sq, h, e), dtype)
    k = jax.random.normal(jax.random.fold_in(key, 1), (2, sk, n, e), dtype)
    v = jax.random.normal(jax.random.fold_in(key, 2), (2, sk, n, e), dtype)
    out = flash_attention(q, k, v, causal=causal, block_q=64, block_k=64,
                          interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


@pytest.mark.parametrize("S,h,n,e,bk", [
    (256, 8, 4, 64, 64),
    (512, 16, 2, 128, 128),
    (128, 4, 4, 64, 128),    # bk > S
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_sweep(S, h, n, e, bk, dtype):
    b = 3
    key = jax.random.PRNGKey(1)
    q = jax.random.normal(key, (b, h, e), dtype)
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, S, n, e), dtype)
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, S, n, e), dtype)
    lengths = jnp.array([S, S // 2, 7], jnp.int32)
    out = decode_attention(q, k, v, lengths, block_k=bk, interpret=True)
    want = ref.decode_attention_ref(q, k, v, lengths)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


@pytest.mark.parametrize("M,K,N,bm,bn,bk", [
    (128, 256, 192, 64, 64, 64),
    (64, 64, 64, 64, 64, 64),
    (256, 128, 512, 128, 256, 128),
])
def test_int8_matmul_sweep(M, K, N, bm, bn, bk):
    key = jax.random.PRNGKey(2)
    x = jax.random.normal(key, (M, K))
    w = jax.random.normal(jax.random.fold_in(key, 1), (K, N))
    xq, sx = quantize_int8(x, axis=1)
    wq, sw = quantize_int8(w, axis=0)
    out = int8_matmul(xq, wq, sx, sw, block_m=bm, block_n=bn, block_k=bk,
                      interpret=True)
    want = ref.int8_matmul_ref(xq, wq, sx, sw)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), atol=1e-2)
    # int8 quantized matmul approximates the f32 product
    dense = x @ w
    rel = float(jnp.abs(out.astype(jnp.float32) - dense).mean()
                / jnp.abs(dense).mean())
    assert rel < 0.05


@pytest.mark.parametrize("nq,N,d,k,bq,bn", [
    (16, 1000, 64, 8, 8, 256),
    (8, 512, 128, 16, 8, 128),
    (32, 300, 32, 4, 16, 512),   # bn > N
])
def test_topk_sweep(nq, N, d, k, bq, bn):
    key = jax.random.PRNGKey(3)
    q = jax.random.normal(key, (nq, d))
    c = jax.random.normal(jax.random.fold_in(key, 1), (N, d))
    vals, idxs = topk_retrieval(q, c, k, block_q=bq, block_n=bn,
                                interpret=True)
    wv, wi = ref.topk_retrieval_ref(q, c, k)
    np.testing.assert_allclose(np.asarray(vals), np.asarray(wv), atol=1e-4)
    assert (np.asarray(idxs) == np.asarray(wi)).all()


@pytest.mark.parametrize("b,nc,Q,H,P,N", [
    (2, 3, 32, 4, 16, 8),
    (1, 2, 64, 8, 32, 16),
    (2, 1, 16, 2, 8, 8),
])
def test_ssd_chunk_sweep(b, nc, Q, H, P, N):
    key = jax.random.PRNGKey(4)
    x = jax.random.normal(key, (b, nc, Q, H, P))
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(key, 1),
                                           (b, nc, Q, H)))
    B = jax.random.normal(jax.random.fold_in(key, 2), (b, nc, Q, H, N))
    C = jax.random.normal(jax.random.fold_in(key, 3), (b, nc, Q, H, N))
    dA = -dt * 0.5
    y, S = ssd_chunk(x, dt, B, C, dA, interpret=True)
    wy, wS = ref.ssd_chunk_ref(x, dt, B, C, dA)
    np.testing.assert_allclose(np.asarray(y), np.asarray(wy), atol=2e-4,
                               rtol=2e-4)
    np.testing.assert_allclose(np.asarray(S), np.asarray(wS), atol=2e-4,
                               rtol=2e-4)
