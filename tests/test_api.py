"""HeroSession facade tests: backend parity, continuous multi-query
admission, declarative WorkflowSpec round-trips, and the four-strategy
quickstart path."""
import pytest

from repro.api import HeroSession, LiveBackend, SimBackend
from repro.api.spec import (BranchGroup, BranchStage, CollectorSpec,
                            StageSpec, WorkflowSpec, builtin_spec)
from repro.rag import STAGE_ROLES, default_means, sample_traces


@pytest.fixture(scope="module")
def traces():
    return sample_traces("finqabench", 4, seed=5)


# --- backend parity ---------------------------------------------------------

@pytest.mark.parametrize("backend", ["sim", "live"])
def test_w1_completes_on_both_backends(traces, backend):
    """The same session script runs against either substrate."""
    sess = HeroSession(world="sd8gen4", family="qwen3", backend=backend)
    sess.submit(traces[0], wf=1)
    [res] = sess.run(timeout=120)
    assert res.backend == backend
    assert res.makespan > 0
    # same DAG on both substrates: W1 is six stages, no dynamic branches
    assert res.n_nodes >= 6
    assert set(res.stage_latency) >= {"embed", "vsearch", "rerank",
                                      "chat_prefill", "chat_decode"}
    assert res.dispatches >= res.n_nodes


def test_sim_live_same_dag_shape(traces):
    """Sim and live execute the *same* spec-derived graph: every perf
    stage the sim run touched, the live run touches too."""
    by_backend = {}
    for backend in ("sim", "live"):
        sess = HeroSession(backend=backend)
        sess.submit(traces[1], wf=1)
        [res] = sess.run(timeout=120)
        by_backend[backend] = res
    assert (set(by_backend["sim"].stage_latency)
            == set(by_backend["live"].stage_latency))
    assert by_backend["sim"].n_nodes >= 6
    assert by_backend["live"].n_nodes >= 6


# --- continuous multi-query admission ---------------------------------------

def test_staggered_arrival_not_started_early(traces):
    sess = HeroSession()
    sess.submit(traces[0], wf=1)
    late = sess.submit(traces[1], wf=1, arrival_time=6.0)
    r0, r1 = sess.run()
    assert late.prefix == "q1/"
    # no stage of the late query may start before its arrival time
    starts = [t for t, ev, nid in sess.last_run.events
              if ev == "start" and nid.startswith("q1/")
              and not nid.startswith("q1/admit")]
    assert starts and min(starts) >= 6.0 - 1e-9
    assert r1.arrival_time == 6.0
    assert r1.makespan == pytest.approx(r1.finish_time - 6.0)
    # the early query was admitted immediately
    assert r0.finish_time > 0 and r0.arrival_time == 0.0


def test_shared_dag_merges_queries(traces):
    sess = HeroSession()
    for tr in traces[:3]:
        sess.submit(tr, wf=1)
    results = sess.run()
    assert [r.qid for r in results] == [0, 1, 2]
    # merged execution: every query completes, total span bounded by the
    # sum of isolated runs
    iso = HeroSession()
    for tr in traces[:3]:
        iso.submit(tr, wf=1)
    iso_results = iso.run(mode="isolated")
    assert max(r.finish_time for r in results) \
        <= sum(r.makespan for r in iso_results) * 1.05


def test_live_staggered_arrival(traces):
    sess = HeroSession(backend="live")
    sess.submit(traces[0], wf=1)
    sess.submit(traces[1], wf=1, arrival_time=0.25)
    r0, r1 = sess.run(timeout=60)
    starts = [t for t, ev, nid in sess.last_run.events
              if ev == "start" and nid.startswith("q1/")
              and not nid.startswith("q1/admit")]
    # wall-clock gating is best-effort but never early
    assert starts and min(starts) >= 0.25 - 1e-3


# --- declarative workflow specs ---------------------------------------------

def test_custom_spec_round_trip(traces):
    """User-defined workflow: spec -> DAG -> template, then executed
    end-to-end through the session on both backends."""
    spec = WorkflowSpec(
        "summarize-each-doc",
        statics=(
            StageSpec("embed_docs", "embed", "batchable",
                      lambda v: v.n_chunks, role="embed"),
            StageSpec("plan_prefill", "plan_prefill", "stream_prefill",
                      lambda v: v.query_tokens, role="search_llm"),
            StageSpec("plan_decode", "plan_decode", "stream_decode",
                      lambda v: v.plan_tokens, deps=("plan_prefill",),
                      role="search_llm"),
        ),
        groups=(BranchGroup(
            source="plan_decode", count=lambda v: v.n_docs, label="d{i}",
            progressive=True,
            stages=(BranchStage("summ_prefill_{i}", "refine_prefill",
                                "stream_prefill",
                                lambda v: v.context_tokens // 4,
                                deps=("$source", "embed_docs"),
                                template="summ_prefill"),
                    BranchStage("summ_decode_{i}", "refine_decode",
                                "stream_decode",
                                lambda v: v.refine_tokens,
                                deps=("$prev",),
                                template="summ_decode")),
        ),),
        collector=CollectorSpec(base_dep="embed_docs"))

    tr = traces[2]
    # DAG: statics materialized, branches deferred until plan_decode runs
    dag = spec.build_dag(tr)
    assert "embed_docs" in dag.nodes and "chat_decode" in dag.nodes
    assert not any(n.startswith("summ_prefill") for n in dag.nodes)
    assert dag.nodes["plan_decode"].expander is not None

    # template derived from the SAME spec
    tmpl = spec.build_template(tr)
    assert {"embed_docs", "plan_decode", "summ_prefill", "summ_decode",
            "refine_prefill", "chat_decode"} <= set(tmpl.stages)
    assert tmpl.stages["summ_prefill"].prob == tr.n_docs
    assert tmpl.stages["summ_decode"].deps == {"summ_prefill"}
    assert "summ_decode" in tmpl.stages["refine_prefill"].deps

    # end-to-end on both substrates
    for backend in ("sim", "live"):
        sess = HeroSession(backend=backend)
        sess.submit(tr, spec=spec)
        [res] = sess.run(timeout=120)
        # the dynamic branches actually spawned
        assert res.n_nodes > len(spec.statics)
        assert "refine_decode" in res.stage_latency


def test_builtin_specs_match_legacy_builders(traces):
    """rag.workflow's builders are thin wrappers over the specs."""
    from repro.rag import build_workflow, make_template
    tr = traces[0]
    means = default_means(traces)
    for wf in (1, 2, 3):
        spec = builtin_spec(wf)
        a = build_workflow(wf, tr, fine_grained=True)
        b = spec.build_dag(tr, fine_grained=True)
        assert set(a.nodes) == set(b.nodes)
        assert {n.id: n.workload for n in a.nodes.values()} \
            == {n.id: n.workload for n in b.nodes.values()}
        ta, tb = make_template(wf, means), spec.build_template(means)
        assert set(ta.stages) == set(tb.stages)
        assert spec.stage_roles().items() <= STAGE_ROLES.items()


# --- strategies / quickstart path -------------------------------------------

def test_four_strategies_via_session(traces):
    """The quickstart comparison: all four §6.1 strategies through the
    facade, HeRo fastest."""
    means = default_means(traces)
    lat = {}
    for strategy in ("llamacpp_gpu", "powerserve_npu", "ayo_like", "hero"):
        sess = HeroSession(world="sd8gen4", family="qwen3",
                           strategy=strategy, means=means)
        sess.submit(traces[0], wf=2)
        [res] = sess.run()
        lat[strategy] = res.makespan
        assert res.makespan > 0 and res.redispatches == 0
    assert lat["hero"] < min(lat[s] for s in lat if s != "hero")


def test_streaming_callbacks(traces):
    got = {"tokens": 0, "stages": []}
    sess = HeroSession()
    sess.submit(traces[0], wf=2,
                on_token=lambda h, n, t: got.__setitem__(
                    "tokens", got["tokens"] + n),
                on_stage_done=lambda h, node, t: got["stages"].append(
                    node.stage))
    [res] = sess.run()
    # every answer token streamed, in token-group granularity
    assert got["tokens"] == traces[0].answer_tokens
    assert len(got["stages"]) == res.n_nodes


def test_session_backend_instances(traces):
    """Backend objects (not just names) plug in: custom fault-injected sim."""
    sess = HeroSession(backend=SimBackend(HeroSession().gt,
                                          straggler_prob=1.0,
                                          straggler_slow=50.0, seed=1))
    sess.submit(traces[0], wf=1)
    [res] = sess.run()
    assert res.redispatches >= 1

    sess = HeroSession(backend=LiveBackend())
    sess.submit(traces[0], wf=1)
    [res] = sess.run(timeout=60)
    assert res.backend == "live"
