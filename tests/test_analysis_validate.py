"""repro.analysis.validate — the builtin W1-W3 specs (and their built
DAGs) validate clean; handcrafted broken specs trip each issue code; the
``SessionOptions.validate_spec`` wiring surfaces errors before a run."""
import warnings

import pytest

from repro.analysis.validate import (SpecValidationError, ensure_valid,
                                     validate_dag, validate_spec)
from repro.api.options import SessionOptions
from repro.api.spec import (BranchGroup, BranchStage, CollectorSpec,
                            DecodeSpec, StageSpec, WorkflowSpec,
                            builtin_spec)
from repro.core.dag import DynamicDAG, Node
from repro.rag import sample_traces


@pytest.fixture(scope="module")
def trace():
    return sample_traces("hotpotqa", 1, seed=11)[0]


def _codes(issues):
    return sorted(i.code for i in issues)


def _spec(statics, groups=(), collector=None, name="t"):
    return WorkflowSpec(name=name, statics=tuple(statics),
                        groups=tuple(groups), collector=collector)


def _chain(*ids_kinds):
    """Linear prefill->decode chain helper: [(id, stage, kind), ...]."""
    out, prev = [], None
    for sid, stage, kind in ids_kinds:
        out.append(StageSpec(id=sid, stage=stage, kind=kind, workload=8,
                             deps=(prev,) if prev else ()))
        prev = sid
    return out


GOOD = _chain(("embed", "embed", "batchable"),
              ("pf", "chat_prefill", "stream_prefill"),
              ("dc", "chat_decode", "stream_decode"))


# --- builtin specs and DAGs validate clean -----------------------------------

@pytest.mark.parametrize("wf", [1, 2, 3])
def test_builtin_specs_clean(wf, trace):
    spec = builtin_spec(wf)
    with warnings.catch_warnings():
        warnings.simplefilter("error", RuntimeWarning)
        assert ensure_valid(spec=spec) == []
        assert ensure_valid(dag=spec.build_dag(trace)) == []


def test_build_dag_validate_kwarg(trace):
    # the SessionOptions.validate_spec wiring point
    dag = builtin_spec(1).build_dag(trace, validate=True)
    assert dag.nodes


def test_session_option_runs_validation(trace):
    from repro.api import HeroSession
    sess = HeroSession(world="sd8gen4", family="qwen3",
                       options=SessionOptions(validate_spec=True))
    sess.submit(trace, wf=1)
    [res] = sess.run()
    assert res.makespan > 0


# --- spec-level error codes --------------------------------------------------

def test_s001_duplicate_static_id():
    s = GOOD[0]
    assert "S001" in _codes(validate_spec(_spec([s, s])))


def test_s002_unknown_dep():
    bad = GOOD[:2] + [StageSpec(id="dc", stage="chat_decode",
                                kind="stream_decode", workload=8,
                                deps=("missing",))]
    assert "S002" in _codes(validate_spec(_spec(bad)))


def test_s003_dependency_cycle():
    a = StageSpec(id="a", stage="embed", kind="batchable", workload=1,
                  deps=("b",))
    b = StageSpec(id="b", stage="rerank", kind="batchable", workload=1,
                  deps=("a",))
    issues = validate_spec(_spec([a, b] + GOOD))
    assert "S003" in _codes(issues)


def test_s004_unknown_group_source():
    g = BranchGroup(source="nope", count=2, stages=(
        BranchStage(id="b{i}", stage="embed", kind="batchable",
                    workload=1, deps=("$source",), template="b"),))
    assert "S004" in _codes(validate_spec(_spec(GOOD, groups=[g])))


def test_s005_bad_branch_dep_token():
    g = BranchGroup(source="embed", count=2, stages=(
        BranchStage(id="b{i}", stage="embed", kind="batchable",
                    workload=1, deps=("$prev",), template="b"),))
    issues = validate_spec(_spec(GOOD, groups=[g]))
    assert "S005" in _codes(issues)       # $prev on the first branch stage
    g2 = BranchGroup(source="embed", count=2, stages=(
        BranchStage(id="b{i}", stage="embed", kind="batchable",
                    workload=1, deps=("$sorce",), template="b"),))
    assert "S005" in _codes(validate_spec(_spec(GOOD, groups=[g2])))


def test_s006_branch_id_without_placeholder():
    g = BranchGroup(source="embed", count=2, stages=(
        BranchStage(id="branch", stage="embed", kind="batchable",
                    workload=1, deps=("$source",), template="b"),))
    assert "S006" in _codes(validate_spec(_spec(GOOD, groups=[g])))


def test_s007_unknown_collector_base_dep():
    col = CollectorSpec(base_dep="nope")
    assert "S007" in _codes(validate_spec(_spec(GOOD, collector=col)))


def test_s008_draft_pins_on_non_decode_stage():
    bad = GOOD[:2] + [StageSpec(
        id="dc", stage="chat_decode", kind="stream_decode", workload=8,
        deps=("pf",))]
    bad[0] = StageSpec(id="embed", stage="embed", kind="batchable",
                       workload=1, decode=DecodeSpec(draft_width=4))
    assert "S008" in _codes(validate_spec(_spec(bad)))


# --- spec-level warnings -----------------------------------------------------

def test_w101_shared_ctx_off_convention():
    bad = [StageSpec(id="pf", stage="summarize", kind="stream_prefill",
                     workload=64, shared_ctx=32),
           StageSpec(id="dc", stage="chat_decode", kind="stream_decode",
                     workload=8, deps=("pf",))]
    assert "W101" in _codes(validate_spec(_spec(bad)))
    # DecodeSpec.kv_stage override silences it
    ok = [StageSpec(id="pf", stage="summarize", kind="stream_prefill",
                    workload=64, shared_ctx=32,
                    decode=DecodeSpec(kv_stage="chat_decode")),
          bad[1]]
    assert "W101" not in _codes(validate_spec(_spec(ok)))


def test_w103_prefill_decode_family_mismatch():
    bad = [StageSpec(id="pf", stage="refine_prefill", kind="stream_prefill",
                     workload=64),
           StageSpec(id="dc", stage="chat_decode", kind="stream_decode",
                     workload=8, deps=("pf",))]
    assert "W103" in _codes(validate_spec(_spec(bad)))


def test_w104_collector_convention_mismatch():
    col = CollectorSpec(base_dep="embed", refine_prefill="refine_prefill",
                        refine_decode="chat_decode")
    assert "W104" in _codes(validate_spec(_spec(GOOD, collector=col)))


def test_w105_dangling_static():
    dangling = GOOD + [StageSpec(id="orphan", stage="rerank",
                                 kind="batchable", workload=4)]
    assert "W105" in _codes(validate_spec(_spec(dangling)))
    assert "W105" not in _codes(validate_spec(_spec(GOOD)))


# --- graph-level codes -------------------------------------------------------

def test_d001_dag_cycle():
    dag = DynamicDAG()
    dag.add(Node("a", "embed", "batchable", 1))
    dag.add(Node("b", "rerank", "batchable", 1, deps={"a"}))
    dag.nodes["a"].deps.add("b")     # forged after add() to make a cycle
    assert "D001" in _codes(validate_dag(dag))


def test_d002_unknown_dep_in_graph():
    dag = DynamicDAG()
    dag.add(Node("a", "embed", "batchable", 1))
    dag.nodes["a"].deps.add("ghost")
    assert "D002" in _codes(validate_dag(dag))


def test_d003_no_coalesce_with_batch_pu():
    dag = DynamicDAG()
    dag.add(Node("a", "chat_decode", "stream_decode", 8,
                 payload={"no_coalesce": True, "batch_pu": "gpu"}))
    assert "D003" in _codes(validate_dag(dag))


def test_d004_round_without_members():
    dag = DynamicDAG()
    dag.add(Node("r", "chat_decode", "stream_decode", 8,
                 payload={"decode_round": True}))
    assert "D004" in _codes(validate_dag(dag))


def test_d005_negative_kv_ctx():
    dag = DynamicDAG()
    dag.add(Node("a", "chat_decode", "stream_decode", 8,
                 payload={"kv_ctx": -4}))
    assert "D005" in _codes(validate_dag(dag))


def test_clean_dag_validates(trace):
    assert validate_dag(builtin_spec(2).build_dag(trace)) == []


# --- enforcement semantics ---------------------------------------------------

def test_ensure_valid_raises_on_errors_warns_on_warnings():
    s = GOOD[0]
    with pytest.raises(SpecValidationError) as ei:
        ensure_valid(spec=_spec([s, s]))
    assert any(i.code == "S001" for i in ei.value.issues)
    dangling = GOOD + [StageSpec(id="orphan", stage="rerank",
                                 kind="batchable", workload=4)]
    with pytest.warns(RuntimeWarning, match="W105"):
        ensure_valid(spec=_spec(dangling))


def test_session_surfaces_spec_error_before_run(trace):
    from repro.api import HeroSession
    s = GOOD[0]
    sess = HeroSession(world="sd8gen4", family="qwen3",
                       options=SessionOptions(validate_spec=True))
    sess.submit(trace, spec=_spec([s, s]))
    with pytest.raises(SpecValidationError):
        sess.run()
