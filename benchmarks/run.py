"""Benchmark driver — one section per paper table/figure.
Prints ``name,us_per_call,derived``-style CSV blocks per section.

    python benchmarks/run.py --list          # enumerate sections
    python benchmarks/run.py --only Serving  # run matching sections only
    python benchmarks/run.py --quick         # reduced sweeps
"""
from __future__ import annotations

import os
import sys
import time

# make ``python benchmarks/run.py`` work from a checkout: the script's dir
# is on sys.path but the ``benchmarks`` package root (repo root) is not
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))


def session_facade(csv=print):
    """Facade smoke: the same session script on both backends (sim gives
    modelled SoC seconds, live gives wall seconds over dry executors)."""
    from repro.api import HeroSession
    from repro.rag import sample_traces

    trace = sample_traces("finqabench", 1, seed=2)[0]
    csv("backend,strategy,makespan_s,dispatches")
    for backend in ("sim", "live"):
        for strategy in ("hero", "llamacpp_gpu"):
            sess = HeroSession(world="sd8gen4", family="qwen3",
                               strategy=strategy, backend=backend)
            sess.submit(trace, wf=2)
            [res] = sess.run(timeout=120)
            csv(f"{backend},{strategy},{res.makespan:.3f},{res.dispatches}")


def main() -> None:
    import argparse

    from benchmarks import (fig2_affinity, fig3_contention, fig5_qwen3,
                            fig6_bge, grid_search, kernels_bench,
                            multiquery, roofline, table3_ablation)
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="reduced sweeps for the big sections")
    ap.add_argument("--list", action="store_true",
                    help="print section names and exit")
    ap.add_argument("--only", metavar="SUBSTR",
                    help="run only sections whose name contains SUBSTR "
                         "(case-insensitive)")
    args = ap.parse_args()
    quick = args.quick
    sections = [
        ("SessionFacade_sim_live (api)", session_facade, {}),
        ("Fig2_affinity_shape_sensitivity", fig2_affinity.run, {}),
        ("Fig3_contention_slowdown", fig3_contention.run, {}),
        ("Fig5_e2e_latency_qwen3", fig5_qwen3.run,
         {"n": 2, "datasets": ("finqabench", "2wikimqa")} if quick else {}),
        ("Fig6_e2e_latency_bge", fig6_bge.run,
         {"n": 2, "datasets": ("finqabench", "2wikimqa")} if quick else {}),
        ("Table3_technique_ablation", table3_ablation.run,
         {"n": 2} if quick else {}),
        ("GridSearch_alpha_beta (paper §5)", grid_search.run,
         {"n": 2} if quick else {}),
        ("MultiQuery_throughput (beyond-paper)", multiquery.run_admission,
         {}),
        ("Serving_continuous_batching (bench-smoke gate)",
         multiquery.serving_metrics, {}),
        ("Serving_prefix_cache (paged-KV bench-smoke leg)",
         multiquery.serving_metrics, {"regimes": ("prefix",)}),
        ("Serving_spec_decode (specdec bench-smoke leg)",
         multiquery.serving_metrics, {"regimes": ("specdec",)}),
        ("Serving-ablation_adaptive_vs_fixed_caps (CI gate)",
         multiquery.serving_ablation, {}),
        ("Kernel_microbench", kernels_bench.run, {}),
        ("Roofline_from_dryrun", roofline.run, {}),
    ]
    if args.list:
        for name, _, _ in sections:
            print(name)
        return
    only = args.only.lower() if args.only else None
    for name, fn, kwargs in sections:
        if only is not None and only not in name.lower():
            continue
        print(f"\n=== {name} ===")
        t0 = time.time()
        fn(**kwargs)
        print(f"# section wall time: {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()
