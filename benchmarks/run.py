"""Benchmark driver — one section per paper table/figure.
Prints ``name,us_per_call,derived``-style CSV blocks per section."""
from __future__ import annotations

import sys
import time


def main() -> None:
    from benchmarks import (fig2_affinity, fig3_contention, fig5_qwen3,
                            fig6_bge, grid_search, kernels_bench,
                            multiquery, roofline, table3_ablation)
    quick = "--quick" in sys.argv
    sections = [
        ("Fig2_affinity_shape_sensitivity", fig2_affinity.run, {}),
        ("Fig3_contention_slowdown", fig3_contention.run, {}),
        ("Fig5_e2e_latency_qwen3", fig5_qwen3.run,
         {"n": 2, "datasets": ("finqabench", "2wikimqa")} if quick else {}),
        ("Fig6_e2e_latency_bge", fig6_bge.run,
         {"n": 2, "datasets": ("finqabench", "2wikimqa")} if quick else {}),
        ("Table3_technique_ablation", table3_ablation.run,
         {"n": 2} if quick else {}),
        ("GridSearch_alpha_beta (paper §5)", grid_search.run,
         {"n": 2} if quick else {}),
        ("MultiQuery_throughput (beyond-paper)", multiquery.run_all, {}),
        ("Kernel_microbench", kernels_bench.run, {}),
        ("Roofline_from_dryrun", roofline.run, {}),
    ]
    for name, fn, kwargs in sections:
        print(f"\n=== {name} ===")
        t0 = time.time()
        fn(**kwargs)
        print(f"# section wall time: {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()
