"""Paper Fig. 2: stage–accelerator affinity and workload shape sensitivity.

Emits, per (stage, PU), the profiled latency curve over batch size —
reproducing both claims: indexing/reranking run much faster on NPU while
LLM generation favours the GPU, and per-item efficiency is non-monotone
in batch size.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import make_world


def run(csv=print):
    soc, gt, perf = make_world("sd8gen4", "qwen3")
    batches = [1, 2, 4, 8, 16, 32, 64, 128, 256]
    csv("stage,pu,batch,p0_ms,per_item_ms,bandwidth_gbs")
    rows = []
    for stage in ("embed", "rerank", "chat_prefill", "chat_decode"):
        for pu in ("cpu", "gpu", "npu"):
            if not perf.supported(stage, pu):
                continue
            for n in batches:
                p0 = perf.p0(stage, pu, n)
                bw = perf.bandwidth(stage, pu, n)
                rows.append((stage, pu, n, p0, p0 / n, bw))
                csv(f"{stage},{pu},{n},{p0 * 1e3:.3f},"
                    f"{p0 / n * 1e3:.4f},{bw / 1e9:.2f}")
    # derived claims
    e_npu = perf.p0("embed", "npu", 32)
    e_gpu = perf.p0("embed", "gpu", 32)
    d_gpu = perf.p0("chat_decode", "gpu", 16)
    d_npu = perf.p0("chat_decode", "npu", 16)
    csv(f"# claim: embed NPU speedup over GPU = {e_gpu / e_npu:.1f}x "
        f"(paper: 'much faster on NPUs')")
    csv(f"# claim: decode GPU speedup over NPU = {d_npu / d_gpu:.2f}x "
        f"(paper: 'generation stages favor GPUs')")
    # shape sensitivity: per-item latency non-monotone on npu
    per_item = [perf.p0("embed", "npu", n) / n for n in batches]
    best = int(np.argmin(per_item))
    csv(f"# claim: npu embed per-item optimum at batch={batches[best]} "
        f"(larger batches are {per_item[-1] / per_item[best]:.2f}x worse "
        f"per item)")
    return rows


def main():
    run()


if __name__ == "__main__":
    main()
