"""Beyond-paper: multi-request orchestration throughput.

The paper optimizes single-query latency (mobile).  At pod scale, a
server admits several concurrent RAG queries; through ``HeroSession``
this is one facade call — the shared DynamicDAG holds every query
subgraph and the criticality/concurrency machinery arbitrates between
them.  Three admission regimes are compared:

- sequential   : one query at a time (sum of isolated makespans);
- merged_dag   : all queries admitted at t=0;
- staggered    : queries arrive on a fixed inter-arrival grid (continuous
                 admission — later queries join the running DAG via
                 arrival-gated timer nodes).

``serving_metrics`` is the serving ablation behind CI's ``bench-smoke``
gate: saturated + staggered regimes comparing plain HeRo, stage
coalescing only, and coalescing + continuous decode batching, reporting
throughput and p50/p99 per-query latency (``--bench-out`` writes the
JSON artifact the regression gate diffs against its committed baseline).
"""
from __future__ import annotations

import numpy as np

from repro.api import HeroSession
from repro.core import tpu_v5e_slices
from repro.rag import default_means, sample_traces


def run(csv=print, k: int = 3, wf: int = 2, dataset: str = "hotpotqa",
        world: str = "sd8gen4", inter_arrival: float = 2.0):
    if world == "tpu_pod":
        # pod carved into 6 PU slices: many more lanes than one query needs
        soc = tpu_v5e_slices({"s0": 8, "s1": 8, "s2": 16, "s3": 32,
                              "s4": 64, "s5": 128})
    else:
        soc = world
    traces = sample_traces(dataset, k, seed=11)
    means = default_means(traces)

    def session():
        return HeroSession(world=soc, family="qwen3", strategy="hero",
                           means=means)

    # sequential: sum of single-query makespans
    sess = session()
    for tr in traces:
        sess.submit(tr, wf=wf)
    seq = float(sum(r.makespan for r in sess.run(mode="isolated")))

    # merged: all queries admitted at t=0 into one shared DAG
    sess = session()
    for tr in traces:
        sess.submit(tr, wf=wf)
    merged_res = sess.run()
    merged = float(max(r.finish_time for r in merged_res))
    merged_lat = float(np.mean([r.makespan for r in merged_res]))

    # staggered: continuous admission, one query every `inter_arrival` s
    sess = session()
    for qi, tr in enumerate(traces):
        sess.submit(tr, wf=wf, arrival_time=qi * inter_arrival)
    stag_res = sess.run()
    stag_total = float(max(r.finish_time for r in stag_res))
    stag_lat = float(np.mean([r.makespan for r in stag_res]))

    csv("world,mode,queries,total_s,throughput_qps,mean_query_s")
    csv(f"{world},sequential,{k},{seq:.2f},{k / seq:.3f},{seq / k:.2f}")
    csv(f"{world},merged_dag,{k},{merged:.2f},{k / merged:.3f},"
        f"{merged_lat:.2f}")
    csv(f"{world},staggered,{k},{stag_total:.2f},{k / stag_total:.3f},"
        f"{stag_lat:.2f}")
    csv(f"# {world}: merged-DAG throughput gain {seq / merged:.2f}x")
    return seq, merged


# serving scheduler variants: plain HeRo, stage coalescing only (the PR 2
# lever), and coalescing + continuous decode batching (the full serving mode)
VARIANTS = (
    ("hero", dict(coalesce=False)),
    ("hero+coalesce", dict(coalesce=True,
                           cfg_overrides={"decode_batch": False})),
    ("hero+decode_batch", dict(coalesce=True)),
)


def _variant_metrics(world, means, traces, wf, inter_arrival, kw) -> dict:
    k = len(traces)
    sess = HeroSession(world=world, family="qwen3", strategy="hero",
                       means=means, **kw)
    for qi, tr in enumerate(traces):
        sess.submit(tr, wf=wf, arrival_time=qi * inter_arrival)
    res = sess.run()
    lats = np.array([r.makespan for r in res])
    total = float(max(r.finish_time for r in res))
    return {"total": total, "throughput": k / total,
            "p50": float(np.percentile(lats, 50)),
            "p99": float(np.percentile(lats, 99)),
            "coalesced": int(sum(r.coalesced_nodes for r in res)),
            "decode_rounds": int(sum(r.decode_rounds for r in res))}


# the two regimes the bench-smoke CI gate tracks: saturating arrivals (the
# continuous-batching stress case — queries arrive far below the per-query
# service time, so ready sets overlap at every scheduling point) and a
# wider staggered grid (the continuous-admission case); both on the sim
# backend so CI is deterministic
SERVING_REGIMES = {
    "saturated": dict(k=8, wf=1, inter_arrival=0.25),
    "staggered": dict(k=8, wf=1, inter_arrival=2.0),
}


def serving_metrics(world: str = "sd8gen4", dataset: str = "hotpotqa",
                    csv=print) -> dict:
    """The serving benchmark behind CI's ``bench-smoke`` gate: every
    (regime, scheduler-variant) cell with p50/p99/makespan/throughput."""
    out = {}
    for regime, cfg in SERVING_REGIMES.items():
        traces = sample_traces(dataset, cfg["k"], seed=11)
        means = default_means(traces)
        cells = out[regime] = {}
        csv(f"# regime={regime} (k={cfg['k']}, wf=w{cfg['wf']}, "
            f"inter_arrival={cfg['inter_arrival']}s)")
        csv("world,scheduler,total_s,p50_s,p99_s,throughput_qps,"
            "decode_rounds")
        for label, kw in VARIANTS:
            row = cells[label] = _variant_metrics(
                world, means, traces, cfg["wf"], cfg["inter_arrival"], kw)
            csv(f"{world},{label},{row['total']:.2f},{row['p50']:.2f},"
                f"{row['p99']:.2f},{row['throughput']:.3f},"
                f"{row['decode_rounds']}")
        gain = (cells["hero+decode_batch"]["throughput"]
                / cells["hero"]["throughput"])
        csv(f"# {world}/{regime}: serving throughput gain {gain:.2f}x, p99 "
            f"{cells['hero']['p99']:.2f}s -> "
            f"{cells['hero+decode_batch']['p99']:.2f}s")
    return out


def write_serving_bench(path: str, world: str = "sd8gen4",
                        dataset: str = "hotpotqa", csv=print) -> dict:
    """Run :func:`serving_metrics` and write the BENCH_serving.json
    artifact the CI regression gate compares against its committed
    baseline."""
    import json

    blob = {"world": world, "dataset": dataset,
            "regimes": serving_metrics(world, dataset, csv=csv)}
    with open(path, "w") as f:
        json.dump(blob, f, indent=1, sort_keys=True)
    csv(f"# wrote {path}")
    return blob


def run_admission(csv=print, **kw):
    """The admission-regime comparison alone (no serving ablation) — what
    ``benchmarks/run.py``'s MultiQuery section runs; the serving cells live
    in their own section so the saturated sweep is never paid twice."""
    run(csv)                            # mobile SoC: saturated by one query
    return run(csv, world="tpu_pod", k=6)   # pod slices: concurrency pays


def run_all(csv=print, **kw):
    out = run_admission(csv)
    serving_metrics(csv=csv)            # batching pays once queries pile up
    return out


def main():
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--bench-out", metavar="PATH",
                    help="write the BENCH_serving.json artifact for the CI "
                         "perf gate instead of running the full comparison")
    args = ap.parse_args()
    if args.bench_out:
        write_serving_bench(args.bench_out)
        return
    run_all()


if __name__ == "__main__":
    main()
