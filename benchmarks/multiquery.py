"""Beyond-paper: multi-request orchestration throughput.

The paper optimizes single-query latency (mobile).  At pod scale, a
server admits several concurrent RAG queries; through ``HeroSession``
this is one facade call — the shared DynamicDAG holds every query
subgraph and the criticality/concurrency machinery arbitrates between
them.  Three admission regimes are compared:

- sequential   : one query at a time (sum of isolated makespans);
- merged_dag   : all queries admitted at t=0;
- staggered    : queries arrive on a fixed inter-arrival grid (continuous
                 admission — later queries join the running DAG via
                 arrival-gated timer nodes).

``run_saturated`` is the cross-query coalescing ablation: a
saturating-arrival regime (queries arrive faster than the single-query
service rate, so same-stage ready work from different queries piles up)
comparing the plain HeRo scheduler against ``coalesce=True``, reporting
throughput and p50/p99 per-query latency.
"""
from __future__ import annotations

import numpy as np

from repro.api import HeroSession
from repro.core import tpu_v5e_slices
from repro.rag import default_means, sample_traces


def run(csv=print, k: int = 3, wf: int = 2, dataset: str = "hotpotqa",
        world: str = "sd8gen4", inter_arrival: float = 2.0):
    if world == "tpu_pod":
        # pod carved into 6 PU slices: many more lanes than one query needs
        soc = tpu_v5e_slices({"s0": 8, "s1": 8, "s2": 16, "s3": 32,
                              "s4": 64, "s5": 128})
    else:
        soc = world
    traces = sample_traces(dataset, k, seed=11)
    means = default_means(traces)

    def session():
        return HeroSession(world=soc, family="qwen3", strategy="hero",
                           means=means)

    # sequential: sum of single-query makespans
    sess = session()
    for tr in traces:
        sess.submit(tr, wf=wf)
    seq = float(sum(r.makespan for r in sess.run(mode="isolated")))

    # merged: all queries admitted at t=0 into one shared DAG
    sess = session()
    for tr in traces:
        sess.submit(tr, wf=wf)
    merged_res = sess.run()
    merged = float(max(r.finish_time for r in merged_res))
    merged_lat = float(np.mean([r.makespan for r in merged_res]))

    # staggered: continuous admission, one query every `inter_arrival` s
    sess = session()
    for qi, tr in enumerate(traces):
        sess.submit(tr, wf=wf, arrival_time=qi * inter_arrival)
    stag_res = sess.run()
    stag_total = float(max(r.finish_time for r in stag_res))
    stag_lat = float(np.mean([r.makespan for r in stag_res]))

    csv("world,mode,queries,total_s,throughput_qps,mean_query_s")
    csv(f"{world},sequential,{k},{seq:.2f},{k / seq:.3f},{seq / k:.2f}")
    csv(f"{world},merged_dag,{k},{merged:.2f},{k / merged:.3f},"
        f"{merged_lat:.2f}")
    csv(f"{world},staggered,{k},{stag_total:.2f},{k / stag_total:.3f},"
        f"{stag_lat:.2f}")
    csv(f"# {world}: merged-DAG throughput gain {seq / merged:.2f}x")
    return seq, merged


def run_saturated(csv=print, k: int = 8, wf: int = 1,
                  dataset: str = "hotpotqa", world: str = "sd8gen4",
                  inter_arrival: float = 0.25):
    """Coalescing ablation under saturating arrivals (k queries, one every
    ``inter_arrival`` s — far below the per-query service time, so the
    ready sets of different queries overlap at every scheduling point)."""
    traces = sample_traces(dataset, k, seed=11)
    means = default_means(traces)
    out = {}
    csv("world,scheduler,queries,total_s,throughput_qps,p50_s,p99_s,"
        "coalesced_nodes")
    for label, coalesce in (("hero", False), ("hero+coalesce", True)):
        sess = HeroSession(world=world, family="qwen3", strategy="hero",
                           means=means, coalesce=coalesce)
        for qi, tr in enumerate(traces):
            sess.submit(tr, wf=wf, arrival_time=qi * inter_arrival)
        res = sess.run()
        lats = np.array([r.makespan for r in res])
        total = float(max(r.finish_time for r in res))
        out[label] = {"total": total, "throughput": k / total,
                      "p50": float(np.percentile(lats, 50)),
                      "p99": float(np.percentile(lats, 99)),
                      "coalesced": sum(r.coalesced_nodes for r in res)}
        row = out[label]
        csv(f"{world},{label},{k},{total:.2f},{row['throughput']:.3f},"
            f"{row['p50']:.2f},{row['p99']:.2f},{row['coalesced']}")
    gain = out["hero+coalesce"]["throughput"] / out["hero"]["throughput"]
    csv(f"# {world}: coalescing throughput gain {gain:.2f}x at k={k}, "
        f"p99 {out['hero']['p99']:.2f}s -> {out['hero+coalesce']['p99']:.2f}s")
    return out


def run_all(csv=print, **kw):
    run(csv)                            # mobile SoC: saturated by one query
    run_saturated(csv)                  # coalescing pays once queries pile up
    return run(csv, world="tpu_pod", k=6)   # pod slices: concurrency pays


def main():
    run_all()


if __name__ == "__main__":
    main()
