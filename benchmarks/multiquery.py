"""Beyond-paper: multi-request orchestration throughput.

The paper optimizes single-query latency (mobile).  At pod scale, a
server admits several concurrent RAG queries; through ``HeroSession``
this is one facade call — the shared DynamicDAG holds every query
subgraph and the criticality/concurrency machinery arbitrates between
them.  Three admission regimes are compared:

- sequential   : one query at a time (sum of isolated makespans);
- merged_dag   : all queries admitted at t=0;
- staggered    : queries arrive on a fixed inter-arrival grid (continuous
                 admission — later queries join the running DAG via
                 arrival-gated timer nodes).

``serving_metrics`` is the serving benchmark behind CI's ``bench-smoke``
matrix: six regimes (saturated / staggered W1, a ``mixed`` regime
interleaving W1–W3 with an optional inter-arrival sweep, the
KV-``migration`` stress case, a shared-corpus ``prefix`` regime for
the paged-KV prefix cache, and an ``slo`` regime interleaving
interactive W1 with batch W3 under load — the class-aware admission +
preemption case, with per-class p50/p99 columns) × the scheduler
variants, reporting throughput, p50/p99 latency, and the batching
policy's chosen decode widths / token groups per cell.  Each CI matrix
leg runs ONE regime (``--regime``) and writes its own
``BENCH_serving.json`` artifact, which ``check_regression.py`` diffs
against the per-regime baseline under ``benchmarks/baselines/``.

``serving_ablation`` is the Table-3-style CI leg: adaptive caps vs fixed
caps vs batching off, failing (exit 1) if adaptive p99 regresses more
than 5% against the fixed-cap scheduler on any regime.
"""
from __future__ import annotations

import numpy as np

from repro.api import HeroSession, SessionOptions
from repro.core import tpu_v5e_slices
from repro.rag import default_means, sample_traces


def run(csv=print, k: int = 3, wf: int = 2, dataset: str = "hotpotqa",
        world: str = "sd8gen4", inter_arrival: float = 2.0):
    if world == "tpu_pod":
        # pod carved into 6 PU slices: many more lanes than one query needs
        soc = tpu_v5e_slices({"s0": 8, "s1": 8, "s2": 16, "s3": 32,
                              "s4": 64, "s5": 128})
    else:
        soc = world
    traces = sample_traces(dataset, k, seed=11)
    means = default_means(traces)

    def session():
        return HeroSession(world=soc, family="qwen3", strategy="hero",
                           means=means)

    # sequential: sum of single-query makespans
    sess = session()
    for tr in traces:
        sess.submit(tr, wf=wf)
    seq = float(sum(r.makespan for r in sess.run(mode="isolated")))

    # merged: all queries admitted at t=0 into one shared DAG
    sess = session()
    for tr in traces:
        sess.submit(tr, wf=wf)
    merged_res = sess.run()
    merged = float(max(r.finish_time for r in merged_res))
    merged_lat = float(np.mean([r.makespan for r in merged_res]))

    # staggered: continuous admission, one query every `inter_arrival` s
    sess = session()
    for qi, tr in enumerate(traces):
        sess.submit(tr, wf=wf, arrival_time=qi * inter_arrival)
    stag_res = sess.run()
    stag_total = float(max(r.finish_time for r in stag_res))
    stag_lat = float(np.mean([r.makespan for r in stag_res]))

    csv("world,mode,queries,total_s,throughput_qps,mean_query_s")
    csv(f"{world},sequential,{k},{seq:.2f},{k / seq:.3f},{seq / k:.2f}")
    csv(f"{world},merged_dag,{k},{merged:.2f},{k / merged:.3f},"
        f"{merged_lat:.2f}")
    csv(f"{world},staggered,{k},{stag_total:.2f},{k / stag_total:.3f},"
        f"{stag_lat:.2f}")
    csv(f"# {world}: merged-DAG throughput gain {seq / merged:.2f}x")
    return seq, merged


# serving scheduler variants: plain HeRo, stage coalescing only (the PR 2
# lever), coalescing + continuous decode batching under the PR 3 fixed
# caps, the full adaptive batching policy (caps/windows/groups derived
# online from the profiled grids — the serving default), and the adaptive
# policy with p99-aware (high-quantile) round scoring
VARIANTS = (
    ("hero", SessionOptions()),
    ("hero+coalesce", SessionOptions(
        coalesce=True, cfg_overrides={"decode_batch": False})),
    ("hero+decode_batch", SessionOptions(coalesce=True)),
    ("hero+adaptive", SessionOptions(coalesce=True,
                                     batch_policy="adaptive")),
    ("hero+adaptive-q", SessionOptions(
        coalesce=True, batch_policy="adaptive",
        cfg_overrides={"round_score": "quantile"})),
)

# the migration-heavy regime's variant set: the adaptive scheduler with
# KV-residency tracking on, priced by the legacy constant (the
# mischarging baseline — real transfers are charged but the scheduler
# still sees 10 ms per move) vs the modeled footprint ÷ link-bandwidth
# cost; the two legacy (physics-off) cells anchor the comparison
KV_VARIANTS = (
    ("hero+decode_batch", SessionOptions(coalesce=True)),
    ("hero+adaptive", SessionOptions(coalesce=True,
                                     batch_policy="adaptive")),
    ("hero+kv-const", SessionOptions(
        coalesce=True, batch_policy="adaptive",
        cfg_overrides={"kv_residency": True,
                       "migrate_pricing": "constant"})),
    ("hero+kv", SessionOptions(coalesce=True, batch_policy="adaptive",
                               kv_residency=True)),
    ("hero+pages", SessionOptions(coalesce=True, batch_policy="adaptive",
                                  kv_pages=True)),
    ("hero+prefetch", SessionOptions(coalesce=True,
                                     batch_policy="adaptive",
                                     kv_pages=True, kv_prefetch=True)),
)

# the prefix regime's variant set: fixed caps, the monolithic KV tracker
# (pages off — the comparator the structural claim is judged against),
# the paged subsystem whose cross-query prefix cache is the lever this
# regime exercises, and the paged subsystem with predictive tier
# prefetch (spill-resident hit pages staged under compute overlap)
PREFIX_VARIANTS = (
    ("hero+decode_batch", SessionOptions(coalesce=True)),
    ("hero+kv", SessionOptions(coalesce=True, batch_policy="adaptive",
                               kv_residency=True)),
    ("hero+pages", SessionOptions(coalesce=True, batch_policy="adaptive",
                                  kv_pages=True)),
    ("hero+prefetch", SessionOptions(coalesce=True,
                                     batch_policy="adaptive",
                                     kv_pages=True, kv_prefetch=True)),
)

# the SLO regime's variant set: fixed caps (the anchor every regime
# carries), the adaptive policy with the class machinery OFF (the
# comparator the structural claims are judged against — same traffic,
# same SLO labels, labels ignored), and the full class-aware scheduler
# (SLO admission + boundary-preemptible fused dispatches)
SLO_VARIANTS = (
    ("hero+decode_batch", SessionOptions(coalesce=True)),
    ("hero+adaptive", SessionOptions(coalesce=True,
                                     batch_policy="adaptive")),
    ("hero+slo", SessionOptions(coalesce=True, batch_policy="adaptive",
                                preempt=True, slo_admission=True)),
)

# the specdec regime's variant set: fixed caps (the anchor), the
# adaptive policy with speculation OFF (the comparator the structural
# claim is judged against — same traffic, same policy, no draft pairs),
# and the adaptive policy with scheduler-visible speculative decoding:
# every decode round may dispatch as a coupled (draft, verify) pair the
# Eq. 4 mapper can split across PUs
SPEC_VARIANTS = (
    ("hero+decode_batch", SessionOptions(coalesce=True)),
    ("hero+adaptive", SessionOptions(coalesce=True,
                                     batch_policy="adaptive")),
    ("hero+spec", SessionOptions(coalesce=True, batch_policy="adaptive",
                                 spec_decode=True)),
)

# batch-class throughput floor for the slo regime's structural claim:
# hero+slo may trade batch completion for interactive p99, but never
# below this fraction of the class-blind comparator's batch throughput
SLO_BATCH_FLOOR = 0.75


def _hist(d: dict) -> str:
    """``{16: 3, 4: 1}`` -> ``16:3|4:1`` (stable, CSV-safe)."""
    return "|".join(f"{k}:{v}" for k, v in sorted(d.items())) or "-"


def _variant_metrics(world, means, traces, wfs, inter_arrival, opts,
                     slo_mix: bool = False,
                     spec_cols: bool = False) -> dict:
    k = len(traces)
    sess = HeroSession(world=world, family="qwen3", strategy="hero",
                       means=means, options=opts)
    for qi, tr in enumerate(traces):
        wf = wfs[qi % len(wfs)]
        # slo regime: W1 queries are interactive traffic, everything
        # heavier is batch — labels are submitted for EVERY variant so
        # the class-blind comparators report the same per-class split
        slo = ("interactive" if wf == 1 else "batch") if slo_mix \
            else "interactive"
        sess.submit(tr, wf=wf, slo=slo,
                    arrival_time=qi * inter_arrival)
    res = sess.run(timeout=14400)
    lats = np.array([r.makespan for r in res])
    batching = sess.last_run.batching
    row = {"total": float(max(r.finish_time for r in res)),
            "throughput": k / float(max(r.finish_time for r in res)),
            "p50": float(np.percentile(lats, 50)),
            "p99": float(np.percentile(lats, 99)),
            "coalesced": int(sum(r.coalesced_nodes for r in res)),
            "decode_rounds": int(sum(r.decode_rounds for r in res)),
            # KV-residency telemetry: decode-stream cache moves and the
            # bytes they shipped (zero with the subsystem off)
            "kv_migrations": int(sess.last_run.kv_migrations),
            "kv_bytes": float(sess.last_run.kv_bytes_moved),
            # paged-KV telemetry: prefix-cache hits, the prefill tokens
            # they skipped, and tier evictions (zero with pages off)
            "kv_page_hits": int(sess.last_run.kv_page_hits),
            "kv_hit_tokens": int(sess.last_run.kv_hit_tokens),
            "kv_evictions": int(sess.last_run.kv_evictions),
            # prefetch + bugfix telemetry: staging groups issued, staged
            # pages the gather found resident, hits the hit-or-recompute
            # rule declined, and all-pinned capacity breaches (all zero
            # with the respective subsystems off)
            "kv_prefetches": int(sess.last_run.kv_prefetches),
            "kv_prefetch_hits": int(sess.last_run.kv_prefetch_hits),
            "kv_hit_declined": int(sess.last_run.kv_hit_declined),
            "kv_soft_overflows": int(sess.last_run.kv_soft_overflows),
            # chosen shapes per regime: the observable output of the
            # batching policy (widths/groups the scheduler actually ran)
            "decode_widths": dict(batching.get("decode_width", {})),
            "decode_groups": dict(batching.get("decode_group", {})),
            # members released from preempted fused dispatches (zero
            # unless the variant turns ``preempt`` on)
            "preemptions": int(sess.last_run.preemptions)}
    if slo_mix:
        def _pct(rs, q):
            return float(np.percentile([r.makespan for r in rs], q))

        ints = [r for r in res if r.slo_class == "interactive"]
        bats = [r for r in res if r.slo_class == "batch"]
        # batch throughput is judged on when the batch CLASS drains, so
        # deferral/preemption pushing batch work later is priced even
        # when overall total_s is carried by something else
        batch_total = max((r.finish_time for r in bats), default=0.0)
        row.update(
            int_p50=_pct(ints, 50), int_p99=_pct(ints, 99),
            batch_p50=_pct(bats, 50), batch_p99=_pct(bats, 99),
            batch_throughput=len(bats) / max(batch_total, 1e-9))
    if spec_cols:
        from repro.api import builtin_spec
        # decode tokens the workload demands (identical for every variant
        # of a regime — the denominator that makes token-rate comparable):
        # the sum of stream_decode workloads over each query's DAG
        dec_tok = 0
        for qi, tr in enumerate(traces):
            d = builtin_spec(wfs[qi % len(wfs)]).build_dag(tr)
            dec_tok += sum(n.workload for n in d.nodes.values()
                           if n.kind == "stream_decode")
        row.update(
            decode_tokens=int(dec_tok),
            decode_tok_rate=dec_tok / row["total"],
            drafted=int(sess.last_run.drafted_tokens),
            accepted=int(sess.last_run.accepted_tokens),
            spec_rounds=int(sess.last_run.spec_rounds),
            spec_widths=dict(batching.get("spec_width", {})))
    return row


# the bench-smoke CI matrix: saturating W1 arrivals (the continuous-
# batching stress case), a wider staggered W1 grid (continuous
# admission), a mixed regime interleaving W1-W3 — where no single fixed
# cap suits every decode stage, the case the adaptive policy exists for —
# and a migration-heavy regime: long-context W3 streams (sampled traces
# stretched by ctx/answer scale) under PU pressure, where decode KV
# footprints run to hundreds of MB and mispricing a PU move is visible
# in p99 — the cell KV-residency tracking exists for.  All on the sim
# backend so CI is deterministic.  A regime's ``variants`` replaces the
# default scheduler-variant set for that regime only.
SERVING_REGIMES = {
    "saturated": dict(k=8, wfs=(1,), inter_arrival=0.25),
    "staggered": dict(k=8, wfs=(1,), inter_arrival=2.0),
    "mixed": dict(k=9, wfs=(1, 2, 3), inter_arrival=0.5),
    "migration": dict(k=8, wfs=(3,), inter_arrival=1.0,
                      ctx_scale=4, answer_scale=6, variants=KV_VARIANTS),
    # prefix-reuse regime: a hot/cold serving mix — even-slot queries
    # cycle ``hot_corpora`` shared corpora (identical retrieved chunk
    # lists, so their chat prefills re-hit resident context pages),
    # odd-slot queries each bring a one-shot cold corpus whose pages are
    # dead weight after release.  Scaled contexts push the combined
    # working set past the PU arenas and the DRAM pool, so hot prefix
    # chains get demoted between reuses and the repeat prefill finds its
    # hits in a spill tier — the cross-query prefix-cache case the paged
    # subsystem exists for, and the spill-resident-hit case predictive
    # prefetch exists for
    "prefix": dict(k=16, wfs=(1,), inter_arrival=30.0,
                   shared_corpus=True, hot_corpora=2, ctx_scale=8,
                   variants=PREFIX_VARIANTS),
    # SLO-mix regime: interactive W1 queries interleaved with heavy batch
    # W3 queries under load — batch fusions monopolize PUs exactly when
    # an interactive arrival lands, the case class-aware admission
    # (batch stands aside while interactive waits, bounded by the
    # throughput floor) and boundary preemption (in-flight batch fusions
    # yield at the next member boundary) exist for.  Per-class p50/p99
    # and batch throughput are reported per cell
    "slo": dict(k=10, wfs=(1, 3), inter_arrival=0.5, slo_mix=True,
                variants=SLO_VARIANTS),
    # speculative-decoding regime: a decode-heavy W1 mix (answers
    # stretched so token generation dominates the makespan) under
    # spaced arrivals that leave a PU free for the draft stream — the
    # case spec decoding exists for: the small draft streams candidates
    # on a spare PU while the target verifies a whole group per weight
    # sweep.  Per-cell decode token-rate plus drafted/accepted totals
    # and the chosen draft widths are reported
    "specdec": dict(k=8, wfs=(1,), inter_arrival=2.0, answer_scale=6,
                    spec_cols=True, variants=SPEC_VARIANTS),
}

# the mixed regime's --arrival-sweep grid (inter-arrival seconds); the
# canonical mixed cell (0.5) is always present, the sweep adds the rest
ARRIVAL_SWEEP = (1.0, 2.0)


def serving_metrics(world: str = "sd8gen4", dataset: str = "hotpotqa",
                    csv=print, regimes=None, arrival_sweep: bool = False,
                    variants=VARIANTS) -> dict:
    """The serving benchmark behind CI's ``bench-smoke`` matrix: every
    (regime, scheduler-variant) cell with p50/p99/makespan/throughput and
    the chosen decode widths/groups.  ``regimes`` restricts to a subset
    (one CI matrix leg = one regime); ``arrival_sweep`` adds
    ``mixed@<ia>`` cells over :data:`ARRIVAL_SWEEP`; ``variants``
    restricts the scheduler variants simulated (the ablation leg skips
    the cells it never reads)."""
    todo = []
    for name, cfg in SERVING_REGIMES.items():
        if regimes is not None and name not in regimes:
            continue
        todo.append((name, cfg))
        if name == "mixed" and arrival_sweep:
            for ia in ARRIVAL_SWEEP:
                todo.append((f"mixed@{ia:g}", {**cfg, "inter_arrival": ia}))
    out = {}
    for regime, cfg in todo:
        if cfg.get("shared_corpus"):
            from repro.rag import shared_corpus_traces
            hot = cfg.get("hot_corpora", 0)
            if hot:
                # hot/cold mix: even slots cycle the hot shared corpora
                # (prefix reuse), odd slots are one-shot cold corpora
                # (eviction pressure + dead-weight victims)
                hots = [shared_corpus_traces(dataset, cfg["k"],
                                             seed=11 + s)
                        for s in range(hot)]
                traces, hi = [], 0
                for i in range(cfg["k"]):
                    if i % 2 == 0:
                        traces.append(hots[hi % hot][hi // hot])
                        hi += 1
                    else:
                        traces.append(shared_corpus_traces(
                            dataset, 1, seed=101 + i)[0])
            else:
                traces = shared_corpus_traces(dataset, cfg["k"], seed=11)
        else:
            traces = sample_traces(dataset, cfg["k"], seed=11)
        if cfg.get("ctx_scale") or cfg.get("answer_scale"):
            # the migration-heavy regime stretches the sampled traces:
            # long contexts grow the resident KV footprints, long answers
            # keep the streams resident while PU pressure builds
            import dataclasses as _dc
            traces = [_dc.replace(
                t,
                context_tokens=t.context_tokens * cfg.get("ctx_scale", 1),
                answer_tokens=t.answer_tokens * cfg.get("answer_scale", 1))
                for t in traces]
        means = default_means(traces)
        cells = out[regime] = {}
        wfs = cfg["wfs"]
        slo_mix = bool(cfg.get("slo_mix"))
        spec_cols = bool(cfg.get("spec_cols"))
        csv(f"# regime={regime} (k={cfg['k']}, "
            f"wf={'+'.join(f'w{w}' for w in wfs)}, "
            f"inter_arrival={cfg['inter_arrival']}s)")
        csv("world,scheduler,total_s,p50_s,p99_s,throughput_qps,"
            "decode_rounds,kv_migrations,kv_gb,page_hits,hit_tok,"
            "prefetches,prefetch_hits,widths,groups"
            + (",int_p50_s,int_p99_s,batch_p50_s,batch_p99_s,"
               "batch_qps,preemptions" if slo_mix else "")
            + (",decode_tok_s,drafted,accepted,spec_widths"
               if spec_cols else ""))
        for label, opts in cfg.get("variants", variants):
            row = cells[label] = _variant_metrics(
                world, means, traces, wfs, cfg["inter_arrival"], opts,
                slo_mix=slo_mix, spec_cols=spec_cols)
            csv(f"{world},{label},{row['total']:.2f},{row['p50']:.2f},"
                f"{row['p99']:.2f},{row['throughput']:.3f},"
                f"{row['decode_rounds']},{row['kv_migrations']},"
                f"{row['kv_bytes'] / 1e9:.2f},{row['kv_page_hits']},"
                f"{row['kv_hit_tokens']},{row['kv_prefetches']},"
                f"{row['kv_prefetch_hits']},{_hist(row['decode_widths'])},"
                f"{_hist(row['decode_groups'])}"
                + (f",{row['int_p50']:.2f},{row['int_p99']:.2f},"
                   f"{row['batch_p50']:.2f},{row['batch_p99']:.2f},"
                   f"{row['batch_throughput']:.3f},{row['preemptions']}"
                   if slo_mix else "")
                + (f",{row['decode_tok_rate']:.1f},{row['drafted']},"
                   f"{row['accepted']},{_hist(row['spec_widths'])}"
                   if spec_cols else ""))
        kvm, kvc = cells.get("hero+kv"), cells.get("hero+kv-const")
        if kvm and kvc:
            csv(f"# {world}/{regime}: modeled migration pricing p99 "
                f"{kvc['p99']:.2f}s -> {kvm['p99']:.2f}s "
                f"({kvc['kv_migrations']} moves/"
                f"{kvc['kv_bytes'] / 1e9:.2f} GB -> "
                f"{kvm['kv_migrations']} moves/"
                f"{kvm['kv_bytes'] / 1e9:.2f} GB)")
        pages, off = cells.get("hero+pages"), cells.get("hero+kv")
        if pages and off:
            csv(f"# {world}/{regime}: paged KV p99 {off['p99']:.2f}s -> "
                f"{pages['p99']:.2f}s ({pages['kv_page_hits']} page hits/"
                f"{pages['kv_hit_tokens']} prefill tokens skipped, "
                f"{pages['kv_evictions']} evictions)")
        pre_ = cells.get("hero+prefetch")
        if pre_ and pages:
            csv(f"# {world}/{regime}: predictive prefetch p99 "
                f"{pages['p99']:.4f}s -> {pre_['p99']:.4f}s "
                f"({pre_['kv_prefetches']} stagings/"
                f"{pre_['kv_prefetch_hits']} pages found resident at "
                "gather; overlap credit hides the spill fetch, so the "
                "delta is bounded by the tier traffic the run paid)")
        sp_on = cells.get("hero+spec")
        sp_off = cells.get("hero+adaptive") if spec_cols else None
        if sp_on and sp_off:
            rate = (sp_on["accepted"] / sp_on["drafted"]
                    if sp_on["drafted"] else 0.0)
            csv(f"# {world}/{regime}: speculative decoding token-rate "
                f"{sp_off['decode_tok_rate']:.1f} -> "
                f"{sp_on['decode_tok_rate']:.1f} tok/s "
                f"({sp_on['spec_rounds']} spec rounds, "
                f"{sp_on['drafted']} drafted / {sp_on['accepted']} "
                f"accepted, rate {rate:.2f}, widths "
                f"{_hist(sp_on['spec_widths'])})")
        son, soff = cells.get("hero+slo"), cells.get("hero+adaptive")
        if son and soff and slo_mix:
            csv(f"# {world}/{regime}: class-aware scheduling interactive "
                f"p99 {soff['int_p99']:.2f}s -> {son['int_p99']:.2f}s "
                f"({son['preemptions']} boundary splits); batch "
                f"throughput {soff['batch_throughput']:.3f} -> "
                f"{son['batch_throughput']:.3f} qps "
                f"(floor {SLO_BATCH_FLOOR:.0%} of class-blind)")
        if "hero+adaptive" not in cells or "hero" not in cells:
            continue
        gain = (cells["hero+adaptive"]["throughput"]
                / cells["hero"]["throughput"])
        csv(f"# {world}/{regime}: adaptive serving throughput gain "
            f"{gain:.2f}x, p99 {cells['hero']['p99']:.2f}s -> "
            f"{cells['hero+adaptive']['p99']:.2f}s "
            f"(fixed caps {cells['hero+decode_batch']['p99']:.2f}s)")
    return out


def write_serving_bench(path: str, world: str = "sd8gen4",
                        dataset: str = "hotpotqa", csv=print,
                        regimes=None, arrival_sweep: bool = False) -> dict:
    """Run :func:`serving_metrics` and write the BENCH_serving.json
    artifact the CI regression gate compares against the per-regime
    baseline under ``benchmarks/baselines/``."""
    import json

    blob = {"world": world, "dataset": dataset,
            "regimes": serving_metrics(world, dataset, csv=csv,
                                       regimes=regimes,
                                       arrival_sweep=arrival_sweep)}
    with open(path, "w") as f:
        json.dump(blob, f, indent=1, sort_keys=True)
    csv(f"# wrote {path}")
    return blob


# -- Table-3-style batching ablation (the CI ``serving-ablation`` leg) -----

ABLATION_TOL = 0.05     # adaptive p99 may trail fixed caps by at most 5%


def serving_ablation(csv=print, world: str = "sd8gen4",
                     dataset: str = "hotpotqa", tol: float = ABLATION_TOL,
                     strict: bool = True) -> dict:
    """Adaptive caps vs fixed caps vs batching off, per regime.

    The CI leg behind ``benchmarks/run.py --only serving-ablation``:
    fails (SystemExit 1) when ``strict`` and the adaptive policy's p99
    regresses more than ``tol`` against the fixed-cap scheduler on any
    regime — the acceptance bar that keeps the derived caps honest
    against the constants they replaced."""
    ablated = tuple((label, kw) for label, kw in VARIANTS
                    if label != "hero+coalesce")   # cells the gate reads
    cells = serving_metrics(world, dataset, csv=lambda *_: None,
                            variants=ablated)
    csv("regime,scheduler,p99_s,p50_s,total_s,delta_vs_fixed")
    violations = []
    for regime, row in cells.items():
        fixed = row["hero+decode_batch"]["p99"]
        for label in ("hero", "hero+decode_batch", "hero+adaptive",
                      "hero+adaptive-q", "hero+kv-const", "hero+kv",
                      "hero+pages", "hero+prefetch", "hero+slo",
                      "hero+spec"):
            if label not in row:   # per-regime variant sets differ
                continue
            p99 = row[label]["p99"]
            delta = (p99 / fixed - 1.0) * 100.0
            csv(f"{regime},{label},{p99:.2f},{row[label]['p50']:.2f},"
                f"{row[label]['total']:.2f},{delta:+.1f}%")
        if "hero+adaptive" in row:   # the prefix regime swaps this cell out
            adaptive = row["hero+adaptive"]["p99"]
            if adaptive > fixed * (1.0 + tol):
                violations.append(
                    f"{regime}: adaptive p99 {adaptive:.2f}s regresses "
                    f"{(adaptive / fixed - 1) * 100:.1f}% vs fixed-cap "
                    f"{fixed:.2f}s (> {tol * 100:.0f}% tolerance)")
    mixed = cells.get("mixed")
    if mixed and mixed["hero+adaptive"]["p99"] >= mixed["hero+decode_batch"]["p99"]:
        violations.append(
            "mixed: adaptive p99 no longer beats fixed caps "
            f"({mixed['hero+adaptive']['p99']:.2f}s vs "
            f"{mixed['hero+decode_batch']['p99']:.2f}s) — the regime the "
            "adaptive policy exists for")
    mig = cells.get("migration", {})
    kvm, kvc = mig.get("hero+kv"), mig.get("hero+kv-const")
    if kvm and kvc and kvm["p99"] >= kvc["p99"]:
        violations.append(
            "migration: modeled migration pricing p99 no longer beats "
            f"the constant ({kvm['p99']:.2f}s vs {kvc['p99']:.2f}s) — "
            "the regime KV-residency tracking exists for")
    pre = cells.get("prefix", {})
    pages, off = pre.get("hero+pages"), pre.get("hero+kv")
    if pages and off:
        if not pages["kv_page_hits"]:
            violations.append(
                "prefix: paged KV scored zero prefix-cache hits on the "
                "shared-corpus regime — the case the page table exists for")
        if pages["p99"] >= off["p99"]:
            violations.append(
                "prefix: paged KV p99 no longer beats the monolithic "
                f"tracker ({pages['p99']:.2f}s vs {off['p99']:.2f}s) on "
                "the shared-corpus regime")
    # the SessionOptions class-machinery cell: hero+slo must buy its
    # interactive p99 win without dropping batch throughput below the
    # declared floor — judged against the same-traffic class-blind
    # adaptive scheduler
    srow = cells.get("slo", {})
    s_on, s_off = srow.get("hero+slo"), srow.get("hero+adaptive")
    if s_on and s_off:
        if s_on["int_p99"] >= s_off["int_p99"]:
            violations.append(
                f"slo: hero+slo interactive p99 {s_on['int_p99']:.2f}s no "
                f"longer beats class-blind {s_off['int_p99']:.2f}s — the "
                "regime SLO admission + preemption exist for")
        if s_on["batch_throughput"] < \
                SLO_BATCH_FLOOR * s_off["batch_throughput"]:
            violations.append(
                f"slo: hero+slo batch throughput "
                f"{s_on['batch_throughput']:.3f} qps fell below "
                f"{SLO_BATCH_FLOOR:.0%} of class-blind "
                f"{s_off['batch_throughput']:.3f} qps")
    # the speculative-decoding cell: hero+spec must actually draft, and
    # its decode token-rate must strictly beat the same adaptive
    # scheduler with speculation off on the decode-heavy regime
    spd = cells.get("specdec", {})
    sp_on, sp_off = spd.get("hero+spec"), spd.get("hero+adaptive")
    if sp_on and sp_off:
        if not sp_on["drafted"]:
            violations.append(
                "specdec: hero+spec drafted zero candidate tokens — the "
                "decode-heavy regime speculation exists for")
        if sp_on["decode_tok_rate"] <= sp_off["decode_tok_rate"]:
            violations.append(
                f"specdec: hero+spec decode token-rate "
                f"{sp_on['decode_tok_rate']:.1f} tok/s no longer beats "
                f"spec-off {sp_off['decode_tok_rate']:.1f} tok/s")
    for v in violations:
        csv(f"# ABLATION GATE: {v}")
    if not violations:
        csv("# ablation gate OK: adaptive caps hold against fixed caps "
            f"on {len(cells)} regimes")
    if violations and strict:
        raise SystemExit(1)
    return cells


def run_admission(csv=print, **kw):
    """The admission-regime comparison alone (no serving ablation) — what
    ``benchmarks/run.py``'s MultiQuery section runs; the serving cells live
    in their own section so the saturated sweep is never paid twice."""
    run(csv)                            # mobile SoC: saturated by one query
    return run(csv, world="tpu_pod", k=6)   # pod slices: concurrency pays


def run_all(csv=print, **kw):
    out = run_admission(csv)
    serving_metrics(csv=csv)            # batching pays once queries pile up
    return out


def main():
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--bench-out", metavar="PATH",
                    help="write the BENCH_serving.json artifact for the CI "
                         "perf gate instead of running the full comparison")
    ap.add_argument("--regime", choices=sorted(SERVING_REGIMES) + ["all"],
                    default="all",
                    help="restrict the serving benchmark to one regime "
                         "(one CI matrix leg each; default: all)")
    ap.add_argument("--arrival-sweep", action="store_true",
                    help="add mixed@<inter-arrival> cells over "
                         f"{ARRIVAL_SWEEP} to the mixed regime")
    ap.add_argument("--ablation", action="store_true",
                    help="run the Table-3-style adaptive-vs-fixed-vs-off "
                         "ablation gate instead (exit 1 on >5% adaptive "
                         "p99 regression)")
    args = ap.parse_args()
    regimes = None if args.regime == "all" else (args.regime,)
    if args.ablation:
        serving_ablation()
        return
    if args.bench_out:
        write_serving_bench(args.bench_out, regimes=regimes,
                            arrival_sweep=args.arrival_sweep)
        return
    run_all()


if __name__ == "__main__":
    main()
