"""Beyond-paper: multi-request orchestration throughput.

The paper optimizes single-query latency (mobile).  At pod scale, a
server admits several concurrent RAG queries; through ``HeroSession``
this is one facade call — the shared DynamicDAG holds every query
subgraph and the criticality/concurrency machinery arbitrates between
them.  Three admission regimes are compared:

- sequential   : one query at a time (sum of isolated makespans);
- merged_dag   : all queries admitted at t=0;
- staggered    : queries arrive on a fixed inter-arrival grid (continuous
                 admission — later queries join the running DAG via
                 arrival-gated timer nodes).
"""
from __future__ import annotations

import numpy as np

from repro.api import HeroSession
from repro.core import tpu_v5e_slices
from repro.rag import default_means, sample_traces


def run(csv=print, k: int = 3, wf: int = 2, dataset: str = "hotpotqa",
        world: str = "sd8gen4", inter_arrival: float = 2.0):
    if world == "tpu_pod":
        # pod carved into 6 PU slices: many more lanes than one query needs
        soc = tpu_v5e_slices({"s0": 8, "s1": 8, "s2": 16, "s3": 32,
                              "s4": 64, "s5": 128})
    else:
        soc = world
    traces = sample_traces(dataset, k, seed=11)
    means = default_means(traces)

    def session():
        return HeroSession(world=soc, family="qwen3", strategy="hero",
                           means=means)

    # sequential: sum of single-query makespans
    sess = session()
    for tr in traces:
        sess.submit(tr, wf=wf)
    seq = float(sum(r.makespan for r in sess.run(mode="isolated")))

    # merged: all queries admitted at t=0 into one shared DAG
    sess = session()
    for tr in traces:
        sess.submit(tr, wf=wf)
    merged_res = sess.run()
    merged = float(max(r.finish_time for r in merged_res))
    merged_lat = float(np.mean([r.makespan for r in merged_res]))

    # staggered: continuous admission, one query every `inter_arrival` s
    sess = session()
    for qi, tr in enumerate(traces):
        sess.submit(tr, wf=wf, arrival_time=qi * inter_arrival)
    stag_res = sess.run()
    stag_total = float(max(r.finish_time for r in stag_res))
    stag_lat = float(np.mean([r.makespan for r in stag_res]))

    csv("world,mode,queries,total_s,throughput_qps,mean_query_s")
    csv(f"{world},sequential,{k},{seq:.2f},{k / seq:.3f},{seq / k:.2f}")
    csv(f"{world},merged_dag,{k},{merged:.2f},{k / merged:.3f},"
        f"{merged_lat:.2f}")
    csv(f"{world},staggered,{k},{stag_total:.2f},{k / stag_total:.3f},"
        f"{stag_lat:.2f}")
    csv(f"# {world}: merged-DAG throughput gain {seq / merged:.2f}x")
    return seq, merged


def run_all(csv=print, **kw):
    run(csv)                            # mobile SoC: saturated by one query
    return run(csv, world="tpu_pod", k=6)   # pod slices: concurrency pays


def main():
    run_all()


if __name__ == "__main__":
    main()
