"""Beyond-paper: multi-request orchestration throughput.

The paper optimizes single-query latency (mobile). At pod scale, a server
admits several concurrent RAG queries; HeRo's scheduler handles this with
NO changes — the DynamicDAG simply holds multiple query subgraphs and the
criticality/concurrency machinery arbitrates between them.  We compare
sequential (one query at a time) vs merged-DAG execution.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import make_world
from repro.configs import get_family
from repro.core import (GroundTruthPerf, HeroScheduler, LinearPerfModel,
                        SchedulerConfig, Simulator, tpu_v5e_slices)
from repro.rag import build_stages
from repro.core.dag import DynamicDAG
from repro.rag import (build_workflow, default_means, make_template,
                       sample_traces)
from repro.rag.workflow import BUILDERS


def run(csv=print, k: int = 3, wf: int = 2, dataset: str = "hotpotqa",
        world: str = "sd8gen4"):
    if world == "tpu_pod":
        # pod carved into 6 PU slices: many more lanes than one query needs
        soc = tpu_v5e_slices({"s0": 8, "s1": 8, "s2": 16, "s3": 32,
                              "s4": 64, "s5": 128})
        stages = build_stages(get_family("qwen3"))
        gt = GroundTruthPerf(soc, stages)
        perf = LinearPerfModel().fit(gt)
    else:
        soc, gt, perf = make_world(world, "qwen3")
    traces = sample_traces(dataset, k, seed=11)
    means = default_means(traces)

    def sched():
        return HeroScheduler(perf, [p.name for p in soc.pus], soc.dram_bw,
                             SchedulerConfig(),
                             template=make_template(wf, means))

    # sequential: sum of single-query makespans
    seq = 0.0
    for tr in traces:
        dag = build_workflow(wf, tr, fine_grained=True)
        seq += Simulator(gt, sched()).run(dag).makespan

    # merged: all queries admitted at t=0 (expanders still fire per query;
    # the builders namespace node ids with a per-query prefix)
    merged = DynamicDAG()
    for qi, tr in enumerate(traces):
        BUILDERS[wf](tr, True, prefix=f"q{qi}/", dag=merged)
    par = Simulator(gt, sched()).run(merged).makespan

    csv("world,mode,queries,total_s,throughput_qps")
    csv(f"{world},sequential,{k},{seq:.2f},{k / seq:.3f}")
    csv(f"{world},merged_dag,{k},{par:.2f},{k / par:.3f}")
    csv(f"# {world}: merged-DAG throughput gain {seq / par:.2f}x")
    return seq, par


def run_all(csv=print, **kw):
    run(csv)                            # mobile SoC: saturated by one query
    return run(csv, world="tpu_pod", k=6)   # pod slices: concurrency pays


def main():
    run_all()


if __name__ == "__main__":
    main()

