"""§Roofline: render the dry-run results (results/dryrun.jsonl) as the
per-(arch × shape × mesh) three-term roofline table."""
from __future__ import annotations

import json
import os


def run(csv=print, path: str = "results/dryrun.jsonl"):
    if not os.path.exists(path):
        csv(f"# {path} missing — run: PYTHONPATH=src python -m "
            f"repro.launch.dryrun --all --multi-pod both --out {path}")
        return []
    csv("arch,shape,mesh,t_compute_s,t_memory_s,t_collective_s,bottleneck,"
        "useful_flops_frac,peak_gb_per_dev,fits_16gb,status")
    rows = []
    for line in open(path):
        r = json.loads(line)
        if r["status"] == "skipped":
            csv(f"{r['arch']},{r['shape']},{r['mesh']},,,,skipped,,,,"
                f"skipped:{r['reason'][:40]}")
            continue
        if r["status"] != "ok":
            csv(f"{r['arch']},{r['shape']},{r['mesh']},,,,error,,,,error")
            continue
        rf = r["roofline"]
        csv(f"{r['arch']},{r['shape']},{r['mesh']},"
            f"{rf['t_compute_s']:.4f},{rf['t_memory_s']:.4f},"
            f"{rf['t_collective_s']:.4f},{rf['bottleneck']},"
            f"{rf['useful_flops_frac']:.3f},"
            f"{r['memory']['peak_bytes'] / 1e9:.1f},"
            f"{r['fits_16gb_hbm']},ok")
        rows.append(r)
    return rows


def main():
    run()


if __name__ == "__main__":
    main()
