"""Paper Fig. 6: end-to-end latency, BGE + Llama3 family (8B chat model —
smaller relative gains than Fig. 5, the paper's model-level analysis)."""
from __future__ import annotations

from benchmarks.common import DATASETS, SOCS, STRATEGIES, mean_latency

FAMILY = "bge"


def run(csv=print, n: int = 4, datasets=DATASETS, workflows=(1, 2, 3)):
    csv("platform,dataset,workflow,strategy,latency_s,speedup_vs_gpu")
    rows = []
    for soc_name in SOCS:
        for ds in datasets:
            for wf in workflows:
                lat = {s: mean_latency(s, soc_name, FAMILY, wf, ds, n=n)
                       for s in STRATEGIES}
                for s in STRATEGIES:
                    csv(f"{soc_name},{ds},W{wf},{s},{lat[s]:.2f},"
                        f"{lat['llamacpp_gpu'] / lat[s]:.2f}")
                    rows.append((soc_name, ds, wf, s, lat[s]))
    return rows


def main():
    run()


if __name__ == "__main__":
    main()
