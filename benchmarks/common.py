"""Shared harness for the paper-reproduction benchmarks."""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import numpy as np

from repro.configs import get_family
from repro.core import (GroundTruthPerf, HeroScheduler, LinearPerfModel,
                        SchedulerConfig, Simulator, snapdragon_8gen3,
                        snapdragon_8gen4, strategy_config)
from repro.rag import (STAGE_ROLES, build_stages, build_workflow,
                       default_means, make_template, sample_traces)

SOCS = {"sd8gen3": snapdragon_8gen3, "sd8gen4": snapdragon_8gen4}
STRATEGIES = ("llamacpp_gpu", "powerserve_npu", "ayo_like", "hero")
DATASETS = ("finqabench", "truthfulqa", "hotpotqa", "2wikimqa")


def make_world(soc_name: str, family: str):
    soc = SOCS[soc_name]()
    stages = build_stages(get_family(family))
    gt = GroundTruthPerf(soc, stages)
    perf = LinearPerfModel().fit(gt)
    return soc, gt, perf


def scheduler_for(strategy: str, perf, soc, wf: int, means,
                  overrides: Optional[dict] = None) -> HeroScheduler:
    if strategy == "hero":
        cfg, tmpl = SchedulerConfig(), make_template(wf, means)
    else:
        cfg, tmpl = strategy_config(strategy, STAGE_ROLES), None
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
        if cfg.enable_criticality and tmpl is None:
            tmpl = make_template(wf, means)
    return HeroScheduler(perf, [p.name for p in soc.pus], soc.dram_bw, cfg,
                         template=tmpl), cfg


def mean_latency(strategy: str, soc_name: str, family: str, wf: int,
                 dataset: str, n: int = 5, seed: int = 1,
                 overrides: Optional[dict] = None) -> float:
    soc, gt, perf = make_world(soc_name, family)
    traces = sample_traces(dataset, n, seed=seed)
    means = default_means(traces)
    lat = []
    for tr in traces:
        sched, cfg = scheduler_for(strategy, perf, soc, wf, means, overrides)
        dag = build_workflow(wf, tr, fine_grained=cfg.enable_partition)
        lat.append(Simulator(gt, sched).run(dag).makespan)
    return float(np.mean(lat))


def timeit_us(fn, *args, warmup: int = 2, iters: int = 5) -> float:
    for _ in range(warmup):
        fn(*args)
    t0 = time.perf_counter()
    for _ in range(iters):
        fn(*args)
    return (time.perf_counter() - t0) / iters * 1e6
