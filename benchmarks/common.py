"""Shared harness for the paper-reproduction benchmarks — a thin layer
over the ``repro.api`` session facade."""
from __future__ import annotations

import time
from typing import Optional

import numpy as np

from repro.api import HeroSession, SessionOptions
from repro.api.session import SOCS, STRATEGIES, make_world  # noqa: F401
from repro.rag import default_means, sample_traces

DATASETS = ("finqabench", "truthfulqa", "hotpotqa", "2wikimqa")


def mean_latency(strategy: str, soc_name: str, family: str, wf: int,
                 dataset: str, n: int = 5, seed: int = 1,
                 overrides: Optional[dict] = None) -> float:
    """Mean single-query makespan over ``n`` sampled traces (the paper's
    latency protocol): one isolated session run per trace."""
    traces = sample_traces(dataset, n, seed=seed)
    sess = HeroSession(world=soc_name, family=family, strategy=strategy,
                       means=default_means(traces),
                       options=SessionOptions(cfg_overrides=overrides))
    for tr in traces:
        sess.submit(tr, wf=wf)
    results = sess.run(mode="isolated")
    return float(np.mean([r.makespan for r in results]))


def timeit_us(fn, *args, warmup: int = 2, iters: int = 5) -> float:
    for _ in range(warmup):
        fn(*args)
    t0 = time.perf_counter()
    for _ in range(iters):
        fn(*args)
    return (time.perf_counter() - t0) / iters * 1e6
