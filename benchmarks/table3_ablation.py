"""Paper Table 3: speedup breakdown of the three techniques.

C1: Qwen3 family, Workflow 2, FinqaBench.
C2: BGE family, Workflow 3, 2WikiMQA.
Baseline = Ayo-like static mapping; each row adds ONE technique; ALL = HeRo.
Plus the anti-ablation (HeRo minus concurrency control) showing Eq. 5's
value inside the full system.
"""
from __future__ import annotations

from benchmarks.common import mean_latency

CASES = {"C1": ("qwen3", 2, "finqabench"), "C2": ("bge", 3, "2wikimqa")}

ROWS = {
    "baseline": ("ayo_like", None),
    "+partition": ("ayo_like", {"enable_partition": True}),
    "+criticality": ("ayo_like", {"enable_criticality": True,
                                  "static_map": None}),
    "+concurrency": ("ayo_like", {"enable_concurrency": True}),
    "ALL (HeRo)": ("hero", None),
    "ALL minus CC": ("hero", {"enable_concurrency": False}),
}

PAPER = {"C1": {"baseline": 1.0, "+partition": 1.14, "+criticality": 1.37,
                "+concurrency": 1.25, "ALL (HeRo)": 1.52},
         "C2": {"baseline": 1.0, "+partition": 1.96, "+criticality": 2.53,
                "+concurrency": 2.09, "ALL (HeRo)": 3.20}}


def run(csv=print, n: int = 5):
    csv("case,technique,latency_s,speedup,paper_speedup")
    rows = []
    for case, (family, wf, ds) in CASES.items():
        base = None
        for name, (strategy, overrides) in ROWS.items():
            lat = mean_latency(strategy, "sd8gen4", family, wf, ds, n=n,
                               seed=3, overrides=overrides)
            if base is None:
                base = lat
            sp = base / lat
            paper = PAPER[case].get(name, float("nan"))
            csv(f"{case},{name},{lat:.2f},{sp:.2f},{paper}")
            rows.append((case, name, lat, sp))
    return rows


def main():
    run()


if __name__ == "__main__":
    main()
