"""Kernel microbenchmarks: reference-path timings on CPU (the Pallas path
targets TPU; interpret-mode timing is not meaningful) + analytic VMEM
working-set sizes per kernel block config."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import timeit_us
from repro.kernels import ref


def run(csv=print):
    csv("name,us_per_call,derived")
    key = jax.random.PRNGKey(0)

    q = jax.random.normal(key, (1, 512, 8, 64))
    k = jax.random.normal(key, (1, 512, 4, 64))
    v = jax.random.normal(key, (1, 512, 4, 64))
    f = jax.jit(lambda a, b, c: ref.flash_attention_ref(a, b, c))
    us = timeit_us(lambda: f(q, k, v).block_until_ready())
    csv(f"flash_attention_ref_512,{us:.0f},vmem_block_kb="
        f"{(256 * 64 * 3 * 4 + 256 * 256 * 4) // 1024}")

    qd = jax.random.normal(key, (4, 8, 64))
    lengths = jnp.array([512, 256, 128, 512], jnp.int32)
    fd = jax.jit(lambda a, b, c, l: ref.decode_attention_ref(a, b, c, l))
    us = timeit_us(lambda: fd(qd, k, v, lengths[:1]).block_until_ready())
    csv(f"decode_attention_ref,{us:.0f},bytes_per_token="
        f"{2 * 512 * 4 * 64 * 4}")

    from repro.kernels.int8_matmul import quantize_int8
    x = jax.random.normal(key, (512, 512))
    xq, sx = quantize_int8(x, 1)
    wq, sw = quantize_int8(x, 0)
    fi = jax.jit(lambda a, b, c, d: ref.int8_matmul_ref(a, b, c, d))
    us = timeit_us(lambda: fi(xq, wq, sx, sw).block_until_ready())
    csv(f"int8_matmul_ref_512,{us:.0f},mxu_util_target=2x_bf16")

    c = jax.random.normal(key, (8192, 128))
    qr = jax.random.normal(key, (8, 128))
    ft = jax.jit(lambda a, b: ref.topk_retrieval_ref(a, b, 16))
    us = timeit_us(lambda: ft(qr, c)[0].block_until_ready())
    csv(f"topk_retrieval_ref_8k,{us:.0f},fused_hbm_passes=1")
    return []


def main():
    run()


if __name__ == "__main__":
    main()
