"""Paper Fig. 3: contention slowdown under various parallelism.

Co-runs 1..4 stages on different PUs in the simulator and reports each
stage's slowdown vs running alone — the φ(B) behaviour the concurrency
controller (Eq. 5) is built on.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import make_world
from repro.core import Config


def run(csv=print):
    soc, gt, perf = make_world("sd8gen4", "qwen3")
    combos = [
        ("decode alone", [("chat_decode", "gpu", 16)]),
        ("decode + embed", [("chat_decode", "gpu", 16),
                            ("embed", "npu", 32)]),
        ("decode + embed + search", [("chat_decode", "gpu", 16),
                                     ("embed", "npu", 32),
                                     ("vsearch", "cpu", 4096)]),
        ("2 decodes + embed + search", [("chat_decode", "gpu", 16),
                                        ("rewrite_decode", "cpu", 16),
                                        ("embed", "npu", 32),
                                        ("vsearch", "cpu", 4096)]),
    ]
    csv("combo,stage,pu,B_total_gbs,phi,slowdown_pct")
    rows = []
    for name, tasks in combos:
        B = sum(gt.bandwidth(gt.stages[s], soc.pu(p), Config(p, b))
                for s, p, b in tasks)
        for s, p, b in tasks:
            phi = gt.phi(gt.stages[s], B)
            rows.append((name, s, p, B, phi))
            csv(f"{name},{s},{p},{B / 1e9:.1f},{phi:.3f},"
                f"{(phi - 1) * 100:.1f}")
    return rows


def main():
    run()


if __name__ == "__main__":
    main()
