"""Paper Fig. 5: end-to-end latency, Qwen3 family — 3 workflows × 4 datasets
× 2 platforms × 4 strategies."""
from __future__ import annotations

from benchmarks.common import DATASETS, SOCS, STRATEGIES, mean_latency

FAMILY = "qwen3"


def run(csv=print, n: int = 4, datasets=DATASETS, workflows=(1, 2, 3)):
    csv("platform,dataset,workflow,strategy,latency_s,speedup_vs_gpu")
    rows = []
    best = {"gpu": 0.0, "ayo": 0.0}
    for soc_name in SOCS:
        for ds in datasets:
            for wf in workflows:
                lat = {s: mean_latency(s, soc_name, FAMILY, wf, ds, n=n)
                       for s in STRATEGIES}
                for s in STRATEGIES:
                    csv(f"{soc_name},{ds},W{wf},{s},{lat[s]:.2f},"
                        f"{lat['llamacpp_gpu'] / lat[s]:.2f}")
                    rows.append((soc_name, ds, wf, s, lat[s]))
                best["gpu"] = max(best["gpu"],
                                  lat["llamacpp_gpu"] / lat["hero"])
                best["ayo"] = max(best["ayo"],
                                  lat["ayo_like"] / lat["hero"])
    csv(f"# max speedup vs llama.cpp-GPU: {best['gpu']:.2f}x "
        f"(paper: up to 10.94x)")
    csv(f"# max speedup vs Ayo-like: {best['ayo']:.2f}x "
        f"(paper: 1.5x text / 3.2x Table 3)")
    return rows


def main():
    run()


if __name__ == "__main__":
    main()
