"""Paper §5: "The scheduler exposes two hyperparameters: the bandwidth-
contention penalty weight α ... and the future-term weight β ... We tune
both parameters for each deployment via grid search."

This reproduces that tuning and reports the sensitivity surface.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import make_world
from repro.core import HeroScheduler, SchedulerConfig, Simulator
from repro.rag import (build_workflow, default_means, make_template,
                       sample_traces)

ALPHAS = (0.0, 0.1, 0.35, 0.7, 1.5)
BETAS = (0.0, 0.3, 0.6, 1.0, 2.0)


def run(csv=print, n: int = 3, wf: int = 3, dataset: str = "2wikimqa"):
    soc, gt, perf = make_world("sd8gen4", "qwen3")
    traces = sample_traces(dataset, n, seed=5)
    means = default_means(traces)
    csv("alpha,beta,mean_latency_s")
    best = (None, float("inf"))
    for a in ALPHAS:
        for b in BETAS:
            lat = []
            for tr in traces:
                dag = build_workflow(wf, tr, fine_grained=True)
                sched = HeroScheduler(
                    perf, [p.name for p in soc.pus], soc.dram_bw,
                    SchedulerConfig(alpha=a, beta=b),
                    template=make_template(wf, means))
                lat.append(Simulator(gt, sched).run(dag).makespan)
            m = float(np.mean(lat))
            csv(f"{a},{b},{m:.3f}")
            if m < best[1]:
                best = ((a, b), m)
    csv(f"# grid-search optimum: alpha={best[0][0]} beta={best[0][1]} "
        f"({best[1]:.2f}s) — deployed defaults alpha=0.35 beta=0.6")
    return best


def main():
    run()


if __name__ == "__main__":
    main()
