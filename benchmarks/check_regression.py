"""Perf-regression gate for the serving benchmark (CI ``bench-smoke``).

Compares a fresh ``BENCH_serving.json`` (written by
``benchmarks/multiquery.py --bench-out``) against the committed baseline
and fails when p99 latency or makespan of any (regime, scheduler) cell
regresses by more than ``--tol`` (default 10%).  Also enforces the
structural serving claim behind the continuous-decode-batching PR: in the
saturating regime, ``hero+decode_batch`` must keep its p99 win over the
stage-coalescing-only scheduler.

    python benchmarks/check_regression.py BENCH_serving.json \
        benchmarks/baselines/serving_baseline.json --tol 0.10
"""
from __future__ import annotations

import argparse
import json
import sys

# the cells the gate tracks; higher-is-worse metrics only
GATED_METRICS = ("p99", "total")


def compare(current: dict, baseline: dict, tol: float) -> list:
    """Return a list of human-readable violations (empty = gate passes)."""
    violations = []
    for regime, cells in baseline["regimes"].items():
        cur_cells = current.get("regimes", {}).get(regime)
        if cur_cells is None:
            violations.append(f"regime {regime!r} missing from current run")
            continue
        for variant, base_row in cells.items():
            cur_row = cur_cells.get(variant)
            if cur_row is None:
                violations.append(
                    f"{regime}/{variant} missing from current run")
                continue
            for metric in GATED_METRICS:
                base, cur = base_row[metric], cur_row[metric]
                if cur > base * (1.0 + tol):
                    violations.append(
                        f"{regime}/{variant} {metric}: {cur:.2f}s vs "
                        f"baseline {base:.2f}s (+{(cur / base - 1) * 100:.1f}%"
                        f" > {tol * 100:.0f}% tolerance)")
    # the structural claim: continuous decode batching beats
    # stage-coalescing-only p99 under saturating arrivals
    sat = current.get("regimes", {}).get("saturated", {})
    dec, co = sat.get("hero+decode_batch"), sat.get("hero+coalesce")
    if dec and co and dec["p99"] >= co["p99"]:
        violations.append(
            f"saturated: hero+decode_batch p99 {dec['p99']:.2f}s no longer "
            f"beats hero+coalesce p99 {co['p99']:.2f}s")
    return violations


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("current", help="fresh BENCH_serving.json")
    ap.add_argument("baseline", help="committed baseline json")
    ap.add_argument("--tol", type=float, default=0.10,
                    help="allowed fractional regression (default 0.10)")
    args = ap.parse_args()
    with open(args.current) as f:
        current = json.load(f)
    with open(args.baseline) as f:
        baseline = json.load(f)
    violations = compare(current, baseline, args.tol)
    if violations:
        print("PERF REGRESSION GATE FAILED:")
        for v in violations:
            print(f"  - {v}")
        return 1
    n = sum(len(c) for c in baseline["regimes"].values())
    print(f"perf gate OK: {n} cells within {args.tol * 100:.0f}% of baseline")
    return 0


if __name__ == "__main__":
    sys.exit(main())
