"""Perf-regression gate for the serving benchmark (CI ``bench-smoke``).

Compares a fresh ``BENCH_serving.json`` (written by
``benchmarks/multiquery.py --bench-out``) against a committed per-regime
baseline and prints a diffable report of every gated cell.  Exit codes
distinguish the two failure modes so baseline refreshes are reviewable:

- ``0`` — every cell within tolerance;
- ``2`` — perf regression (a gated metric drifted past ``--tol``, or a
  structural serving claim broke);
- ``3`` — missing baseline (file absent, or the current run has regimes /
  variants the baseline does not know): refresh the baseline rather than
  chase a phantom regression.

After an intentional perf change, regenerate with ``--write-baseline``::

    python benchmarks/check_regression.py BENCH_serving.json \
        benchmarks/baselines/serving_saturated.json --write-baseline

and commit the updated baseline alongside the change.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

# the cells the gate tracks; higher-is-worse metrics only
GATED_METRICS = ("p99", "total")

# slo-regime floor (mirrors multiquery.SLO_BATCH_FLOOR — this script
# stays import-free so the gate can run without PYTHONPATH): hero+slo
# may trade batch completion for interactive p99, but never below this
# fraction of the class-blind comparator's batch throughput
SLO_BATCH_FLOOR = 0.75

EXIT_OK, EXIT_REGRESSION, EXIT_MISSING = 0, 2, 3


def compare(current: dict, baseline: dict, tol: float):
    """Return ``(report_lines, regressions, missing)``.

    ``report_lines`` covers EVERY gated cell (diffable: stable order, one
    line per metric); ``regressions`` and ``missing`` are the violation
    subsets that map to exit codes 2 and 3."""
    report, regressions, missing = [], [], []
    base_regimes = baseline.get("regimes", {})
    cur_regimes = current.get("regimes", {})
    for regime in sorted(set(base_regimes) | set(cur_regimes)):
        cells = base_regimes.get(regime)
        cur_cells = cur_regimes.get(regime)
        if cells is None:
            missing.append(f"regime {regime!r} absent from baseline "
                           "(new regime: refresh the baseline)")
            continue
        if cur_cells is None:
            regressions.append(f"regime {regime!r} missing from current run")
            continue
        for variant in sorted(set(cells) | set(cur_cells)):
            base_row = cells.get(variant)
            cur_row = cur_cells.get(variant)
            if base_row is None:
                missing.append(f"{regime}/{variant} absent from baseline "
                               "(new variant: refresh the baseline)")
                continue
            if cur_row is None:
                regressions.append(
                    f"{regime}/{variant} missing from current run")
                continue
            for metric in GATED_METRICS:
                base, cur = base_row[metric], cur_row[metric]
                delta = (cur / base - 1.0) * 100.0 if base else 0.0
                flag = " REGRESSION" if cur > base * (1.0 + tol) else ""
                report.append(f"{regime}/{variant} {metric}: "
                              f"{base:.2f} -> {cur:.2f} ({delta:+.1f}%)"
                              f"{flag}")
                if flag:
                    regressions.append(
                        f"{regime}/{variant} {metric}: {cur:.2f}s vs "
                        f"baseline {base:.2f}s (+{delta:.1f}% > "
                        f"{tol * 100:.0f}% tolerance)")
            # KV-residency telemetry: informational columns (migration
            # counts shift with scheduling choices; the p99/total gates
            # above are what enforce their cost)
            if "kv_migrations" in cur_row:
                report.append(
                    f"{regime}/{variant} kv_migrations: "
                    f"{base_row.get('kv_migrations', 0)} -> "
                    f"{cur_row['kv_migrations']}, bytes_moved: "
                    f"{base_row.get('kv_bytes', 0.0) / 1e9:.2f} GB -> "
                    f"{cur_row.get('kv_bytes', 0.0) / 1e9:.2f} GB")
            # paged-KV hit rate: hits / (hits + prefill dispatches is not
            # recorded per cell, so report hits and skipped tokens — the
            # structural claims below enforce non-zero reuse)
            if cur_row.get("kv_page_hits") or base_row.get("kv_page_hits"):
                report.append(
                    f"{regime}/{variant} kv_page_hits: "
                    f"{base_row.get('kv_page_hits', 0)} -> "
                    f"{cur_row.get('kv_page_hits', 0)}, hit_tokens: "
                    f"{base_row.get('kv_hit_tokens', 0)} -> "
                    f"{cur_row.get('kv_hit_tokens', 0)}, evictions: "
                    f"{base_row.get('kv_evictions', 0)} -> "
                    f"{cur_row.get('kv_evictions', 0)}")
            # predictive-prefetch telemetry: staging groups and the staged
            # pages the gather found resident (informational; the prefix-
            # regime structural claim below is what enforces activity)
            if (cur_row.get("kv_prefetches")
                    or base_row.get("kv_prefetches")):
                report.append(
                    f"{regime}/{variant} kv_prefetches: "
                    f"{base_row.get('kv_prefetches', 0)} -> "
                    f"{cur_row.get('kv_prefetches', 0)}, prefetch_hits: "
                    f"{base_row.get('kv_prefetch_hits', 0)} -> "
                    f"{cur_row.get('kv_prefetch_hits', 0)}")
            # speculative-decoding telemetry (specdec regime only):
            # drafted/accepted totals and the decode token-rate are
            # informational here — the structural claim below enforces
            # the rate win and non-zero drafting
            if "drafted" in cur_row:
                report.append(
                    f"{regime}/{variant} decode_tok_rate: "
                    f"{base_row.get('decode_tok_rate', 0.0):.1f} -> "
                    f"{cur_row['decode_tok_rate']:.1f} tok/s, drafted: "
                    f"{base_row.get('drafted', 0)} -> "
                    f"{cur_row.get('drafted', 0)}, accepted: "
                    f"{base_row.get('accepted', 0)} -> "
                    f"{cur_row.get('accepted', 0)}, spec_rounds: "
                    f"{base_row.get('spec_rounds', 0)} -> "
                    f"{cur_row.get('spec_rounds', 0)}")
            # SLO-class telemetry (slo regime only): per-class tails and
            # preemption counts are informational here — the structural
            # claims below are what enforce the interactive win and the
            # batch floor
            if "int_p99" in cur_row:
                report.append(
                    f"{regime}/{variant} int_p99: "
                    f"{base_row.get('int_p99', 0.0):.2f} -> "
                    f"{cur_row['int_p99']:.2f}, batch_p99: "
                    f"{base_row.get('batch_p99', 0.0):.2f} -> "
                    f"{cur_row.get('batch_p99', 0.0):.2f}, batch_qps: "
                    f"{base_row.get('batch_throughput', 0.0):.3f} -> "
                    f"{cur_row.get('batch_throughput', 0.0):.3f}, "
                    f"preemptions: {base_row.get('preemptions', 0)} -> "
                    f"{cur_row.get('preemptions', 0)}")
    # structural serving claims, checked on whatever regimes this leg ran:
    # continuous decode batching keeps its p99 win over stage coalescing
    # under saturating arrivals, and the adaptive policy keeps its win
    # over fixed caps on the mixed W1-W3 regime
    sat = cur_regimes.get("saturated", {})
    dec, co = sat.get("hero+decode_batch"), sat.get("hero+coalesce")
    if dec and co and dec["p99"] >= co["p99"]:
        regressions.append(
            f"saturated: hero+decode_batch p99 {dec['p99']:.2f}s no longer "
            f"beats hero+coalesce p99 {co['p99']:.2f}s")
    mixed = cur_regimes.get("mixed", {})
    ada, fix = mixed.get("hero+adaptive"), mixed.get("hero+decode_batch")
    if ada and fix and ada["p99"] >= fix["p99"]:
        regressions.append(
            f"mixed: hero+adaptive p99 {ada['p99']:.2f}s no longer beats "
            f"fixed-cap p99 {fix['p99']:.2f}s")
    # modeled migration pricing beats the constant on the migration-heavy
    # regime (long-context W3 under PU pressure — the cell KV-residency
    # tracking exists for; both cells pay real transfer physics)
    mig = cur_regimes.get("migration", {})
    kvm, kvc = mig.get("hero+kv"), mig.get("hero+kv-const")
    if kvm and kvc and kvm["p99"] >= kvc["p99"]:
        regressions.append(
            f"migration: hero+kv p99 {kvm['p99']:.2f}s no longer beats "
            f"constant-priced hero+kv-const p99 {kvc['p99']:.2f}s")
    # the paged subsystem earns its keep on the shared-corpus prefix
    # regime: the prefix cache must actually hit, and those hits must buy
    # a p99 win over the monolithic (pages-off) tracker
    pre = cur_regimes.get("prefix", {})
    pages, off = pre.get("hero+pages"), pre.get("hero+kv")
    if pages and off:
        if not pages.get("kv_page_hits"):
            regressions.append(
                "prefix: hero+pages scored zero prefix-cache page hits "
                "on the shared-corpus regime")
        if pages["p99"] >= off["p99"]:
            regressions.append(
                f"prefix: hero+pages p99 {pages['p99']:.2f}s no longer "
                f"beats pages-off hero+kv p99 {off['p99']:.2f}s")
    # predictive prefetch earns its keep on the same regime: the spill-
    # resident hit pages MUST get staged (nonzero prefetches — the hot
    # prefix chains are demoted between reuses by design), and the
    # overlapped staging must never leave p99 worse than the pages-only
    # cell (tier traffic is small against compute on this profile, so
    # the bound is exact, not a percentage band)
    # the class machinery earns its keep on the slo regime: with the
    # same labelled traffic, SLO admission + boundary preemption must
    # improve interactive p99 over the class-blind adaptive scheduler,
    # and the batch class it defers/preempts must keep at least
    # SLO_BATCH_FLOOR of the comparator's throughput
    slo = cur_regimes.get("slo", {})
    s_on, s_off = slo.get("hero+slo"), slo.get("hero+adaptive")
    if s_on and s_off:
        if s_on["int_p99"] >= s_off["int_p99"]:
            regressions.append(
                f"slo: hero+slo interactive p99 {s_on['int_p99']:.2f}s no "
                f"longer beats class-blind hero+adaptive "
                f"{s_off['int_p99']:.2f}s")
        floor = SLO_BATCH_FLOOR * s_off["batch_throughput"]
        if s_on["batch_throughput"] < floor:
            regressions.append(
                f"slo: hero+slo batch throughput "
                f"{s_on['batch_throughput']:.3f} qps fell below "
                f"{SLO_BATCH_FLOOR:.0%} of class-blind "
                f"{s_off['batch_throughput']:.3f} qps")
    # speculative decoding earns its keep on the decode-heavy specdec
    # regime: hero+spec must actually draft candidates, and its decode
    # token-rate must strictly beat the same adaptive scheduler with
    # speculation off (same traffic, same policy, no draft pairs)
    spd = cur_regimes.get("specdec", {})
    sp_on, sp_off = spd.get("hero+spec"), spd.get("hero+adaptive")
    if sp_on and sp_off:
        if not sp_on.get("drafted"):
            regressions.append(
                "specdec: hero+spec drafted zero candidate tokens on the "
                "decode-heavy regime — the case speculation exists for")
        if sp_on.get("decode_tok_rate", 0.0) <= \
                sp_off.get("decode_tok_rate", 0.0):
            regressions.append(
                f"specdec: hero+spec decode token-rate "
                f"{sp_on.get('decode_tok_rate', 0.0):.1f} tok/s no longer "
                f"beats spec-off hero+adaptive "
                f"{sp_off.get('decode_tok_rate', 0.0):.1f} tok/s")
    pfc = pre.get("hero+prefetch")
    if pfc and pages:
        if not pfc.get("kv_prefetches"):
            regressions.append(
                "prefix: hero+prefetch issued zero prefetch stagings on "
                "the hot/cold regime — the spill-resident-hit case the "
                "prefetcher exists for")
        if pfc["p99"] > pages["p99"]:
            regressions.append(
                f"prefix: hero+prefetch p99 {pfc['p99']:.4f}s exceeds "
                f"pages-only hero+pages p99 {pages['p99']:.4f}s — "
                "overlapped staging must not cost latency")
    return report, regressions, missing


def main() -> int:
    ap = argparse.ArgumentParser(
        description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("current", help="fresh BENCH_serving.json")
    ap.add_argument("baseline", help="committed baseline json")
    ap.add_argument("--tol", type=float, default=0.10,
                    help="allowed fractional regression (default 0.10)")
    ap.add_argument("--write-baseline", action="store_true",
                    help="overwrite the baseline with the current run "
                         "(the reviewable refresh workflow) and exit 0")
    args = ap.parse_args()
    with open(args.current) as f:
        current = json.load(f)
    if args.write_baseline:
        os.makedirs(os.path.dirname(args.baseline) or ".", exist_ok=True)
        with open(args.baseline, "w") as f:
            json.dump(current, f, indent=1, sort_keys=True)
        print(f"baseline refreshed: {args.baseline} <- {args.current}")
        return EXIT_OK
    if not os.path.exists(args.baseline):
        print(f"MISSING BASELINE: {args.baseline} does not exist")
        print(f"  create it with: python benchmarks/check_regression.py "
              f"{args.current} {args.baseline} --write-baseline")
        return EXIT_MISSING
    with open(args.baseline) as f:
        baseline = json.load(f)
    report, regressions, missing = compare(current, baseline, args.tol)
    for line in report:
        print(line)
    if missing:
        print("MISSING BASELINE KEYS:")
        for v in missing:
            print(f"  - {v}")
        print(f"  refresh with: python benchmarks/check_regression.py "
              f"{args.current} {args.baseline} --write-baseline")
    if regressions:
        print("PERF REGRESSION GATE FAILED:")
        for v in regressions:
            print(f"  - {v}")
    if regressions:
        return EXIT_REGRESSION
    if missing:
        return EXIT_MISSING
    n = sum(len(c) for c in baseline.get("regimes", {}).values())
    print(f"perf gate OK: {n} cells within {args.tol * 100:.0f}% of baseline")
    return EXIT_OK


if __name__ == "__main__":
    sys.exit(main())
