"""INT8 × INT8 quantized matmul with per-channel scales — Pallas TPU kernel.

The paper quantizes every RAG stage model to INT8 (§6.1); on TPU the MXU
executes int8×int8→int32 at 2× the bf16 rate, which is what makes NPU-style
affinity (Fig. 2) reproducible on a TPU slice.  Dequantization applies
per-row activation scales and per-column weight scales on the f32
accumulator at the final K step.

Grid (M/bm, N/bn, K/bk), K innermost; int32 accumulator scratch in VMEM.
Default tiles (256, 256, 256): ~0.4 MB VMEM working set, MXU-aligned.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _int8_kernel(x_ref, w_ref, sx_ref, sw_ref, o_ref, acc_ref, *, nk: int):
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.int32)

    @pl.when(ik == nk - 1)
    def _finish():
        sx = sx_ref[...].astype(jnp.float32)            # (bm, 1)
        sw = sw_ref[...].astype(jnp.float32)            # (1, bn)
        o_ref[...] = (acc_ref[...].astype(jnp.float32) * sx * sw
                      ).astype(o_ref.dtype)


def int8_matmul(x: jax.Array, w: jax.Array, sx: jax.Array, sw: jax.Array, *,
                block_m: int = 256, block_n: int = 256, block_k: int = 256,
                out_dtype=jnp.bfloat16, interpret: bool = False) -> jax.Array:
    """x (M, K) int8, w (K, N) int8, sx (M, 1) f32 per-row activation scales,
    sw (1, N) f32 per-column weight scales -> (M, N) out_dtype."""
    M, K = x.shape
    N = w.shape[1]
    bm, bn, bk = min(block_m, M), min(block_n, N), min(block_k, K)
    nk = pl.cdiv(K, bk)

    return pl.pallas_call(
        functools.partial(_int8_kernel, nk=nk),
        grid=(pl.cdiv(M, bm), pl.cdiv(N, bn), nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda im, jn, ik: (im, ik)),
            pl.BlockSpec((bk, bn), lambda im, jn, ik: (ik, jn)),
            pl.BlockSpec((bm, 1), lambda im, jn, ik: (im, 0)),
            pl.BlockSpec((1, bn), lambda im, jn, ik: (0, jn)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda im, jn, ik: (im, jn)),
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        interpret=interpret,
    )(x, w, sx, sw)


def quantize_int8(x: jax.Array, axis: int = -1):
    """Symmetric per-channel int8 quantization -> (q, scale)."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=axis, keepdims=True)
    scale = jnp.maximum(amax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale
