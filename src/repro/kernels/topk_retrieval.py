"""Fused vector-search scoring + top-k — Pallas TPU kernel.

The vector-DB retrieval stage (FAISS in the paper) reduces to a
(queries × d) · (corpus × d)ᵀ matmul followed by per-query top-k.  Fusing
the two means corpus blocks stream HBM→VMEM once; the running top-k
(values + indices) lives in VMEM scratch across corpus blocks, merged with
each block's scores via a single sort of (k + block) candidates.

Grid (q_blocks, corpus_blocks), corpus innermost.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _topk_kernel(q_ref, c_ref, val_ref, idx_ref, *, k: int, bn: int,
                 nn: int, n_total: int):
    ic = pl.program_id(1)

    @pl.when(ic == 0)
    def _init():
        val_ref[...] = jnp.full_like(val_ref, NEG_INF)
        idx_ref[...] = jnp.full_like(idx_ref, -1)

    q = q_ref[...].astype(jnp.float32)                  # (bq, d)
    c = c_ref[...].astype(jnp.float32)                  # (bn, d)
    s = jax.lax.dot_general(q, c, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (bq, bn)
    pos = ic * bn + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(pos < n_total, s, NEG_INF)            # mask corpus padding

    cur_v = val_ref[...]                                # (bq, k)
    cur_i = idx_ref[...]
    cand_v = jnp.concatenate([cur_v, s], axis=1)        # (bq, k + bn)
    cand_i = jnp.concatenate([cur_i, pos], axis=1)
    new_v, sel = jax.lax.top_k(cand_v, k)
    new_i = jnp.take_along_axis(cand_i, sel, axis=1)
    val_ref[...] = new_v
    idx_ref[...] = new_i


def topk_retrieval(queries: jax.Array, corpus: jax.Array, k: int, *,
                   block_q: int = 128, block_n: int = 1024,
                   interpret: bool = False):
    """queries (nq, d), corpus (N, d) -> (scores (nq, k), ids (nq, k)),
    inner-product metric (callers pre-normalize for cosine)."""
    nq, d = queries.shape
    N = corpus.shape[0]
    bq, bn = min(block_q, nq), min(block_n, N)
    nqb, nnb = pl.cdiv(nq, bq), pl.cdiv(N, bn)

    vals, idxs = pl.pallas_call(
        functools.partial(_topk_kernel, k=k, bn=bn, nn=nnb, n_total=N),
        grid=(nqb, nnb),
        in_specs=[
            pl.BlockSpec((bq, d), lambda iq, ic: (iq, 0)),
            pl.BlockSpec((bn, d), lambda iq, ic: (ic, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bq, k), lambda iq, ic: (iq, 0)),
            pl.BlockSpec((bq, k), lambda iq, ic: (iq, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nq, k), jnp.float32),
            jax.ShapeDtypeStruct((nq, k), jnp.int32),
        ],
        interpret=interpret,
    )(queries, corpus)
    return vals, idxs
