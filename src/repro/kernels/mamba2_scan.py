"""Mamba2 SSD intra-chunk kernel — Pallas TPU.

Computes, for each (batch, chunk, head) grid cell:
  y_intra = (C·Bᵀ ⊙ L) · (dt·x)      — the quadratic-within-chunk term
  S       = (B ⊙ decay_to_end)ᵀ · (dt·x) — this chunk's contribution to the
                                           inter-chunk state recurrence
The lightweight inter-chunk recurrence (over nc chunk states of size
(H, P, N)) stays in jnp — it is O(L/Q) tiny matmuls and does not merit a
kernel; fusing the quadratic term is where the HBM traffic is.

VMEM per cell at (Q=256, P=64, N=64): x (Q,P) + B/C (Q,N) + L (Q,Q) f32
≈ 0.45 MB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ssd_kernel(x_ref, dt_ref, B_ref, C_ref, dA_ref, y_ref, S_ref, *,
                Q: int):
    x = x_ref[0, 0, :, 0, :].astype(jnp.float32)        # (Q, P)
    dt = dt_ref[0, 0, :, 0].astype(jnp.float32)         # (Q,)
    Bm = B_ref[0, 0, :, 0, :].astype(jnp.float32)       # (Q, N)
    Cm = C_ref[0, 0, :, 0, :].astype(jnp.float32)       # (Q, N)
    dA = dA_ref[0, 0, :, 0].astype(jnp.float32)         # (Q,)

    dtx = x * dt[:, None]                               # (Q, P)
    cs = jnp.cumsum(dA)
    seg = cs[:, None] - cs[None, :]                     # (Q, Q)
    ii = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    L = jnp.where(ii >= jj, jnp.exp(seg), 0.0)

    scores = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    y = jax.lax.dot(scores * L, dtx,
                    preferred_element_type=jnp.float32)  # (Q, P)
    y_ref[0, 0, :, 0, :] = y.astype(y_ref.dtype)

    decay_end = jnp.exp(cs[-1] - cs)                    # (Q,)
    Bw = Bm * decay_end[:, None]
    S = jax.lax.dot_general(Bw, dtx, (((0,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (N, P)
    S_ref[0, 0, 0] = S.astype(S_ref.dtype)


def ssd_chunk(x: jax.Array, dt: jax.Array, B: jax.Array, C: jax.Array,
              dA: jax.Array, *, interpret: bool = False):
    """Intra-chunk SSD.

    x (b, nc, Q, H, P); dt/dA (b, nc, Q, H); B/C (b, nc, Q, H, N)
    (B/C pre-broadcast from groups to heads by the caller).
    Returns (y_intra (b, nc, Q, H, P), S (b, nc, H, N, P))."""
    b, nc, Q, H, P = x.shape
    N = B.shape[-1]

    y, S = pl.pallas_call(
        functools.partial(_ssd_kernel, Q=Q),
        grid=(b, nc, H),
        in_specs=[
            pl.BlockSpec((1, 1, Q, 1, P), lambda ib, ic, ih: (ib, ic, 0, ih, 0)),
            pl.BlockSpec((1, 1, Q, 1), lambda ib, ic, ih: (ib, ic, 0, ih)),
            pl.BlockSpec((1, 1, Q, 1, N), lambda ib, ic, ih: (ib, ic, 0, ih, 0)),
            pl.BlockSpec((1, 1, Q, 1, N), lambda ib, ic, ih: (ib, ic, 0, ih, 0)),
            pl.BlockSpec((1, 1, Q, 1), lambda ib, ic, ih: (ib, ic, 0, ih)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, Q, 1, P), lambda ib, ic, ih: (ib, ic, 0, ih, 0)),
            pl.BlockSpec((1, 1, 1, N, P), lambda ib, ic, ih: (ib, ic, ih, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, nc, Q, H, P), jnp.float32),
            jax.ShapeDtypeStruct((b, nc, H, N, P), jnp.float32),
        ],
        interpret=interpret,
    )(x, dt, B, C, dA)
    return y, S
