"""Flash attention (GQA, causal/full) — Pallas TPU kernel.

Online-softmax flash attention for prefill / training.  The grid is
(batch*q_heads, q_blocks, kv_blocks); the kv dimension is innermost so the
f32 accumulator, row-max and row-sum scratch live in VMEM across kv
iterations (TPU grids execute sequentially).

VMEM working set per step (defaults bq=bk=256, e<=256):
  q (256, e) + k (256, e) + v (256, e) + acc f32 (256, e) + s (256, 256) f32
  ≈ 1.3 MB at e=128 — comfortably inside the ~16 MB VMEM budget, with MXU
  dims (256×e×256) aligned to the 128×128 systolic array.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  scale: float, causal: bool, bq: int, bk: int, nk: int):
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32)                    # (bq, e)
    k = k_ref[0].astype(jnp.float32)                    # (bk, e)
    v = v_ref[0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    if causal:
        iq = pl.program_id(1)
        qpos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        s = jnp.where(qpos >= kpos, s, NEG_INF)

    m_prev, l_prev = m_ref[...], l_ref[...]
    m_cur = jnp.max(s, axis=1)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new[:, None])
    alpha = jnp.exp(m_prev - m_new)
    l_new = alpha * l_prev + jnp.sum(p, axis=1)
    acc_ref[...] = (acc_ref[...] * alpha[:, None]
                    + jax.lax.dot(p.astype(v.dtype), v,
                                  preferred_element_type=jnp.float32))
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(ik == nk - 1)
    def _finish():
        l = l_ref[...]
        safe = jnp.where(l == 0.0, 1.0, l)
        o_ref[0] = (acc_ref[...] / safe[:, None]).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, block_q: int = 256,
                    block_k: int = 256, scale: Optional[float] = None,
                    interpret: bool = False) -> jax.Array:
    """q (b, sq, h, e); k/v (b, sk, n, e) with h % n == 0.  Returns
    (b, sq, h, e)."""
    b, sq, h, e = q.shape
    sk, n = k.shape[1], k.shape[2]
    group = h // n
    scale = scale if scale is not None else e ** -0.5
    bq, bk = min(block_q, sq), min(block_k, sk)
    nq, nk = pl.cdiv(sq, bq), pl.cdiv(sk, bk)

    qr = q.transpose(0, 2, 1, 3).reshape(b * h, sq, e)
    kr = k.transpose(0, 2, 1, 3).reshape(b * n, sk, e)
    vr = v.transpose(0, 2, 1, 3).reshape(b * n, sk, e)

    def kv_index(ibh, iq, ik):
        return (ibh // h) * n + (ibh % h) // group, ik, 0

    out = pl.pallas_call(
        functools.partial(_flash_kernel, scale=scale, causal=causal,
                          bq=bq, bk=bk, nk=nk),
        grid=(b * h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, e), lambda ibh, iq, ik: (ibh, iq, 0)),
            pl.BlockSpec((1, bk, e), kv_index),
            pl.BlockSpec((1, bk, e), kv_index),
        ],
        out_specs=pl.BlockSpec((1, bq, e), lambda ibh, iq, ik: (ibh, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, sq, e), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, e), jnp.float32),   # acc
            pltpu.VMEM((bq,), jnp.float32),     # running max
            pltpu.VMEM((bq,), jnp.float32),     # running sum
        ],
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(b, h, sq, e).transpose(0, 2, 1, 3)
