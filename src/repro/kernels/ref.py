"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, causal=True, scale=None):
    """q (b,sq,h,e), k/v (b,sk,n,e) GQA."""
    from repro.models.layers import mha
    b, sq, h, e = q.shape
    scale_ = scale if scale is not None else e ** -0.5
    # mha scales by 1/sqrt(e) internally; rescale if a custom scale is given
    if scale is not None and scale != e ** -0.5:
        q = q * (scale_ * e ** 0.5)
    return mha(q, k, v, causal=causal)


def decode_attention_ref(q, k_cache, v_cache, lengths):
    """q (b,h,e); caches (b,S,n,e); lengths (b,)."""
    from repro.models.layers import mha
    return mha(q[:, None], k_cache, v_cache, causal=False,
               kv_valid_len=lengths)[:, 0]


def int8_matmul_ref(x, w, sx, sw, out_dtype=jnp.bfloat16):
    acc = jnp.einsum("mk,kn->mn", x.astype(jnp.int32), w.astype(jnp.int32))
    return (acc.astype(jnp.float32) * sx.astype(jnp.float32)
            * sw.astype(jnp.float32)).astype(out_dtype)


def topk_retrieval_ref(queries, corpus, k):
    s = jnp.einsum("qd,nd->qn", queries.astype(jnp.float32),
                   corpus.astype(jnp.float32))
    vals, idxs = jax.lax.top_k(s, k)
    return vals, idxs.astype(jnp.int32)


def ssd_chunk_ref(x, dt, B, C, dA):
    """Intra-chunk SSD oracle.  Shapes as kernels.mamba2_scan.ssd_chunk."""
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    dtx = xf * dtf[..., None]
    cs = jnp.cumsum(dA.astype(jnp.float32), axis=2)     # (b,nc,Q,H)
    seg = cs[:, :, :, None, :] - cs[:, :, None, :, :]   # (b,nc,Qi,Qj,H)
    Q = x.shape[2]
    mask = jnp.tril(jnp.ones((Q, Q), bool))[None, None, :, :, None]
    L = jnp.where(mask, jnp.exp(seg), 0.0)
    scores = jnp.einsum("bcqhn,bckhn->bcqkh", C.astype(jnp.float32),
                        B.astype(jnp.float32))
    y = jnp.einsum("bcqkh,bckhp->bcqhp", scores * L, dtx)
    decay_end = jnp.exp(cs[:, :, -1:, :] - cs)          # (b,nc,Q,H)
    S = jnp.einsum("bcqhn,bcqhp->bchnp",
                   B.astype(jnp.float32) * decay_end[..., None], dtx)
    return y, S
