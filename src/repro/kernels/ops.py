"""jit'd public wrappers for the Pallas kernels.

``use_pallas`` dispatch: on TPU backends the Pallas kernels run natively;
on CPU (this container) they run via interpret mode when explicitly
requested, otherwise the jnp reference executes.  The dry-run lowers the
reference path so cost_analysis() sees the real FLOPs.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention as _decode
from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.int8_matmul import int8_matmul as _int8
from repro.kernels.int8_matmul import quantize_int8  # noqa: F401 (re-export)
from repro.kernels.mamba2_scan import ssd_chunk as _ssd
from repro.kernels.topk_retrieval import topk_retrieval as _topk


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _mode(use_pallas: Optional[bool]):
    """-> (run_kernel, interpret)."""
    if use_pallas is None:
        return _on_tpu(), False
    return use_pallas, not _on_tpu()


@functools.partial(jax.jit, static_argnames=("causal", "use_pallas",
                                             "block_q", "block_k"))
def flash_attention(q, k, v, *, causal: bool = True,
                    use_pallas: Optional[bool] = None,
                    block_q: int = 256, block_k: int = 256):
    run, interp = _mode(use_pallas)
    if run:
        return _flash(q, k, v, causal=causal, block_q=block_q,
                      block_k=block_k, interpret=interp)
    return ref.flash_attention_ref(q, k, v, causal=causal)


@functools.partial(jax.jit, static_argnames=("use_pallas", "block_k"))
def decode_attention(q, k_cache, v_cache, lengths, *,
                     use_pallas: Optional[bool] = None, block_k: int = 512):
    run, interp = _mode(use_pallas)
    if run:
        return _decode(q, k_cache, v_cache, lengths, block_k=block_k,
                       interpret=interp)
    return ref.decode_attention_ref(q, k_cache, v_cache, lengths)


@functools.partial(jax.jit, static_argnames=("use_pallas", "out_dtype"))
def int8_matmul(x, w, sx, sw, *, use_pallas: Optional[bool] = None,
                out_dtype=jnp.bfloat16):
    run, interp = _mode(use_pallas)
    if run:
        return _int8(x, w, sx, sw, out_dtype=out_dtype, interpret=interp)
    return ref.int8_matmul_ref(x, w, sx, sw, out_dtype=out_dtype)


@functools.partial(jax.jit, static_argnames=("k", "use_pallas"))
def topk_retrieval(queries, corpus, k: int, *,
                   use_pallas: Optional[bool] = None):
    run, interp = _mode(use_pallas)
    if run:
        return _topk(queries, corpus, k, interpret=interp)
    return ref.topk_retrieval_ref(queries, corpus, k)


@functools.partial(jax.jit, static_argnames=("use_pallas",))
def ssd_chunk(x, dt, B, C, dA, *, use_pallas: Optional[bool] = None):
    run, interp = _mode(use_pallas)
    if run:
        return _ssd(x, dt, B, C, dA, interpret=interp)
    return ref.ssd_chunk_ref(x, dt, B, C, dA)
