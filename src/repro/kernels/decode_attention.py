"""GQA flash-decode — Pallas TPU kernel for single-token serving steps.

One new query token per sequence attends to a long KV cache.  All ``g``
query heads of a kv-group are processed together so the score matmul is
(g × e × bk) — MXU-shaped even though there is a single token.  The valid
cache length is a scalar-prefetch operand (the kernel masks the tail), so
one compiled program serves any fill level — exactly the shape-bucketing
HeRo's perf model assumes for decode stages.

Grid: (batch, kv_heads, kv_blocks); accumulator scratch carries the online
softmax across kv blocks.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref,
                   acc_ref, m_ref, l_ref, *, scale: float, bk: int, nk: int):
    ib, ik = pl.program_id(0), pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32)                  # (g, e)
    k = k_ref[0].astype(jnp.float32)                     # (bk, e)
    v = v_ref[0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    valid_len = len_ref[ib]
    kpos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(kpos < valid_len, s, NEG_INF)

    m_prev, l_prev = m_ref[...], l_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    p = jnp.exp(s - m_new[:, None])
    alpha = jnp.exp(m_prev - m_new)
    l_new = alpha * l_prev + jnp.sum(p, axis=1)
    acc_ref[...] = (acc_ref[...] * alpha[:, None]
                    + jax.lax.dot(p.astype(jnp.float32), v,
                                  preferred_element_type=jnp.float32))
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(ik == nk - 1)
    def _finish():
        l = l_ref[...]
        safe = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_ref[...] / safe[:, None]).astype(o_ref.dtype)


def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     lengths: jax.Array, *, block_k: int = 512,
                     scale=None, interpret: bool = False) -> jax.Array:
    """q (b, h, e) one token per sequence; k/v_cache (b, S, n, e);
    lengths (b,) valid cache lengths.  Returns (b, h, e)."""
    b, h, e = q.shape
    S, n = k_cache.shape[1], k_cache.shape[2]
    g = h // n
    scale = scale if scale is not None else e ** -0.5
    bk = min(block_k, S)
    nk = pl.cdiv(S, bk)

    qr = q.reshape(b, n, g, e)
    kr = k_cache.transpose(0, 2, 1, 3).reshape(b * n, S, e)
    vr = v_cache.transpose(0, 2, 1, 3).reshape(b * n, S, e)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, n, nk),
        in_specs=[
            pl.BlockSpec((1, 1, g, e), lambda ib, ih, ik, _: (ib, ih, 0, 0)),
            pl.BlockSpec((1, bk, e), lambda ib, ih, ik, _: (ib * n + ih, ik, 0)),
            pl.BlockSpec((1, bk, e), lambda ib, ih, ik, _: (ib * n + ih, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, e), lambda ib, ih, ik, _: (ib, ih, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g, e), jnp.float32),
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g,), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(_decode_kernel, scale=scale, bk=bk, nk=nk),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, n, g, e), q.dtype),
        interpret=interpret,
    )(lengths.astype(jnp.int32), qr, kr, vr)
    return out.reshape(b, h, e)
