"""Declarative workflow specifications — the canonical workflow definition.

A :class:`WorkflowSpec` describes an agentic RAG workflow once and derives
BOTH runtime artifacts from that single description:

- ``build_dag(trace)``   -> the :class:`DynamicDAG` the scheduler executes
  (including the dynamic branch expanders of paper §3.1), and
- ``build_template(means)`` -> the Eq. 4 :class:`WorkflowTemplate` used as
  the future-criticality prior.

This collapses the duplication that used to live in
``rag/workflow.py`` between ``build_w1/w2/w3`` and ``make_template`` and
makes user-defined workflows first-class: compose :class:`StageSpec`,
:class:`BranchGroup` and :class:`CollectorSpec` and hand the spec to
``HeroSession.submit(trace, spec=...)``.

Vocabulary
----------
- *statics*: stages known before execution (G_obs(0)).
- *branch groups*: sub-graphs spawned at runtime by a decision stage
  (query rewriter, search planner) — the dynamic inter-stage dependencies
  of §3.1.  ``progressive`` groups release branches per finished
  token-group of the source decode, so the first sub-query's retrieval
  starts before the rewriter finishes (the paper's motivating example).
- *collector*: the paper's RECOMP-style per-branch refine + chunked chat
  prefill pattern; fine-grained mode chains one chat-prefill piece per
  refined branch (§4.2), coarse mode gates a monolithic prefill on all
  branch tails.

Workload callables receive a :class:`View` — one canonical namespace over
either a concrete ``QueryTrace`` (ints, for the DAG) or a means dict
(floats, for the template prior) — so each workload formula is written
exactly once.
"""
from __future__ import annotations

import dataclasses
import warnings
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Mapping, Optional, Tuple

from repro.core.dag import DynamicDAG, Node, WorkflowTemplate

Workload = Callable[["View"], float]

_ONE: Workload = lambda v: 1  # noqa: E731

# QueryTrace field -> canonical View name (means dicts already use these)
_TRACE_ALIASES = {"rerank_candidates": "rerank", "n_web_searches": "n_web"}


class View:
    """Attribute bag over a trace or a means dict (canonical names)."""

    def __init__(self, values: Dict[str, Any]):
        self.__dict__.update(values)

    @classmethod
    def of(cls, source) -> "View":
        if isinstance(source, View):
            return source
        if isinstance(source, Mapping):
            return cls(dict(source))
        vals = {}
        for k in dir(source):
            if k.startswith("_"):
                continue
            val = getattr(source, k)
            if isinstance(val, (int, float, str)):
                vals[_TRACE_ALIASES.get(k, k)] = val
            elif isinstance(val, tuple):
                # structured trace fields (e.g. retrieved chunk_ids — the
                # prefix-cache content keys) pass through verbatim
                vals[_TRACE_ALIASES.get(k, k)] = val
        return cls(vals)


@dataclass(frozen=True)
class DecodeSpec:
    """Typed decode-side configuration of one :class:`StageSpec`.

    Collapses the stringly decode knobs into one validated object:

    - ``kv_stage``: the decode stage whose profiled KV shape denominates
      this stage's cache pages — what the deprecated ``StageSpec.kv_stage``
      kwarg used to stamp as raw ``payload["kv_decode_stage"]``.  Custom
      specs whose stage names do not follow the ``*_prefill``/``*_decode``
      convention MUST set it (see :func:`repro.core.kv_pages.decode_stage_for`).
    - ``draft_model``: the in-tree draft family allowed to speculate for
      this decode stage (validated against ``rag.stages.DRAFT_MODELS``).
      When the session-level draft differs, speculation is disabled for
      this stage rather than run under the wrong draft.
    - ``draft_width``: per-stage draft-width pin.  The scheduler snaps it
      to the profiled width grid and skips the batch policy's candidate
      search for this stage.

    ``build_dag`` stamps the validated object as ``payload["decode_spec"]``;
    the paged-KV tracker and the scheduler consume it typed-first, keeping
    the legacy ``kv_decode_stage`` payload key as a fallback for
    hand-built nodes.
    """

    kv_stage: Optional[str] = None
    draft_model: Optional[str] = None
    draft_width: Optional[int] = None

    def __post_init__(self):
        if self.draft_width is not None and self.draft_width < 1:
            raise ValueError(
                f"DecodeSpec.draft_width must be >= 1, got "
                f"{self.draft_width!r}")
        if self.draft_model is not None:
            from repro.rag.stages import DRAFT_MODELS
            if self.draft_model not in DRAFT_MODELS:
                raise ValueError(
                    f"DecodeSpec.draft_model {self.draft_model!r} is not "
                    f"an in-tree draft family; pick from "
                    f"{sorted(DRAFT_MODELS)}")


@dataclass(frozen=True)
class StageSpec:
    """One statically-known stage."""

    id: str
    stage: str                                # perf-model key
    kind: str                                 # batchable | stream_* | search | io
    workload: Workload
    deps: Tuple[str, ...] = ()
    template: Optional[str] = None            # template stage id (default: id)
    mean_workload: Optional[Workload] = None  # template-side override
    template_deps: Optional[Tuple[str, ...]] = None
    role: Optional[str] = None                # baseline static-map role
    # opt this stage out of cross-query batch coalescing (e.g. stages with
    # per-query side effects that must not share a dispatch)
    coalescable: bool = True
    # stream_prefill only: tokens at the HEAD of this prefill that encode
    # raw retrieved context (prompt order: [shared context][query...]) —
    # prefix-cacheable across queries retrieving the same chunk ids.
    # Stamped as payload["prefix_segments"] when the trace carries
    # chunk_ids; the paged-KV prefix cache keys page hashes off it
    shared_ctx: Optional[Workload] = None
    # typed decode-side configuration: KV-shape override + speculative
    # draft placement (model / width pins).  See :class:`DecodeSpec`
    decode: Optional[DecodeSpec] = None
    # DEPRECATED: pass ``decode=DecodeSpec(kv_stage=...)`` instead.  Kept
    # as a shim that folds into ``decode`` with a DeprecationWarning
    kv_stage: Optional[str] = None

    def __post_init__(self):
        if self.kv_stage is None:
            return
        warnings.warn(
            "StageSpec.kv_stage is deprecated; pass "
            "decode=DecodeSpec(kv_stage=...) instead",
            DeprecationWarning, stacklevel=3)
        dec = self.decode
        if dec is None:
            dec = DecodeSpec(kv_stage=self.kv_stage)
        elif dec.kv_stage is None:
            dec = dataclasses.replace(dec, kv_stage=self.kv_stage)
        elif dec.kv_stage != self.kv_stage:
            raise ValueError(
                f"StageSpec {self.id!r}: deprecated kv_stage="
                f"{self.kv_stage!r} conflicts with decode.kv_stage="
                f"{dec.kv_stage!r}")
        object.__setattr__(self, "decode", dec)

    @property
    def tid(self) -> str:
        return self.template or self.id


@dataclass(frozen=True)
class BranchStage:
    """One stage of a dynamically-spawned branch.  ``id`` is a format
    string over the branch index ``{i}``; deps may reference ``$source``
    (the decision node that spawned the branch), ``$prev`` (the previous
    stage in this branch) or any static stage id."""

    id: str
    stage: str
    kind: str
    workload: Workload
    deps: Tuple[str, ...]
    template: str
    mean_workload: Optional[Workload] = None
    template_deps: Optional[Tuple[str, ...]] = None
    role: Optional[str] = None
    coalescable: bool = True                  # see StageSpec.coalescable


@dataclass(frozen=True)
class BranchGroup:
    """Branches spawned by ``source`` at runtime (dynamic deps, §3.1)."""

    source: str                               # static id of the decision stage
    count: Workload                           # branches per query
    stages: Tuple[BranchStage, ...]
    label: str = "b{i}"                       # per-branch key (collector ids)
    progressive: bool = False                 # spawn per source token-group
    to_collector: bool = True                 # tail feeds the refine/chat sink


@dataclass(frozen=True)
class CollectorSpec:
    """RECOMP-style refine of every branch + (chunked) chat generation."""

    base_dep: str                             # static id of the base branch tail
    refine_prefill: str = "refine_prefill"
    refine_decode: str = "refine_decode"
    chat_prefill: str = "chat_prefill"
    chat_decode: str = "chat_decode"
    context: Workload = lambda v: v.context_tokens
    refine_out: Workload = lambda v: v.refine_tokens
    query: Workload = lambda v: v.query_tokens
    answer: Workload = lambda v: v.answer_tokens
    ctx_floor: int = 32
    refine_floor: int = 8
    role: str = "chat"


@dataclass(frozen=True)
class WorkflowSpec:
    name: str
    statics: Tuple[StageSpec, ...]
    groups: Tuple[BranchGroup, ...] = ()
    collector: Optional[CollectorSpec] = None

    # -- helpers -------------------------------------------------------------
    def _static(self, sid: str) -> StageSpec:
        for s in self.statics:
            if s.id == sid:
                return s
        raise KeyError(f"{self.name}: unknown static stage {sid!r}")

    def final_decode(self) -> Optional[str]:
        """Template id of the answer-generation decode stage (the target of
        per-token streaming callbacks)."""
        if self.collector is not None:
            return self.collector.chat_decode
        for s in reversed(self.statics):
            if s.kind == "stream_decode":
                return s.tid
        return None

    def stage_roles(self) -> Dict[str, str]:
        """Perf-stage -> role map for baseline static mappings
        (``strategy_config``)."""
        default = {"search": "search", "io": "io"}
        roles: Dict[str, str] = {}
        for s in self.statics:
            if s.role is not None:
                roles[s.stage] = s.role
            else:
                roles.setdefault(s.stage, default.get(s.kind, "chat"))
        for g in self.groups:
            for bs in g.stages:
                if bs.role is not None:
                    roles[bs.stage] = bs.role
                else:
                    # a branch stage reusing a static's perf stage (embed_sq
                    # -> "embed") inherits that static's role
                    roles.setdefault(bs.stage, default.get(bs.kind, "chat"))
        if self.collector is not None:
            c = self.collector
            for stage in (c.refine_prefill, c.refine_decode,
                          c.chat_prefill, c.chat_decode):
                roles.setdefault(stage, c.role)
        return roles

    # -- DAG derivation ------------------------------------------------------
    def build_dag(self, trace, fine_grained: bool = True, prefix: str = "",
                  dag: Optional[DynamicDAG] = None,
                  gate_dep: Optional[str] = None,
                  validate: bool = False) -> DynamicDAG:
        """Materialize G_obs(0) (+ runtime expanders) for one query.

        ``gate_dep``: optional node id every root stage depends on — the
        session's admission gate (a timer node carrying the query's
        arrival time).

        ``validate``: run ``repro.analysis.validate`` over this spec
        first — structural errors (dep cycles, unknown deps, DecodeSpec
        placement, the kv_stage naming trap) raise
        :class:`repro.analysis.validate.SpecValidationError` before any
        node is materialized.  Off by default (the session enables it
        via ``SessionOptions.validate_spec``); imported lazily so the
        core build path never depends on the analysis package."""
        if validate:
            from repro.analysis.validate import ensure_valid
            ensure_valid(spec=self)
        dag = dag if dag is not None else DynamicDAG()
        v = View.of(trace)
        col = self.collector

        def N(s: str) -> str:
            return prefix + s

        def W(fn: Workload) -> int:
            return max(int(fn(v)), 1)

        def add(d, nid, stage, kind, workload, deps, template,
                coalescable=True, shared_ctx=0, decode=None):
            n = d.add(Node(id=nid, stage=stage, kind=kind,
                           workload=max(int(workload), 1),
                           deps=set(deps), template=template))
            if not coalescable:
                n.payload["no_coalesce"] = True
            if decode is not None:
                # typed decode-side config (DecodeSpec): the paged tracker
                # reads its kv_stage instead of guessing by the
                # *_prefill/*_decode naming convention; the scheduler reads
                # its draft_model / draft_width pins for spec decoding
                n.payload["decode_spec"] = decode
            if kind == "stream_decode":
                # base KV context the stream inherits from its prefill
                # deps — what KV-residency tracking charges before any
                # decoded tokens (fine-grained chat decodes override this
                # below with the full chunked context)
                n.payload["kv_ctx"] = sum(
                    d.nodes[dep].workload for dep in n.deps
                    if d.nodes[dep].kind == "stream_prefill")
                for dep in n.deps:
                    if d.nodes[dep].kind == "stream_prefill":
                        # link prefill pieces to the decode stream whose
                        # cache they fill (paged-KV page adoption)
                        d.nodes[dep].payload["kv_stream"] = n.id
            elif kind == "stream_prefill" and shared_ctx > 0:
                kvs = decode.kv_stage if decode is not None else None
                if kvs is None and not stage.endswith("_prefill"):
                    # the convention trap, caught at build time: without
                    # an override the tracker would page this prefill's
                    # cache under a guessed (wrong) decode shape — warn
                    # and fall back to no prefix caching instead
                    warnings.warn(
                        f"{self.name}: stage {stage!r} (node {nid!r}) "
                        "declares shared_ctx but does not follow the "
                        "*_prefill naming convention and sets no "
                        "StageSpec.kv_stage override — prefix caching "
                        "disabled for it to avoid paging its KV under "
                        "the wrong profiled shape",
                        RuntimeWarning, stacklevel=2)
                    return n
                chunks = getattr(v, "chunk_ids", ())
                if chunks:
                    # prefix-cache content identity, in prompt order: the
                    # shared retrieved-context head (keyed by the BARE
                    # stage id + chunk ids, so every admitted query
                    # retrieving the same chunks maps to the same pages)
                    # then the per-query remainder (keyed by the full node
                    # id — never shared)
                    head = min(int(shared_ctx), n.workload)
                    bare = (nid[len(prefix):]
                            if prefix and nid.startswith(prefix) else nid)
                    segs = [(f"ctx:{bare}:{','.join(map(str, chunks))}",
                             head)]
                    if n.workload > head:
                        segs.append((f"q:{nid}", n.workload - head))
                    n.payload["prefix_segments"] = tuple(segs)
            return n

        gate = [gate_dep] if gate_dep is not None else []

        # collector sizing: per-source context/refine pieces
        refine_tails: List[str] = []
        chat_state = {"last": None, "pieces": 0}
        if col is not None:
            n_sources = 1 + sum(int(g.count(v)) for g in self.groups
                                if g.to_collector)
            ctx_piece = max(int(col.context(v)) // n_sources, col.ctx_floor)
            refine_piece = max(int(col.refine_out(v)) // n_sources,
                               col.refine_floor)
            q_tokens = int(col.query(v))

        def add_chat_piece(d: DynamicDAG, dep: str):
            if col is None or not fine_grained:
                return
            prev = chat_state["last"]
            nid = N(f"{col.chat_prefill}_{chat_state['pieces']}")
            add(d, nid, col.chat_prefill, "stream_prefill", ctx_piece,
                deps=[dep, prev], template=col.chat_prefill)
            chat_state["last"] = nid
            chat_state["pieces"] += 1
            if N(col.chat_decode) in d.nodes:
                d.retarget_dep(N(col.chat_decode), prev, nid)

        def add_branch_refine(d: DynamicDAG, key: str, dep: str):
            # a refine prefill reads a raw retrieved-context piece: fully
            # prefix-shareable across queries on the same chunk ids
            rp = add(d, N(f"{col.refine_prefill}_{key}"), col.refine_prefill,
                     "stream_prefill", ctx_piece, deps=[dep],
                     template=col.refine_prefill, shared_ctx=ctx_piece)
            rd = add(d, N(f"{col.refine_decode}_{key}"), col.refine_decode,
                     "stream_decode", refine_piece, deps=[rp.id],
                     template=col.refine_decode)
            refine_tails.append(rd.id)
            if fine_grained:
                add_chat_piece(d, rd.id)
            elif N(col.chat_prefill) in d.nodes:
                d.add_edge(rd.id, N(col.chat_prefill))
            return rd

        # statics (the collector's base refine chain is inserted right after
        # its base_dep stage, preserving the legacy builders' graph order)
        for s in self.statics:
            deps = [N(d) for d in s.deps] if s.deps else list(gate)
            add(dag, N(s.id), s.stage, s.kind, W(s.workload), deps=deps,
                template=s.tid, coalescable=s.coalescable,
                shared_ctx=(int(s.shared_ctx(v))
                            if s.shared_ctx is not None else 0),
                decode=s.decode)
            if col is not None and s.id == col.base_dep:
                # base-branch refine; its chat piece is the chain head (it
                # carries the query tokens), not an add_chat_piece link
                rp = add(dag, N(f"{col.refine_prefill}_base"),
                         col.refine_prefill, "stream_prefill", ctx_piece,
                         deps=[N(s.id)], template=col.refine_prefill,
                         shared_ctx=ctx_piece)
                rd = add(dag, N(f"{col.refine_decode}_base"),
                         col.refine_decode, "stream_decode", refine_piece,
                         deps=[rp.id], template=col.refine_decode)
                refine_tails.append(rd.id)
                if fine_grained:
                    nid = N(f"{col.chat_prefill}_0")
                    add(dag, nid, col.chat_prefill, "stream_prefill",
                        ctx_piece + q_tokens, deps=[rd.id],
                        template=col.chat_prefill)
                    chat_state["last"], chat_state["pieces"] = nid, 1

        # dynamic branch groups: wire expanders onto the decision stages
        for g in self.groups:
            self._wire_group(dag, g, v, N, add, add_branch_refine,
                             fine_grained)

        # chat tail gated on every decision stage, so dynamically-spawned
        # branches are always observed before generation starts
        if col is not None:
            gate_ids = [N(g.source) for g in self.groups]
            if fine_grained:
                cd = add(dag, N(col.chat_decode), col.chat_decode,
                         "stream_decode", int(col.answer(v)),
                         deps=[chat_state["last"]] + gate_ids,
                         template=col.chat_decode)
                cd.payload["chat_state"] = chat_state
                # fine-grained mode chains one prefill piece per branch:
                # the decode's KV holds the WHOLE chunked context, not
                # just its direct dep's piece
                cd.payload["kv_ctx"] = int(col.context(v)) + q_tokens
            else:
                add(dag, N(col.chat_prefill), col.chat_prefill,
                    "stream_prefill", int(col.context(v)) + q_tokens,
                    deps=refine_tails + gate_ids, template=col.chat_prefill)
                add(dag, N(col.chat_decode), col.chat_decode, "stream_decode",
                    int(col.answer(v)), deps=[N(col.chat_prefill)],
                    template=col.chat_decode)
        return dag

    def _wire_group(self, dag, g: BranchGroup, v: View, N, add,
                    add_branch_refine, fine_grained: bool):
        count = int(g.count(v))
        src = dag.nodes[N(g.source)]
        per_piece = max(src.workload // max(count, 1), 1)
        state = {"done": 0, "spawned": 0}

        def spawn(d: DynamicDAG, i: int, dep_id: str):
            prev = dep_id
            for bs in g.stages:
                deps = []
                for dep in bs.deps:
                    if dep == "$source":
                        deps.append(dep_id)
                    elif dep == "$prev":
                        deps.append(prev)
                    else:
                        deps.append(N(dep))
                node = add(d, N(bs.id.format(i=i)), bs.stage, bs.kind,
                           max(int(bs.workload(v)), 1), deps=deps,
                           template=bs.template,
                           coalescable=bs.coalescable)
                prev = node.id
            if g.to_collector and self.collector is not None:
                add_branch_refine(d, g.label.format(i=i), prev)

        def expander(d: DynamicDAG, node: Node):
            while state["spawned"] < count:
                spawn(d, state["spawned"], node.id)
                state["spawned"] += 1

        src.expander = expander
        if g.progressive:
            def on_progress(d: DynamicDAG, piece: Node, tokens_done: int):
                state["done"] += tokens_done
                while (state["spawned"] < count
                       and state["done"] >= (state["spawned"] + 1)
                       * per_piece):
                    spawn(d, state["spawned"], piece.id)
                    state["spawned"] += 1

            src.payload["on_progress"] = on_progress

    # -- template derivation (Eq. 4 prior) -----------------------------------
    def build_template(self, means) -> WorkflowTemplate:
        """Derive the future-criticality prior from the SAME spec.  ``means``
        is a historical-means dict (``default_means``) or any trace-like
        object exposing the spec's workload fields."""
        v = View.of(means)
        t = WorkflowTemplate()
        tid_of = {s.id: s.tid for s in self.statics}

        def mw(spec_stage) -> float:
            fn = spec_stage.mean_workload or spec_stage.workload
            return float(fn(v))

        for s in self.statics:
            deps = s.template_deps if s.template_deps is not None else s.deps
            t.add_stage(s.tid, s.stage, s.kind, mw(s), 1.0,
                        deps=[tid_of.get(d, d) for d in deps])
        for g in self.groups:
            prev_t = tid_of[g.source]
            for bs in g.stages:
                deps = (bs.template_deps if bs.template_deps is not None
                        else bs.deps)
                mapped = []
                for dep in deps:
                    if dep == "$source":
                        mapped.append(tid_of[g.source])
                    elif dep == "$prev":
                        mapped.append(prev_t)
                    else:
                        mapped.append(tid_of.get(dep, dep))
                t.add_stage(bs.template, bs.stage, bs.kind, mw(bs),
                            float(g.count(v)), deps=mapped)
                prev_t = bs.template
        col = self.collector
        if col is not None:
            n_sources = 1.0 + sum(float(g.count(v)) for g in self.groups
                                  if g.to_collector)
            ctx_piece = max(float(col.context(v)) / n_sources, col.ctx_floor)
            ref_piece = max(float(col.refine_out(v)) / n_sources,
                            col.refine_floor)
            refine_deps = [tid_of[col.base_dep]] + [
                g.stages[-1].template for g in self.groups if g.to_collector]
            t.add_stage(col.refine_prefill, col.refine_prefill,
                        "stream_prefill", ctx_piece, n_sources,
                        deps=refine_deps)
            t.add_stage(col.refine_decode, col.refine_decode, "stream_decode",
                        ref_piece, n_sources, deps=[col.refine_prefill])
            t.add_stage(col.chat_prefill, col.chat_prefill, "stream_prefill",
                        ctx_piece + float(col.query(v)), n_sources,
                        deps=[col.refine_decode])
            t.add_stage(col.chat_decode, col.chat_decode, "stream_decode",
                        float(col.answer(v)), 1.0, deps=[col.chat_prefill])
        return t


# ---------------------------------------------------------------------------
# builtin specs: the paper's W1-W3 (§6.1)
# ---------------------------------------------------------------------------

def _retrieval_statics(base: bool) -> List[StageSpec]:
    """chunk-embedding + query-embedding + vector search + rerank."""
    sfx = "_base" if base else ""
    return [
        StageSpec("embed_chunks", "embed", "batchable",
                  lambda v: v.n_chunks, role="embed"),
        StageSpec("embed_query", "embed", "batchable", _ONE, role="embed"),
        StageSpec(f"vsearch{sfx}", "vsearch", "search",
                  lambda v: v.n_chunks * 8,
                  deps=("embed_chunks", "embed_query"),
                  template="vsearch", role="search"),
        StageSpec(f"rerank{sfx}", "rerank", "batchable", lambda v: v.rerank,
                  deps=(f"vsearch{sfx}",), template="rerank", role="rerank"),
    ]


def w1_spec() -> WorkflowSpec:
    """W1 Fast Document Finder: chunk→embed→index→retrieve→rerank→generate."""
    statics = _retrieval_statics(base=False) + [
        StageSpec("chat_prefill", "chat_prefill", "stream_prefill",
                  lambda v: v.context_tokens + v.query_tokens,
                  deps=("rerank",), role="chat",
                  shared_ctx=lambda v: v.context_tokens),
        StageSpec("chat_decode", "chat_decode", "stream_decode",
                  lambda v: v.answer_tokens, deps=("chat_prefill",),
                  role="chat"),
    ]
    return WorkflowSpec("w1", tuple(statics))


def _subquery_group() -> BranchGroup:
    """The rewriter's dynamic sub-query branches (progressive release)."""
    return BranchGroup(
        source="rewrite_decode", count=lambda v: v.n_subqueries,
        label="sq{i}", progressive=True,
        stages=(
            BranchStage("embed_sq{i}", "embed", "batchable", _ONE,
                        deps=("$source",), template="embed_sq"),
            BranchStage("vsearch_sq{i}", "vsearch", "search",
                        lambda v: v.n_chunks * 8,
                        deps=("$prev", "embed_chunks"),
                        template="vsearch_sq", template_deps=("$prev",)),
            BranchStage("rerank_sq{i}", "rerank", "batchable",
                        lambda v: max(v.rerank // 2, 4),
                        mean_workload=lambda v: v.rerank / 2,
                        deps=("$prev",), template="rerank_sq"),
        ))


def _web_group() -> BranchGroup:
    """The planner's web-search branches (spawned on plan completion)."""
    return BranchGroup(
        source="plan_decode", count=lambda v: v.n_web, label="web{i}",
        progressive=False,
        stages=(
            BranchStage("web{i}", "web", "io", _ONE, deps=("$source",),
                        template="web", role="io"),
            BranchStage("embed_web{i}", "embed", "batchable", lambda v: 4,
                        deps=("$prev",), template="embed_web"),
        ))


def _agentic_spec(name: str, planner: bool) -> WorkflowSpec:
    statics = _retrieval_statics(base=True) + [
        StageSpec("rewrite_prefill", "rewrite_prefill", "stream_prefill",
                  lambda v: v.query_tokens, role="search_llm"),
        StageSpec("rewrite_decode", "rewrite_decode", "stream_decode",
                  lambda v: v.rewrite_tokens, deps=("rewrite_prefill",),
                  role="search_llm"),
    ]
    groups = [_subquery_group()]
    if planner:
        statics += [
            StageSpec("plan_prefill", "plan_prefill", "stream_prefill",
                      lambda v: v.query_tokens, role="search_llm"),
            StageSpec("plan_decode", "plan_decode", "stream_decode",
                      lambda v: v.plan_tokens, deps=("plan_prefill",),
                      role="search_llm"),
        ]
        groups.append(_web_group())
    return WorkflowSpec(name, tuple(statics), tuple(groups),
                        CollectorSpec(base_dep="rerank_base"))


def w2_spec() -> WorkflowSpec:
    """W2 Advanced Document QA: + LLM query rewriting + per-branch refine."""
    return _agentic_spec("w2", planner=False)


def w3_spec() -> WorkflowSpec:
    """W3 Deep Researcher: + search planner issuing web requests."""
    return _agentic_spec("w3", planner=True)


_BUILTINS: Dict[int, Callable[[], WorkflowSpec]] = {
    1: w1_spec, 2: w2_spec, 3: w3_spec}


def builtin_spec(wf) -> WorkflowSpec:
    """The paper's workflow ``wf`` as a WorkflowSpec: 1/2/3 or the
    equivalent "w1"/"w2"/"w3" names (what mixed-workflow benchmark
    configs and CLI flags pass around)."""
    if isinstance(wf, str):
        wf = int(wf.lower().lstrip("w"))
    return _BUILTINS[wf]()
