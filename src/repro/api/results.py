"""Per-query results assembled from a backend run.

The backends execute one shared :class:`DynamicDAG`; this module slices
the node-level record (start/finish/config on every node, plus the event
timeline) back into per-query :class:`QueryResult` views.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

from repro.core.dag import DONE, DynamicDAG
from repro.core.events import EV_START, REDISPATCH_EVENTS

ADMIT_STAGE = "admit"     # session-inserted arrival-timer nodes


@dataclass
class QueryResult:
    qid: int
    workflow: str                       # WorkflowSpec name
    backend: str
    arrival_time: float
    finish_time: float                  # run-relative completion of last node
    makespan: float                     # finish_time - arrival_time
    stage_latency: Dict[str, float] = field(default_factory=dict)
    pu_busy: Dict[str, float] = field(default_factory=dict)
    dispatches: int = 0
    redispatches: int = 0
    n_nodes: int = 0
    # nodes of this query that ran inside a cross-query fused dispatch
    coalesced_nodes: int = 0
    # token-group rounds this query's decode streams spent resident in a
    # continuous cross-query decode batch
    decode_rounds: int = 0
    # KV-cache migrations this query's decode streams paid (resident
    # rounds moving PU under kv_residency tracking) and the bytes shipped
    kv_migrations: int = 0
    kv_bytes_moved: float = 0.0
    # spill-tier gathers this query's decode streams paid (pages fetched
    # back from dram/disk at dispatch; zero unless ``kv_pages`` is on)
    kv_fetches: int = 0
    kv_fetched_bytes: float = 0.0
    # paged-KV prefix-cache hits on this query's prefills and the prefill
    # tokens those hits skipped (zero unless ``kv_pages`` is on)
    kv_page_hits: int = 0
    kv_hit_tokens: int = 0
    # prefix hits the hit-or-recompute rule declined on this query's
    # prefills (fetching the demoted pages would have cost more than
    # re-prefilling them)
    kv_hit_declined: int = 0
    # predictive-prefetch staging attributed to this query's nodes (zero
    # unless ``kv_prefetch`` is on): groups issued, bytes staged, and
    # staged pages a later dispatch found already resident
    kv_prefetches: int = 0
    kv_prefetch_bytes: float = 0.0
    kv_prefetch_hits: int = 0
    # SLO class the query was submitted under, its optional latency
    # budget (seconds from arrival), and whether the budget held — None
    # when no deadline was given
    slo_class: str = "interactive"
    deadline: Optional[float] = None
    deadline_met: Optional[bool] = None
    # times this query's nodes were released from a preempted fused
    # dispatch (boundary splits; sums to BackendRun.preemptions across
    # queries on either backend)
    preemptions: int = 0
    # speculative decoding (zero unless ``spec_decode`` is on): draft
    # candidate tokens proposed for this query's decode streams, how many
    # the target model accepted, and the resulting per-query accept rate.
    # Payload-attributed per member at round boundaries, so per-query
    # counts sum to the BackendRun totals on either backend
    drafted_tokens: int = 0
    accepted_tokens: int = 0
    accept_rate: Optional[float] = None
    # the query was withdrawn via QueryHandle.cancel() mid-run (metrics
    # cover only the work that completed before the cancel took effect)
    cancelled: bool = False

    def utilization(self, pu: str) -> float:
        """Fraction of this query's latency window ``pu`` spent on it."""
        return self.pu_busy.get(pu, 0.0) / max(self.makespan, 1e-9)


def collect_results(dag: DynamicDAG, handles, run, backend_name: str
                    ) -> List[QueryResult]:
    """Slice one shared-DAG :class:`BackendRun` into per-query results.

    ``handles``: QueryHandle list (each carries ``qid``/``prefix``/
    ``arrival_time``); nodes and events are attributed by id prefix."""
    out = []
    for h in handles:
        nodes = [n for nid, n in dag.nodes.items()
                 if nid.startswith(h.prefix) and n.stage != ADMIT_STAGE]
        stage_latency: Dict[str, float] = {}
        pu_busy: Dict[str, float] = {}
        finish = h.arrival_time
        coalesced = rounds = kv_migs = page_hits = hit_tokens = 0
        hit_declined = prefetches = prefetch_hits = preempts = 0
        drafted = accepted = fetches = 0
        kv_bytes = prefetch_bytes = fetched_bytes = 0.0
        for n in nodes:
            # preemption releases survive even on nodes a later cancel
            # finalized without running (start < 0)
            preempts += n.payload.get("preemptions", 0)
            if n.status != DONE or n.start < 0:
                continue
            kv_migs += n.payload.get("kv_migrations", 0)
            kv_bytes += n.payload.get("kv_bytes_moved", 0.0)
            fetches += n.payload.get("kv_fetches", 0)
            fetched_bytes += n.payload.get("kv_fetched_bytes", 0.0)
            page_hits += n.payload.get("kv_page_hits", 0)
            hit_tokens += n.payload.get("kv_hit_tokens", 0)
            hit_declined += n.payload.get("kv_hit_declined", 0)
            prefetches += n.payload.get("kv_prefetches", 0)
            prefetch_bytes += n.payload.get("kv_prefetch_bytes", 0.0)
            prefetch_hits += n.payload.get("kv_prefetch_hits", 0)
            drafted += n.payload.get("spec_drafted", 0)
            accepted += n.payload.get("spec_accepted", 0)
            dur = n.finish - n.start
            # stage latency is wall time in the stage; PU busy is charged
            # by workload share when the node rode a fused (coalesced)
            # dispatch, so per-query busy sums match real PU occupancy
            share = n.payload.get("fused_share", 1.0)
            if "coalesced" in n.payload:
                coalesced += 1
            rounds += n.payload.get("decode_rounds", 0)
            stage_latency[n.stage] = stage_latency.get(n.stage, 0.0) + dur
            acc = n.payload.get("pu_busy_acc")
            if acc is not None:
                # continuous-batching member: PU occupancy accrued per
                # round by live membership share, not wall duration (the
                # stream idles between boundaries while others are served)
                for pu, v in acc.items():
                    pu_busy[pu] = pu_busy.get(pu, 0.0) + v
                if (not n.payload.get("round_final")
                        and n.config is not None):
                    # left the resident batch and finished on a solo
                    # dispatch: charge that final stint by wall time
                    pu_busy[n.config[0]] = (pu_busy.get(n.config[0], 0.0)
                                            + dur * share)
            elif n.config is not None:
                pu_busy[n.config[0]] = (pu_busy.get(n.config[0], 0.0)
                                        + dur * share)
            finish = max(finish, n.finish)
        dispatches = redispatches = 0
        admit_id = f"{h.prefix}{ADMIT_STAGE}"
        for t, event, nid in run.events:
            if not nid.startswith(h.prefix) or nid == admit_id:
                continue
            if event == EV_START:
                dispatches += 1
            elif event in REDISPATCH_EVENTS:
                redispatches += 1
        res = QueryResult(
            qid=h.qid, workflow=h.spec.name, backend=backend_name,
            arrival_time=h.arrival_time, finish_time=finish,
            makespan=finish - h.arrival_time, stage_latency=stage_latency,
            pu_busy=pu_busy, dispatches=dispatches,
            redispatches=redispatches, n_nodes=len(nodes),
            coalesced_nodes=coalesced, decode_rounds=rounds,
            kv_migrations=kv_migs, kv_bytes_moved=kv_bytes,
            kv_fetches=fetches, kv_fetched_bytes=fetched_bytes,
            kv_page_hits=page_hits, kv_hit_tokens=hit_tokens,
            kv_hit_declined=hit_declined, kv_prefetches=prefetches,
            kv_prefetch_bytes=prefetch_bytes,
            kv_prefetch_hits=prefetch_hits,
            slo_class=getattr(h, "slo", "interactive"),
            deadline=getattr(h, "deadline", None),
            preemptions=preempts,
            drafted_tokens=drafted, accepted_tokens=accepted,
            cancelled=bool(getattr(h, "cancelled", False)))
        if drafted > 0:
            res.accept_rate = accepted / drafted
        if res.deadline is not None:
            res.deadline_met = res.makespan <= res.deadline
        h.result = res
        out.append(res)
    return out
