"""Typed session options — the one configuration surface of
:class:`repro.api.session.HeroSession`.

``HeroSession`` grew one sugar kwarg per serving subsystem (``coalesce``,
``batch_policy``, ``kv_residency``, ``kv_pages``, ``kv_prefetch``) plus a
stringly ``cfg_overrides`` dict; invalid combinations (prefetch without
the paged store) only surfaced deep inside the scheduler.
:class:`SessionOptions` replaces that sprawl: one frozen dataclass that
validates combinations at construction and owns the new ``preempt`` /
``slo_admission`` knobs.  The old kwargs remain as thin
``DeprecationWarning`` shims that build an equivalent ``SessionOptions``.

    sess = HeroSession(options=SessionOptions(coalesce=True,
                                              batch_policy="adaptive",
                                              kv_pages=True,
                                              preempt=True,
                                              slo_admission=True))

``scheduler_overrides()`` folds the typed knobs down to the
``SchedulerConfig`` patch the session applies — only non-default fields
are emitted, so a default ``SessionOptions()`` is indistinguishable from
passing nothing (the baseline strategy configs stay untouched and the
PR 2/PR 3 goldens stay bit-identical).  ``cfg_overrides`` stays as the
escape hatch for the long tail of scheduler knobs; its keys are checked
against ``SchedulerConfig`` at construction, and a typed field set
explicitly wins over the same key in ``cfg_overrides`` (the precedence
the deprecated sugar kwargs always had).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Dict, Mapping, Optional

BATCH_POLICIES = ("fixed", "adaptive")
SLO_CLASSES = ("interactive", "batch")


@dataclass(frozen=True)
class SessionOptions:
    # cross-query batch coalescing (multi-query serving; off for the
    # paper's single-query latency protocol)
    coalesce: bool = False
    # "fixed" keeps the SchedulerConfig constants; "adaptive" derives
    # caps/windows/groups online from the profiled grids
    batch_policy: str = "fixed"
    # per-stream KV-residency tracking with modeled migration pricing
    kv_residency: bool = False
    # paged KV subsystem (tiered store + prefix cache); supersedes the
    # monolithic tracker
    kv_pages: bool = False
    # predictive tier prefetch on the paged store (requires kv_pages)
    kv_prefetch: bool = False
    # preemptible fused dispatches: an in-flight cross-query fused
    # dispatch may be split at its next member boundary when a
    # higher-SLO-class node is left waiting (requires coalesce — fused
    # dispatches only exist under it)
    preempt: bool = False
    # SLO-class, tail-aware admission: interactive queries pierce the
    # Eq. 5 gate's batched-mode stand-down, batch queries defer while
    # interactive work waits and the throughput floor holds
    slo_admission: bool = False
    # speculative decoding: decode rounds may dispatch as coupled
    # (draft, verify) pairs the mapper can place on different PUs
    # (requires coalesce — speculation rides continuous decode rounds)
    spec_decode: bool = False
    # draft-model registry key (rag.stages.DRAFT_MODELS) for spec_decode;
    # None keeps the catalog default the stage set was built with
    draft_model: Optional[str] = None
    # run repro.analysis.validate over every submitted WorkflowSpec (and
    # the assembled DAG) before execution: structural errors (dep cycles,
    # unknown deps, DecodeSpec placement, the kv_stage naming trap) raise
    # SpecValidationError up front instead of failing mid-run
    validate_spec: bool = False
    # escape hatch: raw SchedulerConfig field overrides for knobs with no
    # typed surface (keys validated at construction)
    cfg_overrides: Optional[Mapping[str, Any]] = None

    def __post_init__(self):
        if self.batch_policy not in BATCH_POLICIES:
            raise ValueError(f"batch_policy {self.batch_policy!r}; pick "
                             f"from {BATCH_POLICIES}")
        ov = dict(self.cfg_overrides or {})
        if ov:
            from repro.core.scheduler import SchedulerConfig
            valid = {f.name for f in dataclasses.fields(SchedulerConfig)}
            unknown = sorted(set(ov) - valid)
            if unknown:
                raise ValueError(f"cfg_overrides keys {unknown} are not "
                                 f"SchedulerConfig fields")
        # combination checks run on the *effective* values (a typed knob
        # may legally arrive via cfg_overrides)
        eff = {f.name: ov.get(f.name, getattr(self, f.name))
               for f in dataclasses.fields(type(self))
               if f.name != "cfg_overrides"}
        if eff["kv_prefetch"] and not eff["kv_pages"]:
            raise ValueError("kv_prefetch=True requires kv_pages=True "
                             "(prefetch stages pages of the paged store)")
        if eff["preempt"] and not eff["coalesce"]:
            raise ValueError("preempt=True requires coalesce=True "
                             "(preemption splits fused cross-query "
                             "dispatches, which only exist under "
                             "coalescing)")
        if eff["spec_decode"] and not (eff["coalesce"]
                                       and ov.get("decode_batch", True)):
            raise ValueError("spec_decode=True requires coalesce=True with "
                             "decode_batch on (speculative draft/verify "
                             "pairs ride continuous decode rounds, which "
                             "only exist under multi-query coalescing)")
        if eff["draft_model"] is not None:
            if not eff["spec_decode"]:
                raise ValueError("draft_model is only meaningful with "
                                 "spec_decode=True")
            from repro.rag.stages import DRAFT_MODELS
            if eff["draft_model"] not in DRAFT_MODELS:
                raise ValueError(
                    f"draft_model {eff['draft_model']!r} is not an "
                    f"in-tree draft family; pick from "
                    f"{sorted(DRAFT_MODELS)}")

    def scheduler_overrides(self) -> Dict[str, Any]:
        """The ``SchedulerConfig`` patch this options object denotes:
        ``cfg_overrides`` first, then every typed field that differs from
        its default (typed-field precedence — the sugar-kwarg semantics)."""
        out: Dict[str, Any] = dict(self.cfg_overrides or {})
        for f in dataclasses.fields(type(self)):
            # session-level knobs with no SchedulerConfig counterpart
            if f.name in ("cfg_overrides", "validate_spec"):
                continue
            v = getattr(self, f.name)
            if v != f.default:
                out[f.name] = v
        return out
