# HeRo session API — the one way to run HeRo (simulated or live).
#
#   from repro.api import HeroSession
#   sess = HeroSession(world="sd8gen4", family="qwen3", strategy="hero")
#   h = sess.submit(trace, wf=2)
#   [result] = sess.run()
#
# Low-level building blocks (Simulator, HeroScheduler, HeroRuntime, ...)
# stay importable from repro.core / repro.serving for the figure benchmarks.
from repro.api.backends import (  # noqa: F401
    Backend, BackendRun, LiveBackend, SimBackend)
from repro.api.options import SessionOptions  # noqa: F401
from repro.api.results import QueryResult, collect_results  # noqa: F401
from repro.api.session import HeroSession, QueryHandle, make_world  # noqa: F401
from repro.api.spec import (  # noqa: F401
    BranchGroup, BranchStage, CollectorSpec, DecodeSpec, StageSpec,
    WorkflowSpec, builtin_spec)
