"""`HeroSession` — the one entry point for running HeRo workloads.

Owns the expensive, once-per-session setup (SoC spec, ground-truth
profiling, the fitted ``LinearPerfModel``), then serves queries:

    sess = HeroSession(world="sd8gen4", family="qwen3", strategy="hero")
    h0 = sess.submit(trace0, wf=2)
    h1 = sess.submit(trace1, wf=2, arrival_time=4.0)   # admitted at t=4 s
    results = sess.run()                               # List[QueryResult]

- ``backend="sim"`` executes on the event-driven SoC simulator,
  ``backend="live"`` on real ``PUExecutor`` worker threads — same script,
  same scheduler, either substrate (pass a :class:`Backend` instance for
  anything custom).
- ``run(mode="shared")`` merges every submitted query into ONE
  :class:`DynamicDAG` with per-query admission gates (continuous
  multi-query admission: a query whose ``arrival_time`` lies in the
  future is held behind a timer node and released mid-run).
  ``run(mode="isolated")`` instead runs each query on a fresh DAG and a
  fresh scheduler — the single-query latency protocol used by the paper
  benchmarks.
- ``strategy`` picks the scheduler: ``"hero"`` or one of the §6.1
  baselines (``llamacpp_gpu``/``powerserve_npu``/``ayo_like``), with the
  static maps derived from each workflow spec's stage roles.
- All serving-subsystem knobs live on ONE typed object:
  ``options=SessionOptions(...)`` (``repro.api.options``) — ``coalesce``
  (cross-query batch coalescing), ``batch_policy`` ("fixed"|"adaptive"
  caps), ``kv_residency`` (modeled migration pricing), ``kv_pages``
  (tiered paged-KV store + prefix cache), ``kv_prefetch`` (predictive
  tier staging), ``preempt`` (boundary-preemptible fused dispatches),
  ``slo_admission`` (class-aware Eq. 5 gating), plus ``cfg_overrides``
  as the raw :class:`SchedulerConfig` escape hatch.  Combinations are
  validated at construction.  The former per-knob kwargs
  (``coalesce=`` … ``cfg_overrides=``) still work as deprecated shims.
- SLO classes: ``submit(..., slo="interactive"|"batch",
  deadline=seconds)`` tags a query's class (admission/preemption
  optimize interactive p99 under a batch throughput floor when
  ``slo_admission``/``preempt`` are on) and an optional latency budget;
  results report ``slo_class`` / ``deadline_met`` / ``preemptions``.
  ``QueryHandle.cancel()`` withdraws a query — before ``run()`` it is
  simply dropped, mid-run its remaining nodes collapse through the
  backends' cancellation machinery.
- per-query streaming: ``submit(..., on_token=fn, on_stage_done=fn)``.
"""
from __future__ import annotations

import dataclasses
import warnings
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Union

from repro.api.backends import Backend, BackendRun, LiveBackend, SimBackend
from repro.api.options import SLO_CLASSES, SessionOptions
from repro.api.results import ADMIT_STAGE, QueryResult, collect_results
from repro.api.spec import WorkflowSpec, builtin_spec
from repro.core.dag import DynamicDAG, Node
from repro.core.events import EV_DONE, EV_TOKENS
from repro.core.perf_model import (GroundTruthPerf, LinearPerfModel, SoCSpec,
                                   snapdragon_8gen3, snapdragon_8gen4)
from repro.core.scheduler import (HeroScheduler, SchedulerConfig,
                                  strategy_config)

SOCS = {"sd8gen3": snapdragon_8gen3, "sd8gen4": snapdragon_8gen4}
STRATEGIES = ("llamacpp_gpu", "powerserve_npu", "ayo_like", "hero")

# (world name | SoCSpec id, family) -> (soc, gt, perf): profiling +
# regression fitting is deterministic and read-only in use, so sessions
# share it (the cached soc keeps an id()-keyed SoCSpec alive)
_WORLD_CACHE: Dict[tuple, tuple] = {}


def make_world(world: Union[str, SoCSpec], family: str):
    """Resolve (SoC spec, ground truth, fitted perf model).  Cached per
    named world — and per :class:`SoCSpec` *instance* (by identity), so
    re-using one custom spec across sessions profiles once."""
    from repro.configs import get_family
    from repro.rag.stages import build_stages

    key = ((world, family) if isinstance(world, str)
           else (id(world), family))
    if key in _WORLD_CACHE:
        return _WORLD_CACHE[key]
    soc = SOCS[world]() if isinstance(world, str) else world
    gt = GroundTruthPerf(soc, build_stages(get_family(family)))
    perf = LinearPerfModel().fit(gt)
    _WORLD_CACHE[key] = (soc, gt, perf)
    return soc, gt, perf


@dataclass
class QueryHandle:
    qid: int
    trace: Any
    spec: WorkflowSpec
    arrival_time: float = 0.0
    on_token: Optional[Callable] = None
    on_stage_done: Optional[Callable] = None
    prefix: str = ""
    result: Optional[QueryResult] = None
    # SLO class ("interactive" | "batch") and optional latency budget in
    # seconds from arrival; results carry them back as slo_class /
    # deadline_met
    slo: str = "interactive"
    deadline: Optional[float] = None
    cancelled: bool = False
    # the DAG this handle's query is executing on (set for the duration
    # of run(); lets cancel() reach the live cancellation machinery)
    _dag: Optional[DynamicDAG] = None

    def cancel(self) -> None:
        """Withdraw this query.  Before ``run()`` it is dropped at
        admission; during a run its remaining nodes are flagged and
        collapse at the backend's next scheduling point (an in-flight
        fused dispatch shared with other queries drains first)."""
        self.cancelled = True
        if self._dag is not None:
            self._dag.request_cancel(self.prefix)


class HeroSession:
    def __init__(self, world: Union[str, SoCSpec] = "sd8gen4",
                 family: str = "qwen3", strategy: str = "hero",
                 backend: Union[str, Backend] = "sim",
                 options: Optional[SessionOptions] = None,
                 cfg_overrides: Optional[dict] = None,
                 coalesce: Optional[bool] = None,
                 batch_policy: Optional[str] = None,
                 kv_residency: Optional[bool] = None,
                 kv_pages: Optional[bool] = None,
                 kv_prefetch: Optional[bool] = None,
                 fine_grained: Optional[bool] = None,
                 means: Optional[dict] = None,
                 pus: Optional[List[str]] = None,
                 sim_opts: Optional[dict] = None,
                 stage_fns: Optional[dict] = None,
                 timeout: float = 3600.0):
        if strategy not in STRATEGIES:
            raise KeyError(f"strategy {strategy!r}; pick from {STRATEGIES}")
        self.soc, self.gt, self.perf = make_world(world, family)
        self.strategy = strategy
        # deprecated per-knob kwargs: thin shims over SessionOptions (the
        # typed surface, which also validates combinations)
        legacy = {k: v for k, v in (("coalesce", coalesce),
                                    ("batch_policy", batch_policy),
                                    ("kv_residency", kv_residency),
                                    ("kv_pages", kv_pages),
                                    ("kv_prefetch", kv_prefetch),
                                    ("cfg_overrides", cfg_overrides))
                  if v is not None}
        if legacy:
            warnings.warn(
                f"HeroSession kwargs {sorted(legacy)} are deprecated; pass "
                f"options=SessionOptions(...) instead",
                DeprecationWarning, stacklevel=2)
            if options is not None:
                # a kwarg repeating the options= value is merely redundant
                # (ported callers that still forward their old kwargs keep
                # working); a *disagreeing* kwarg is ambiguous and raises
                conflicts = sorted(k for k, v in legacy.items()
                                   if getattr(options, k) != v)
                if conflicts:
                    raise ValueError(
                        f"deprecated kwargs {conflicts} conflict with the "
                        f"values in options=; pass options= OR the "
                        f"per-knob kwargs, not both")
                warnings.warn(
                    f"kwargs {sorted(legacy)} are redundant: options= "
                    f"already carries the same values",
                    DeprecationWarning, stacklevel=2)
            else:
                options = SessionOptions(**legacy)
        self.options = options if options is not None else SessionOptions()
        self.cfg_overrides = self.options.scheduler_overrides()
        self.fine_grained = fine_grained
        self.means = means
        self.pus = list(pus) if pus is not None else [p.name
                                                      for p in self.soc.pus]
        self.timeout = timeout
        if backend == "sim":
            self.backend: Backend = SimBackend(self.gt, **(sim_opts or {}))
        elif backend == "live":
            self.backend = LiveBackend(stage_fns=stage_fns)
        elif isinstance(backend, str):
            raise KeyError(f"backend {backend!r}; pick 'sim', 'live', or "
                           f"pass a Backend instance")
        else:
            self.backend = backend
        self._handles: List[QueryHandle] = []
        self.last_run: Optional[BackendRun] = None

    # -- admission -----------------------------------------------------------
    def submit(self, trace, wf: Optional[int] = None,
               spec: Optional[WorkflowSpec] = None,
               arrival_time: float = 0.0,
               slo: str = "interactive",
               deadline: Optional[float] = None,
               on_token: Optional[Callable] = None,
               on_stage_done: Optional[Callable] = None) -> QueryHandle:
        """Queue one query.  ``wf`` selects a builtin workflow (1-3);
        ``spec`` supplies a custom :class:`WorkflowSpec` instead.
        ``arrival_time`` is run-relative (simulated seconds on the sim
        backend, wall seconds on the live backend); the query's root
        stages are gated until then.  ``slo`` tags the query's class
        ("interactive" holds p99, "batch" fills throughput — acted on
        when ``SessionOptions.slo_admission``/``preempt`` are on);
        ``deadline`` is an optional latency budget in seconds from
        arrival, reported back as ``QueryResult.deadline_met``."""
        if spec is None:
            spec = builtin_spec(wf if wf is not None else 2)
        elif wf is not None:
            raise ValueError("pass either wf= or spec=, not both")
        if slo not in SLO_CLASSES:
            raise ValueError(f"slo {slo!r}; pick from {SLO_CLASSES}")
        if deadline is not None and deadline <= 0:
            raise ValueError(f"deadline must be positive, got {deadline}")
        h = QueryHandle(qid=len(self._handles), trace=trace, spec=spec,
                        arrival_time=float(arrival_time),
                        slo=slo, deadline=deadline,
                        on_token=on_token, on_stage_done=on_stage_done)
        self._handles.append(h)
        return h

    @property
    def queries(self) -> List[QueryHandle]:
        return list(self._handles)

    def reset(self) -> None:
        """Drop queued queries AND the previous run's residue: the last
        :class:`BackendRun` and the handles' backend attachments (a
        reset session used to keep serving stale ``last_run`` state)."""
        for h in self._handles:
            h._dag = None
        self._handles = []
        self.last_run = None

    # -- execution -----------------------------------------------------------
    def run(self, mode: str = "shared",
            timeout: Optional[float] = None) -> List[QueryResult]:
        """Execute every submitted query and return their results (in
        submit order).  ``mode="shared"``: one DAG, one scheduler,
        per-query admission gates.  ``mode="isolated"``: fresh DAG +
        scheduler per query (arrival times ignored) — the paper's
        single-query latency protocol."""
        # queries cancelled before the run starts are simply dropped
        self._handles = [h for h in self._handles if not h.cancelled]
        if not self._handles:
            return []
        timeout = timeout if timeout is not None else self.timeout
        if mode == "shared":
            results = self._run_shared(timeout)
        elif mode == "isolated":
            results = self._run_isolated(timeout)
        else:
            raise ValueError(f"mode {mode!r}; pick 'shared' or 'isolated'")
        self._handles = []
        return results

    def _run_shared(self, timeout: float) -> List[QueryResult]:
        handles = self._handles
        specs, seen = [], set()
        for h in handles:
            if h.spec.name not in seen:
                seen.add(h.spec.name)
                specs.append(h.spec)
        cfg = self._scheduler_cfg(specs)
        fine = (self.fine_grained if self.fine_grained is not None
                else cfg.enable_partition)
        dag = DynamicDAG()
        solo = len(handles) == 1
        for h in handles:
            h.prefix = "" if solo else f"q{h.qid}/"
            gate = None
            if h.arrival_time > 0:
                gate = dag.add(Node(id=f"{h.prefix}admit", stage=ADMIT_STAGE,
                                    kind="io", workload=1,
                                    payload={"arrival": h.arrival_time})).id
            h.spec.build_dag(h.trace, fine_grained=fine, prefix=h.prefix,
                             dag=dag, gate_dep=gate,
                             validate=self.options.validate_spec)
            h._dag = dag    # cancel() routes through the live DAG
        if self.options.validate_spec:
            # graph-level pass over the assembled multi-query DAG
            # (cross-query issues a single spec cannot see)
            from repro.analysis.validate import ensure_valid
            ensure_valid(dag=dag)
        sched = self._scheduler(cfg, specs)
        # query-namespace -> SLO class: covers every node of the query,
        # including ones expanders create mid-run
        sched.slo_classes = {(h.prefix[:-1] if h.prefix else ""): h.slo
                             for h in handles}
        try:
            run = self.backend.execute(dag, sched,
                                       observer=self._observer(handles),
                                       timeout=timeout)
        finally:
            for h in handles:
                h._dag = None
        self.last_run = run
        return collect_results(dag, handles, run, self.backend.name)

    def _run_isolated(self, timeout: float) -> List[QueryResult]:
        out: List[QueryResult] = []
        for h in self._handles:
            h.prefix = ""
            h.arrival_time = 0.0   # no gate in isolated mode: each query
            # runs from t=0 on its own DAG, so results must not offset by it
            cfg = self._scheduler_cfg([h.spec])
            fine = (self.fine_grained if self.fine_grained is not None
                    else cfg.enable_partition)
            dag = h.spec.build_dag(h.trace, fine_grained=fine,
                                   validate=self.options.validate_spec)
            h._dag = dag
            sched = self._scheduler(cfg, [h.spec])
            sched.slo_classes = {"": h.slo}
            try:
                run = self.backend.execute(dag, sched,
                                           observer=self._observer([h]),
                                           timeout=timeout)
            finally:
                h._dag = None
            self.last_run = run
            out.extend(collect_results(dag, [h], run, self.backend.name))
        return out

    # -- internals -----------------------------------------------------------
    def _scheduler_cfg(self, specs: List[WorkflowSpec]) -> SchedulerConfig:
        if self.strategy == "hero":
            cfg = SchedulerConfig()
        else:
            # baseline static maps must pin every stage of every submitted
            # workflow, not just the first one's
            roles: Dict[str, str] = {}
            for spec in specs:
                for stage, role in spec.stage_roles().items():
                    roles.setdefault(stage, role)
            cfg = strategy_config(self.strategy, roles)
        if self.cfg_overrides:
            cfg = dataclasses.replace(cfg, **self.cfg_overrides)
        return cfg

    def _scheduler(self, cfg: SchedulerConfig,
                   specs: List[WorkflowSpec]) -> HeroScheduler:
        template = None
        if cfg.enable_criticality and (self.strategy == "hero"
                                       or self.cfg_overrides):
            means = self._template_means()
            template = specs[0].build_template(means)
            for spec in specs[1:]:   # mixed workflows: union of priors
                for sid, ts in spec.build_template(means).stages.items():
                    template.stages.setdefault(sid, ts)
        return HeroScheduler(self.perf, self.pus, self.soc.dram_bw, cfg,
                             template=template)

    def _template_means(self):
        """Historical means for the Eq. 4 prior: explicit ``means=`` if
        given, else the field-wise mean over every submitted trace (all
        numeric fields, so custom-spec workload formulas resolve too)."""
        if self.means is not None:
            return self.means
        from repro.api.spec import View
        views = [View.of(h.trace).__dict__ for h in self._handles]
        means: Dict[str, float] = {}
        for key in set().union(*views):
            vals = [v[key] for v in views
                    if isinstance(v.get(key), (int, float))]
            if len(vals) == len(views):
                means[key] = float(sum(vals)) / len(vals)
        return means

    def _observer(self, handles: List[QueryHandle]):
        routed = [h for h in handles if h.on_token or h.on_stage_done]
        if not routed:
            return None
        # longest prefix first so "" (solo) never shadows real prefixes
        routed.sort(key=lambda h: -len(h.prefix))

        def observer(t: float, event: str, node: Node):
            # "done": a node (or solo decode piece) finished; "tokens": a
            # resident continuous-batching member advanced one token group
            # at a decode-round boundary without finishing
            if (event not in (EV_DONE, EV_TOKENS)
                    or node.stage == ADMIT_STAGE):
                return
            for h in routed:
                if not node.id.startswith(h.prefix):
                    continue
                if event == EV_DONE and h.on_stage_done is not None:
                    h.on_stage_done(h, node, t)
                if (h.on_token is not None and node.kind == "stream_decode"
                        and node.template == h.spec.final_decode()):
                    # one callback per finished token group (sub-stage
                    # partitioning or decode-round boundaries make this the
                    # streaming granularity)
                    tokens = (node.payload["last_slice"]
                              if event == EV_TOKENS else node.workload)
                    h.on_token(h, tokens, t)
                break

        return observer
