"""Execution backends behind :class:`repro.api.session.HeroSession`.

One :class:`Backend` protocol, two substrates:

- :class:`SimBackend` — the event-driven SoC simulator
  (``repro.core.simulator``), executing against the ground-truth hardware
  model with bandwidth contention and optional fault injection;
- :class:`LiveBackend` — the wall-clock runtime
  (``repro.serving.executor``), driving real ``PUExecutor`` worker
  threads through the same scheduler.

The same session script runs against either via ``backend="sim"|"live"``.
Both backends forward per-node lifecycle events to an observer callback,
which is how the session implements per-query streaming callbacks
(``on_token`` / ``on_stage_done``).

Admission timers: a node with ``kind == "io"`` and ``payload["arrival"]``
completes no earlier than that absolute (run-relative) time — the
simulator charges it ``max(arrival - now, 0)`` seconds of work, the live
backend sleeps the remaining wall-clock delay.  Gating a query's root
stages on such a node is how continuous multi-query admission works on
both substrates.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Protocol, Tuple

from repro.core import checks
from repro.core.dag import DynamicDAG, Node
from repro.core.events import (EV_PREEMPT, EV_REDISPATCH, EV_RETRY,
                               EV_START, EV_STRAGGLER)
from repro.core.perf_model import GroundTruthPerf
from repro.core.scheduler import HeroScheduler
from repro.core.simulator import Simulator

Observer = Callable[[float, str, Node], None]

# BackendRun counters that deliberately have NO per-query QueryResult
# attribution field (repro.analysis.lint rule CNT001 enforces that every
# other counter is paired).  These measure *global* cache pressure or
# round-shared phenomena: an eviction / soft overflow is caused by the
# whole working set, not any one query, and spec_rounds counts shared
# cross-query decode rounds — slicing them per query would assert an
# attribution the physics does not have.
RUN_ONLY_COUNTERS = frozenset({
    "kv_evictions", "kv_evicted_bytes", "kv_soft_overflows", "spec_rounds",
})


@dataclass
class BackendRun:
    """Substrate-independent record of one execution."""

    makespan: float
    events: List[Tuple[float, str, str]]      # (t, event, node id)
    pu_busy: Dict[str, float] = field(default_factory=dict)
    dispatches: int = 0
    redispatches: int = 0
    # chosen-shape histograms from the scheduler's batching policy
    # (decode_width / decode_group / fused_batch) — stamped identically
    # by both substrates so policy telemetry is backend-independent
    batching: Dict[str, Dict[int, int]] = field(default_factory=dict)
    # KV-residency totals (scheduler's tracker; zero when the subsystem
    # is off): decode-round cache moves and the bytes they shipped
    kv_migrations: int = 0
    kv_bytes_moved: float = 0.0
    # spill-tier gathers on the paged store (pages fetched back from
    # dram/disk at dispatch; zero unless ``kv_pages`` is on) — distinct
    # from migrations, which move between PU arenas
    kv_fetches: int = 0
    kv_fetched_bytes: float = 0.0
    # paged-KV totals (zero unless ``kv_pages`` is on): prefix-cache hits,
    # the prefill tokens they skipped, and tier-eviction traffic
    kv_page_hits: int = 0
    kv_hit_tokens: int = 0
    kv_evictions: int = 0
    kv_evicted_bytes: float = 0.0
    # prefix hits declined by the hit-or-recompute rule (fetching the
    # demoted page would cost more than re-prefilling it) and all-pinned
    # capacity breaches (kv_soft_overflow events)
    kv_hit_declined: int = 0
    kv_soft_overflows: int = 0
    # predictive-prefetch totals (zero unless ``kv_prefetch`` is on):
    # staging groups issued, bytes staged, and staged pages the next
    # dispatch found already resident
    kv_prefetches: int = 0
    kv_prefetch_bytes: float = 0.0
    kv_prefetch_hits: int = 0
    # members released from preempted fused dispatches (boundary splits;
    # zero unless ``preempt`` is on).  Counted from "preempt" timeline
    # events on both substrates, so per-query payload-attributed counts
    # sum to this total
    preemptions: int = 0
    # speculative-decoding totals (scheduler's SpecTracker; zero unless
    # ``spec_decode`` is on): draft candidates proposed, candidates the
    # target accepted, and the decode rounds that ran speculatively.
    # Read identically from both substrates, and per-query
    # payload-attributed counts sum to these totals
    drafted_tokens: int = 0
    accepted_tokens: int = 0
    spec_rounds: int = 0


class Backend(Protocol):
    name: str

    def execute(self, dag: DynamicDAG, scheduler: HeroScheduler,
                observer: Optional[Observer] = None,
                timeout: float = 3600.0) -> BackendRun:
        """Run ``dag`` to completion under ``scheduler``."""
        ...


class SimBackend:
    """Wraps :class:`repro.core.simulator.Simulator`.  Time is simulated
    seconds on the modelled SoC; fault-injection knobs mirror the
    simulator's."""

    name = "sim"

    def __init__(self, gt: GroundTruthPerf, straggler_prob: float = 0.0,
                 straggler_slow: float = 4.0, fail_prob: float = 0.0,
                 seed: int = 0):
        self.gt = gt
        self.straggler_prob = straggler_prob
        self.straggler_slow = straggler_slow
        self.fail_prob = fail_prob
        self.seed = seed

    def execute(self, dag: DynamicDAG, scheduler: HeroScheduler,
                observer: Optional[Observer] = None,
                timeout: float = 3600.0) -> BackendRun:
        sim = Simulator(self.gt, scheduler,
                        straggler_prob=self.straggler_prob,
                        straggler_slow=self.straggler_slow,
                        fail_prob=self.fail_prob, seed=self.seed,
                        observer=observer)
        res = sim.run(dag, max_time=timeout)
        spec = getattr(scheduler, "spec", None)
        # count timeline events (fused dispatches fan out to member
        # events), the same convention LiveBackend uses — run-level
        # counters must be backend-independent
        if checks.enabled() and scheduler.kv is not None:
            scheduler.kv.check_quiescent()
        return BackendRun(makespan=res.makespan, events=res.timeline,
                          pu_busy=dict(res.pu_busy),
                          dispatches=sum(1 for e in res.timeline
                                         if e[1] == EV_START),
                          redispatches=sum(1 for e in res.timeline
                                           if e[1] == EV_REDISPATCH),
                          batching={k: dict(v) for k, v in
                                    scheduler.policy_log.items()},
                          kv_migrations=(scheduler.kv.migrations
                                         if scheduler.kv else 0),
                          kv_bytes_moved=(scheduler.kv.bytes_moved
                                          if scheduler.kv else 0.0),
                          kv_fetches=getattr(scheduler.kv, "fetches", 0),
                          kv_fetched_bytes=getattr(scheduler.kv,
                                                   "fetched_bytes", 0.0),
                          kv_page_hits=getattr(scheduler.kv, "hits", 0),
                          kv_hit_tokens=getattr(scheduler.kv,
                                                "hit_tokens", 0),
                          kv_evictions=getattr(scheduler.kv,
                                               "evictions", 0),
                          kv_evicted_bytes=getattr(scheduler.kv,
                                                   "evicted_bytes", 0.0),
                          kv_hit_declined=getattr(scheduler.kv,
                                                  "hit_declined", 0),
                          kv_soft_overflows=getattr(scheduler.kv,
                                                    "soft_overflows", 0),
                          kv_prefetches=getattr(scheduler.kv,
                                                "prefetches", 0),
                          kv_prefetch_bytes=getattr(scheduler.kv,
                                                    "prefetch_bytes", 0.0),
                          kv_prefetch_hits=getattr(scheduler.kv,
                                                   "prefetch_hits", 0),
                          preemptions=sum(1 for e in res.timeline
                                          if e[1] == EV_PREEMPT),
                          drafted_tokens=getattr(spec, "drafted_tokens", 0),
                          accepted_tokens=getattr(spec,
                                                  "accepted_tokens", 0),
                          spec_rounds=getattr(spec, "rounds", 0))


def _instant_fn(node: Node, batch: int):
    return None


class LiveBackend:
    """Wraps :class:`repro.serving.executor.HeroRuntime` over one
    ``PUExecutor`` worker thread per PU.

    ``stage_fns`` maps perf-stage name -> ``(node, batch) -> result``; any
    missing stage runs as an instant no-op, so a bare ``LiveBackend()``
    exercises the real dispatch/heartbeat/retry machinery without models
    ("dry" live mode).  The ``__io__`` entry handles external calls; it is
    wrapped so admission-timer nodes sleep out their remaining arrival
    delay instead.

    With ``coalesce`` on, a stage fn may receive a *fused* node (a
    cross-query coalesced dispatch): ``node.payload["members"]`` lists the
    member nodes, so a coalesce-aware fn can run one batched model call
    and slice results per query; fns that ignore it still work — the
    runtime fans completion out to every member either way.
    """

    name = "live"

    def __init__(self, stage_fns: Optional[Dict[str, Callable]] = None,
                 max_retries: int = 2, poll: float = 0.002):
        self.stage_fns = dict(stage_fns or {})
        self.max_retries = max_retries
        self.poll = poll

    def execute(self, dag: DynamicDAG, scheduler: HeroScheduler,
                observer: Optional[Observer] = None,
                timeout: float = 300.0) -> BackendRun:
        from repro.serving.executor import HeroRuntime, PUExecutor

        inner_io = self.stage_fns.get("__io__", _instant_fn)
        fns = dict(self.stage_fns)
        executors = {p: PUExecutor(p) for p in scheduler.pus if p != "io"}
        rt = HeroRuntime(scheduler, executors, fns,
                         max_retries=self.max_retries, observer=observer)

        def io_fn(node: Node, batch: int):
            arrival = node.payload.get("arrival")
            if arrival is not None:
                # sleep against the runtime's own epoch so "not before
                # arrival" holds in run-relative time (timer threads only
                # start once run() has set _t0)
                base = getattr(rt, "_t0", time.monotonic())
                time.sleep(max(arrival - (time.monotonic() - base), 0.0))
                return None
            return inner_io(node, batch)

        fns["__io__"] = io_fn
        try:
            rt.run(dag, poll=self.poll, timeout=timeout)
        finally:
            for ex in executors.values():
                ex.shutdown()
        if checks.enabled() and scheduler.kv is not None:
            scheduler.kv.check_quiescent()
        events = list(rt.events)
        spec = getattr(scheduler, "spec", None)
        pu_busy: Dict[str, float] = {}
        for n in dag.nodes.values():
            if "coalesced" in n.payload:
                continue    # members share their fused node's interval —
                            # counting both would double-charge the PU
            if n.config is not None and n.start >= 0 and n.finish >= 0:
                pu_busy[n.config[0]] = (pu_busy.get(n.config[0], 0.0)
                                        + n.finish - n.start)
        return BackendRun(
            makespan=dag.makespan(), events=events, pu_busy=pu_busy,
            dispatches=sum(1 for e in events if e[1] == EV_START),
            redispatches=sum(1 for e in events
                             if e[1] in (EV_STRAGGLER, EV_RETRY)),
            batching={k: dict(v) for k, v in
                      scheduler.policy_log.items()},
            kv_migrations=scheduler.kv.migrations if scheduler.kv else 0,
            kv_bytes_moved=(scheduler.kv.bytes_moved
                            if scheduler.kv else 0.0),
            kv_fetches=getattr(scheduler.kv, "fetches", 0),
            kv_fetched_bytes=getattr(scheduler.kv, "fetched_bytes", 0.0),
            kv_page_hits=getattr(scheduler.kv, "hits", 0),
            kv_hit_tokens=getattr(scheduler.kv, "hit_tokens", 0),
            kv_evictions=getattr(scheduler.kv, "evictions", 0),
            kv_evicted_bytes=getattr(scheduler.kv, "evicted_bytes", 0.0),
            kv_hit_declined=getattr(scheduler.kv, "hit_declined", 0),
            kv_soft_overflows=getattr(scheduler.kv, "soft_overflows", 0),
            kv_prefetches=getattr(scheduler.kv, "prefetches", 0),
            kv_prefetch_bytes=getattr(scheduler.kv, "prefetch_bytes", 0.0),
            kv_prefetch_hits=getattr(scheduler.kv, "prefetch_hits", 0),
            preemptions=sum(1 for e in events if e[1] == EV_PREEMPT),
            drafted_tokens=getattr(spec, "drafted_tokens", 0),
            accepted_tokens=getattr(spec, "accepted_tokens", 0),
            spec_rounds=getattr(spec, "rounds", 0))
