"""Serving engine: chunked prefill + continuous batching for one stage model.

Requests are admitted into fixed KV-cache slots; prefill runs in chunks of
``prefill_chunk`` tokens (the paper's chunked-prefill mechanism — each chunk
is a schedulable sub-stage for HeRo), decode runs in token groups.  Requests
whose current positions coincide decode in lockstep batches (XLA shape
buckets — the same shape rigidity HeRo's perf model captures).
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Dict, List, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import Model, build_model
from repro.rag.tokenizer import EOS


@dataclass
class Request:
    rid: int
    prompt_ids: List[int]
    max_new: int
    # runtime
    generated: List[int] = field(default_factory=list)
    prefilled: int = 0
    done: bool = False


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params, *, max_len: int = 1024,
                 prefill_chunk: int = 128, token_group: int = 8):
        self.cfg = cfg
        self.params = params
        self.model: Model = build_model(cfg)
        self.max_len = max_len
        self.prefill_chunk = prefill_chunk
        self.token_group = token_group
        self._rid = itertools.count()
        self.queue: List[Request] = []
        self.active: Dict[int, dict] = {}    # rid -> {cache, req}
        self._decode = jax.jit(self.model.decode_step)

    # -- API -----------------------------------------------------------------
    def submit(self, prompt_ids: Sequence[int], max_new: int = 32) -> int:
        rid = next(self._rid)
        self.queue.append(Request(rid, list(prompt_ids), max_new))
        return rid

    def step(self) -> List[Request]:
        """One engine step: admit + prefill one chunk each, then one decode
        token group for running requests.  Returns finished requests."""
        self._admit()
        self._prefill_step()
        finished = self._decode_step()
        return finished

    def run_to_completion(self, max_steps: int = 10_000) -> List[Request]:
        out = []
        for _ in range(max_steps):
            out.extend(self.step())
            if not self.queue and not self.active:
                break
        return out

    # -- internals -------------------------------------------------------------
    def _admit(self):
        while self.queue and len(self.active) < 4:
            req = self.queue.pop(0)
            cache = self.model.init_cache(1, self.max_len)
            self.active[req.rid] = {"req": req, "cache": cache}

    def _prefill_step(self):
        for slot in self.active.values():
            req = slot["req"]
            if req.prefilled >= len(req.prompt_ids):
                continue
            # chunked prefill: one chunk per engine step (a HeRo sub-stage)
            end = min(req.prefilled + self.prefill_chunk,
                      len(req.prompt_ids))
            chunk = jnp.asarray([req.prompt_ids[req.prefilled:end]],
                                jnp.int32)
            logits, cache = self.model.prefill(self.params,
                                               {"tokens": chunk},
                                               slot["cache"])
            slot["cache"] = cache
            req.prefilled = end
            if end == len(req.prompt_ids):
                tok = int(jnp.argmax(logits[0, -1]))
                req.generated.append(tok)

    def _decode_step(self) -> List[Request]:
        finished = []
        for rid in list(self.active):
            slot = self.active[rid]
            req = slot["req"]
            if req.prefilled < len(req.prompt_ids) or not req.generated:
                continue
            for _ in range(self.token_group):
                if len(req.generated) >= req.max_new or \
                        req.generated[-1] == EOS:
                    req.done = True
                    break
                logits, slot["cache"] = self._decode(
                    self.params,
                    jnp.asarray([[req.generated[-1]]], jnp.int32),
                    slot["cache"])
                req.generated.append(int(jnp.argmax(logits[0])))
            if len(req.generated) >= req.max_new:
                req.done = True
            if req.done:
                finished.append(req)
                del self.active[rid]
        return finished
