"""Heterogeneous executors + the real-time HeRo runtime.

``PUExecutor`` is one processing-unit group: a worker thread with a task
queue (on real hardware, one JAX mesh slice / device group; here, CPU
workers).  ``HeroRuntime`` drives a live DynamicDAG through the HeRo
scheduler against wall-clock time — the real-system counterpart of
core/simulator.py — with the fault-tolerance loop the paper-scale
deployment needs:

- heartbeat + straggler mitigation: a task exceeding straggler_factor ×
  the perf-model ETA is speculatively re-dispatched to another PU
  (the slow copy is cancelled cooperatively);
- retry with backoff on executor exceptions;
- elastic membership: PUs may join/leave between dispatch passes
  (scheduler.add_pu / remove_pu) — in-flight work on a lost PU is
  re-queued, which is exactly how a lost pod slice is handled at scale.
"""
from __future__ import annotations

import queue
import threading
import time
import traceback
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

from repro.core.dag import DONE, READY, RUNNING, DynamicDAG, Node
from repro.core.events import (EV_CANCELLED, EV_DONE, EV_KV_FETCH,
                               EV_KV_MIGRATE, EV_PREEMPT, EV_RETRY,
                               EV_START, EV_STRAGGLER, EV_TOKENS,
                               SPILL_TIERS)
from repro.core.partitioner import dispatch_passes, fused_boundary_index
from repro.core.scheduler import Dispatch, HeroScheduler

StageFn = Callable[[Node, int], Any]   # (node, batch) -> result


@dataclass
class _Task:
    node: Node
    batch: int
    fn: StageFn
    started: float = 0.0
    cancelled: bool = False
    result: Any = None
    error: Optional[str] = None
    done_evt: threading.Event = field(default_factory=threading.Event)


class PUExecutor:
    def __init__(self, name: str):
        self.name = name
        self._q: "queue.Queue[_Task]" = queue.Queue()
        self._alive = True
        # queued + running tasks, counted at submit() and released when the
        # worker finishes — guarded by a lock so busy() cannot misreport
        # during the worker's dequeue/complete transitions (an unsynchronized
        # counter let the scheduler double-dispatch a PU)
        self._working = 0
        self._lock = threading.Lock()
        self._thread = threading.Thread(target=self._loop, daemon=True)
        self._thread.start()

    def submit(self, task: _Task):
        with self._lock:
            self._working += 1
        self._q.put(task)

    def busy(self) -> bool:
        """True while the worker has queued or running work — including a
        cancelled straggler it cannot preempt (work is non-preemptible;
        the scheduler must route around it)."""
        with self._lock:
            return self._working > 0

    def shutdown(self):
        self._alive = False
        self._q.put(None)  # type: ignore[arg-type]

    def _loop(self):
        while self._alive:
            task = self._q.get()
            if task is None:
                return
            task.started = time.monotonic()
            if not task.cancelled:
                try:
                    task.result = task.fn(task.node, task.batch)
                except Exception:                  # retry handled upstream
                    task.error = traceback.format_exc()
            # release before signalling: once done_evt is visible the PU is
            # genuinely free, so a fresh dispatch must not see busy()==True
            with self._lock:
                self._working -= 1
            task.done_evt.set()


class HeroRuntime:
    """Run one RAG DAG on real executors under the HeRo scheduler."""

    def __init__(self, scheduler: HeroScheduler,
                 executors: Dict[str, PUExecutor],
                 stage_fns: Dict[str, StageFn],
                 max_retries: int = 2,
                 observer: Optional[Callable[[float, str, Node], None]] = None):
        self.sched = scheduler
        self.executors = executors
        self.stage_fns = stage_fns
        self.max_retries = max_retries
        self.results: Dict[str, Any] = {}
        # every event timestamp is run-relative (seconds since run() began),
        # so the list is a usable timeline
        self.events: List[tuple] = []
        self.observer = observer

    def _emit(self, t: float, event: str, node: Node):
        self.events.append((t, event, node.id))
        if self.observer is not None:
            self.observer(t, event, node)
        # fused (cross-query coalesced) dispatches fan events out to their
        # members — same convention as the simulator, so per-query
        # attribution is backend-independent.  At a decode-round boundary,
        # members still resident get a "tokens" event, not "done".
        is_round = bool(node.payload.get("decode_round"))
        for m in node.payload.get("members", ()):
            ev = event
            if is_round and event == EV_DONE and m.status != DONE:
                ev = EV_TOKENS
            self._emit(t, ev, m)

    def add_executor(self, name: str, ex: PUExecutor):
        self.executors[name] = ex
        self.sched.add_pu(name)

    def remove_executor(self, name: str):
        """Elastic scale-down / failure: drop the PU; in-flight work is
        re-queued by the main loop when its heartbeat lapses."""
        self.executors.pop(name, None)
        self.sched.remove_pu(name)

    def run(self, dag: DynamicDAG, poll: float = 0.002,
            timeout: float = 300.0) -> Dict[str, Any]:
        t0 = time.monotonic()
        self._t0 = t0   # run-relative epoch, readable by stage fns (timers)
        inflight: Dict[str, tuple] = {}     # node id -> (_Task, Dispatch, retries)

        def now() -> float:
            return time.monotonic() - t0

        def predicted_total(d: Dispatch) -> float:
            # a dispatch runs ceil(L/batch) passes of p0 each — fused
            # (cross-query coalesced) nodes run whole, so multi-pass
            # dispatches are the norm there, and ETAs must account for it
            # exactly as the simulator does.  Decode rounds serve ONE
            # token group per dispatch: their ETA comes from the
            # remaining tokens at the current group, not the residents'
            # whole horizon (dispatch_passes) — otherwise a cancellation
            # drain overestimates a partially-decoded batch's remaining
            # work and the straggler heartbeat re-reaps it immediately.
            # migrate_s: the modeled one-off KV transfer the dispatch
            # pays first — in the ETA exactly as the simulator counts it
            return (d.predicted_p0 * dispatch_passes(d.node, d.batch)
                    + d.migrate_s)

        def busy_until():
            return {d.pu: d_task.started - t0 + predicted_total(d)
                    for d_task, d, _ in inflight.values()}

        def b_now() -> float:
            return sum(d.bandwidth for _, d, _ in inflight.values())

        def dispatch():
            if dag._cancel_pending:
                # user-requested cancellation, observed at the same
                # granularity as the simulator: queued nodes collapse,
                # in-flight flagged tasks are cancelled cooperatively
                # (the running fn is non-preemptible — it drains
                # off-book, exactly like a cancelled straggler)
                for n in dag.reap_cancelled(now()):
                    self._emit(now(), EV_CANCELLED, n)
                for nid in [k for k, (_tk, dd, _r) in inflight.items()
                            if dd.node.payload.get("cancel_requested")]:
                    tk, dd, _r = inflight.pop(nid)
                    tk.cancelled = True
                    n = dd.node
                    n.status, n.finish = DONE, now()
                    n.expander = None
                    n.payload["cancelled"] = True
                    if dag.kv is not None and n.kind == "stream_decode":
                        dag.kv.release(n)
                    for s in dag._succ.get(nid, ()):
                        dag._refresh_status(dag.nodes[s])
                    self._emit(now(), EV_CANCELLED, n)
                if dag._cancel_pending:
                    for n in dag.reap_cancelled(now()):
                        self._emit(now(), EV_CANCELLED, n)
            # io is unbounded concurrency (network threads), matching the
            # simulator — a sleeping web call or admission timer must not
            # block the io lane for other queries
            busy = {d.pu for _, d, _ in inflight.values() if d.pu != "io"}
            busy |= {name for name, ex in self.executors.items()
                     if ex.busy()}
            idle = [p for p in list(self.executors) + ["io"]
                    if p not in busy]
            for d in self.sched.dispatch_pass(dag, now(), idle, b_now(),
                                              busy_until()):
                self._launch(d, inflight, dag, retries=0, now_t=now())

        dispatch()
        while dag.unfinished():
            if now() > timeout:
                raise TimeoutError("HeroRuntime timed out")
            if not inflight:
                dispatch()
                if not inflight and dag.unfinished():
                    if any(x.busy() for x in self.executors.values()):
                        # cancelled stragglers are non-preemptible: the PU
                        # drains them off-book (not in inflight) and only
                        # then frees up — waiting is progress, not deadlock
                        time.sleep(poll)
                        continue
                    raise RuntimeError(
                        f"deadlock: {[n.id for n in dag.unfinished()][:4]}")
            progressed = False
            for nid in list(inflight):
                task, d, retries = inflight[nid]
                if d.node.payload.pop("preempt_split", False) and \
                        not task.done_evt.is_set() and not task.cancelled:
                    # boundary split flagged by the scheduler: wall-clock
                    # progress against the ETA picks the member boundary;
                    # released members return READY and re-place.  The
                    # running fn is non-preemptible, so on this substrate
                    # the split is bookkeeping (the fn finishes its
                    # original batch; mark_done fans out to kept members
                    # only) — preempt_yield then exempts the shrunken
                    # node from straggler speculation, since its ETA no
                    # longer covers the fn's true remaining work
                    frac = 0.0
                    if task.started:
                        frac = min((time.monotonic() - task.started)
                                   / max(predicted_total(d), 1e-9), 1.0)
                    keep = fused_boundary_index(
                        [m.workload for m in d.node.payload["members"]],
                        frac)
                    released = dag.preempt_fused(d.node, keep,
                                                 prefer_pu=d.pu,
                                                 t=now())
                    if released:
                        d.node.payload["preempt_yield"] = True
                        for m in released:
                            self._emit(now(), EV_PREEMPT, m)
                        progressed = True
                if task.done_evt.is_set():
                    del inflight[nid]
                    progressed = True
                    if task.cancelled:
                        continue
                    if task.error is not None:
                        if retries < self.max_retries:
                            self._emit(now(), EV_RETRY, d.node)
                            self._launch(d, inflight, dag,
                                         retries=retries + 1, now_t=now())
                            continue
                        raise RuntimeError(
                            f"stage {nid} failed:\n{task.error}")
                    if d.node.payload.get("decode_round"):
                        # synthetic per-boundary id: storing under it would
                        # leak one entry per round — fan a coalesce-aware
                        # fn's {member id: result} dict out per query
                        # instead (each member accumulates its rounds)
                        per = (task.result
                               if isinstance(task.result, dict) else {})
                        for m in d.node.payload["members"]:
                            if m.id in per:
                                self.results.setdefault(m.id, []).append(
                                    per[m.id])
                    elif not d.node.payload.get("draft_round"):
                        # draft sub-dispatches get a fresh id per round —
                        # storing their (candidate-token) results would
                        # leak one entry per round; the verify fn is the
                        # one that owes the stream its accepted output
                        self.results[nid] = task.result
                    prog = d.node.payload.get("on_progress")
                    dag.mark_done(nid, now())
                    if prog is not None and d.node.kind == "stream_decode":
                        prog(dag, d.node, d.node.workload)
                    self._emit(now(), EV_DONE, d.node)
                elif task.started and not task.cancelled:
                    # straggler heartbeat (perf-model ETA as the prior, with
                    # a jitter floor and a per-node speculation cap)
                    eta = max(predicted_total(d) *
                              self.sched.cfg.straggler_factor, 0.05)
                    can_spec = (d.node.payload.get("redispatches", 0) < 4
                                and not d.node.payload.get("preempt_yield"))
                    if (can_spec and d.pu in self.executors
                            and time.monotonic() - task.started > eta):
                        task.cancelled = True
                        self._emit(now(), EV_STRAGGLER, d.node)
                        d.node.status = READY
                        d.node.start, d.node.config = -1.0, None
                        d.node.payload["redispatches"] = \
                            d.node.payload.get("redispatches", 0) + 1
                        del inflight[nid]
                        progressed = True
                    elif d.pu not in self.executors:
                        # PU left the fleet: re-queue
                        task.cancelled = True
                        d.node.status = READY
                        d.node.start, d.node.config = -1.0, None
                        del inflight[nid]
                        progressed = True
            if progressed:
                dispatch()
            else:
                time.sleep(poll)
        return self.results

    def _launch(self, d: Dispatch, inflight, dag: DynamicDAG, retries: int,
                now_t: float = 0.0):
        fn = self.stage_fns.get(d.node.stage)
        if d.pu == "io" or fn is None:
            fn = self.stage_fns.get("__io__", lambda n, b: None)
        task = _Task(d.node, d.batch, fn)
        if (d.node.kind == "stream_decode" and self.sched.kv is not None
                and not d.node.payload.get("draft_round")):
            # same registration the simulator does at dispatch start, so
            # kv_migrations / bytes-moved accounting is backend-independent
            # (wall-clock transfer cost is the stage fn's to pay — here it
            # is recorded, not slept).  Paged trackers may gather from the
            # spill tiers: those moves are fetches, not migrations
            migrated = set()
            for m, src, _ctx, _by in self.sched.kv.migrate_for_dispatch(
                    d.node, d.pu):
                if src in SPILL_TIERS:
                    self._emit(now_t, EV_KV_FETCH, m)
                elif m.id not in migrated:
                    # one event per stream per dispatch (multi-arena
                    # gathers are one cache move), matching kv_migrations
                    migrated.add(m.id)
                    self._emit(now_t, EV_KV_MIGRATE, m)
        if getattr(self.sched.kv, "paged", False):
            # paged accounting accrued since the last launch: page events
            # reach the run timeline; spill transfers are recorded in the
            # tracker's counters (wall-clock cost is the executors' to pay)
            self.sched.kv.drain_transfers()
            # prefetched stagings: recorded only (same rule as transfers
            # — the overlapped wall-clock cost is the executors' to pay),
            # keeping both backends' prefetch counters identical
            self.sched.kv.drain_prefetches()
            for ev, n2 in self.sched.kv.drain_events():
                self._emit(now_t, ev, n2)
        if d.node.status != RUNNING:
            dag.mark_running(d.node.id, now_t, (d.pu, d.batch))
        if d.pu == "io":
            threading.Thread(target=lambda: (setattr(
                task, "result", fn(d.node, d.batch)), task.done_evt.set()),
                daemon=True).start()
        else:
            self.executors[d.pu].submit(task)
        inflight[d.node.id] = (task, d, retries)
        self._emit(now_t, EV_START, d.node)
