from repro.serving.engine import Request, ServingEngine  # noqa: F401
from repro.serving.executor import HeroRuntime, PUExecutor  # noqa: F401
