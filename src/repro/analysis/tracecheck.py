"""Happens-before checking over recorded scheduler traces.

``python -m repro.analysis.tracecheck [files...]`` replays recorded
timeline traces (and bench/golden artifacts) through a set of dynamic
invariants the scheduler core must uphold on every run:

- **lifecycle** — each node's event stream obeys the dispatch state
  machine: no serve after completion, no double completion, no
  token-group boundary on a finished stream, redispatch/preempt only on
  live work;
- **PU serialization** — a physical PU serves one dispatch unit at a
  time: recorded serve intervals on the same PU never overlap ("io" is
  exempt — network concurrency is unbounded by design);
- **conservation** — run counters equal (or, for drained paged-KV
  telemetry, bound) their timeline event counts, byte totals move only
  with their paired counts, accepted speculative tokens never exceed
  drafted, and no event lands after the recorded makespan.

Three artifact schemas are sniffed from the JSON shape:

- ``{"schema": "repro.trace/v1", "events": ...}`` — full traces
  recorded by ``--record`` (all rules);
- ``{"regimes": ...}`` — bench-smoke artifacts
  (``benchmarks/baselines/serving_*.json``, ``BENCH_serving.json``):
  per-row sanity (finite, non-negative, p50 ≤ p99 ≤ total,
  accepted ≤ drafted);
- flat ``{name: float | [float]}`` — the PR 2/PR 3 makespan goldens:
  finite and positive.

``--record [DIR]`` re-runs the deterministic scenarios behind the
committed ``tests/goldens/trace_*.json`` files and rewrites them; run it
when an intentional behavior change shifts the traces.
"""
from __future__ import annotations

import glob
import json
import math
import os
import sys
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Sequence

from repro.core.events import (ALL_EVENTS, EV_CANCELLED, EV_DONE, EV_PREEMPT,
                               EV_REDISPATCH, EV_RETRY, EV_START,
                               EV_STRAGGLER, EV_TOKENS, REDISPATCH_EVENTS)

TRACE_SCHEMA = "repro.trace/v1"
EPS = 1e-9

# counters emitted directly onto the timeline, exactly once per count
EXACT_COUNTERS = {
    "dispatches": (EV_START,),
    "redispatches": REDISPATCH_EVENTS,
    "preemptions": (EV_PREEMPT,),
    "kv_migrations": ("kv_migrate",),
    "kv_fetches": ("kv_fetch",),
}
# paged-KV telemetry reaches the timeline via drain_events() at the
# *next* dispatch: counts accrued after the last dispatch stay
# counter-only, so the event count is a lower bound (with a zero pair:
# no counts, no events)
DRAINED_COUNTERS = {
    "kv_page_hits": "kv_page_hit",
    "kv_evictions": "kv_evict",
    "kv_hit_declined": "kv_hit_declined",
    "kv_soft_overflows": "kv_soft_overflow",
    "kv_prefetches": "kv_prefetch",
}
# byte totals that must move together with their count
BYTE_PAIRS = (("kv_migrations", "kv_bytes_moved"),
              ("kv_fetches", "kv_fetched_bytes"),
              ("kv_evictions", "kv_evicted_bytes"),
              ("kv_prefetches", "kv_prefetch_bytes"))


@dataclass(frozen=True)
class TraceViolation:
    path: str
    rule: str
    where: str       # node id / PU / counter the violation anchors to
    message: str

    def __str__(self) -> str:
        return f"{self.path}: {self.rule} [{self.where}] {self.message}"


# -- full traces -------------------------------------------------------------
# lifecycle states: IDLE (never dispatched), LIVE (dispatched / resident,
# may serve again), FINAL (done or cancelled — terminal)
IDLE, LIVE, FINAL = "idle", "live", "final"


def _check_lifecycle(events, path: str) -> List[TraceViolation]:
    out: List[TraceViolation] = []
    state: Dict[str, str] = {}
    final_ev: Dict[str, str] = {}

    def bad(nid, rule, msg):
        out.append(TraceViolation(path, rule, nid, msg))

    for t, ev, nid in events:
        st = state.get(nid, IDLE)
        if ev == EV_START:
            if st == FINAL:
                bad(nid, "TR101",
                    f"serve after completion: 'start' at t={t:.6g} but the "
                    f"node already finalized via {final_ev[nid]!r}")
            state[nid] = LIVE
        elif ev == EV_TOKENS:
            if st == FINAL:
                bad(nid, "TR102",
                    f"token-group boundary at t={t:.6g} on a finished "
                    "stream")
            elif st == IDLE:
                bad(nid, "TR103",
                    f"token-group boundary at t={t:.6g} on a never-"
                    "dispatched stream")
        elif ev == EV_DONE:
            if st == FINAL:
                bad(nid, "TR104",
                    f"double completion: 'done' at t={t:.6g} after "
                    f"{final_ev[nid]!r}")
            elif st == IDLE:
                bad(nid, "TR105",
                    f"'done' at t={t:.6g} without any 'start'")
            state[nid], final_ev[nid] = FINAL, ev
        elif ev == EV_CANCELLED:
            # queued (never-dispatched) work may be reaped: IDLE is legal
            if st == FINAL:
                bad(nid, "TR104",
                    f"double completion: 'cancelled' at t={t:.6g} after "
                    f"{final_ev[nid]!r}")
            state[nid], final_ev[nid] = FINAL, ev
        elif ev in REDISPATCH_EVENTS or ev == EV_PREEMPT:
            if st == FINAL:
                bad(nid, "TR106",
                    f"{ev!r} at t={t:.6g} on a finished node")
            elif st == IDLE:
                bad(nid, "TR107",
                    f"{ev!r} at t={t:.6g} on a never-dispatched node")
            # node returns to the ready pool; it may start again
        # kv_* events carry no lifecycle constraint: pages of a stream
        # move on cache pressure regardless of the owner's state
    return out


def _check_pu_serialization(dispatches, path: str) -> List[TraceViolation]:
    out: List[TraceViolation] = []
    by_pu: Dict[str, List[dict]] = {}
    for d in dispatches:
        if d["t1"] < d["t0"] - EPS:
            out.append(TraceViolation(
                path, "TR201", d["node"],
                f"dispatch interval ends before it starts "
                f"({d['t0']:.6g} -> {d['t1']:.6g})"))
        if d["pu"] != "io":     # io = network, unbounded concurrency
            by_pu.setdefault(d["pu"], []).append(d)
    for pu, ds in by_pu.items():
        ds.sort(key=lambda d: (d["t0"], d["t1"]))
        for prev, cur in zip(ds, ds[1:]):
            if cur["t0"] < prev["t1"] - EPS:
                out.append(TraceViolation(
                    path, "TR202", pu,
                    f"double-serve: {prev['node']!r} "
                    f"[{prev['t0']:.6g}, {prev['t1']:.6g}] overlaps "
                    f"{cur['node']!r} [{cur['t0']:.6g}, {cur['t1']:.6g}] "
                    f"on {pu}"))
    return out


def _check_conservation(doc, path: str) -> List[TraceViolation]:
    out: List[TraceViolation] = []
    events = doc["events"]
    counters = doc.get("counters", {})
    makespan = float(doc.get("makespan", math.inf))
    n_ev: Dict[str, int] = {}
    for _t, ev, _nid in events:
        n_ev[ev] = n_ev.get(ev, 0) + 1

    for t, ev, nid in events:
        if ev not in ALL_EVENTS:
            out.append(TraceViolation(
                path, "TR301", nid, f"unknown event name {ev!r}"))
        if t < -EPS or t > makespan + EPS:
            out.append(TraceViolation(
                path, "TR302", nid,
                f"event {ev!r} at t={t:.6g} outside [0, makespan="
                f"{makespan:.6g}]"))
    prev_t = -math.inf
    for t, ev, nid in events:
        if t < prev_t - EPS:
            out.append(TraceViolation(
                path, "TR303", nid,
                f"timeline goes backwards: {ev!r} at t={t:.6g} after "
                f"t={prev_t:.6g}"))
        prev_t = max(prev_t, t)

    for name, evs in EXACT_COUNTERS.items():
        if name not in counters:
            continue
        got = sum(n_ev.get(e, 0) for e in evs)
        if counters[name] != got:
            out.append(TraceViolation(
                path, "TR304", name,
                f"counter {name}={counters[name]} but the timeline has "
                f"{got} {'/'.join(evs)} event(s)"))
    for name, ev in DRAINED_COUNTERS.items():
        if name not in counters:
            continue
        got = n_ev.get(ev, 0)
        if got > counters[name]:
            out.append(TraceViolation(
                path, "TR305", name,
                f"{got} {ev!r} events exceed counter {name}="
                f"{counters[name]}"))
        if counters[name] == 0 and got:
            out.append(TraceViolation(
                path, "TR305", name,
                f"{got} {ev!r} event(s) with counter {name}=0"))

    for k, v in counters.items():
        if isinstance(v, (int, float)) and (not math.isfinite(v) or v < 0):
            out.append(TraceViolation(
                path, "TR306", k, f"counter {k}={v!r} is not a finite "
                "non-negative number"))
    for cnt, byt in BYTE_PAIRS:
        if counters.get(cnt, 0) == 0 and counters.get(byt, 0.0) > 0.0:
            out.append(TraceViolation(
                path, "TR307", byt,
                f"{byt}={counters[byt]} moved with {cnt}=0"))
    if counters.get("accepted_tokens", 0) > counters.get("drafted_tokens", 0):
        out.append(TraceViolation(
            path, "TR308", "accepted_tokens",
            f"accepted_tokens={counters['accepted_tokens']} exceeds "
            f"drafted_tokens={counters.get('drafted_tokens', 0)}"))

    for pu, busy in doc.get("pu_busy", {}).items():
        if busy < -EPS or busy > makespan + EPS:
            out.append(TraceViolation(
                path, "TR309", pu,
                f"pu_busy[{pu}]={busy:.6g} outside [0, makespan="
                f"{makespan:.6g}]"))
    return out


def _check_full_trace(doc, path: str) -> List[TraceViolation]:
    events = [tuple(e) for e in doc.get("events", ())]
    out = _check_lifecycle(events, path)
    out += _check_pu_serialization(doc.get("dispatches", ()), path)
    out += _check_conservation(doc, path)
    return out


# -- bench artifacts ---------------------------------------------------------
def _check_bench(doc, path: str) -> List[TraceViolation]:
    out: List[TraceViolation] = []
    for regime, systems in doc.get("regimes", {}).items():
        for sysname, row in systems.items():
            where = f"{regime}/{sysname}"
            if not isinstance(row, dict):
                continue
            for k, v in row.items():
                if isinstance(v, bool) or not isinstance(v, (int, float)):
                    continue
                if not math.isfinite(v) or v < 0:
                    out.append(TraceViolation(
                        path, "BN301", where,
                        f"{k}={v!r} is not finite and non-negative"))
            p50, p99 = row.get("p50"), row.get("p99")
            total = row.get("total")
            if p50 is not None and p99 is not None and p50 > p99 + EPS:
                out.append(TraceViolation(
                    path, "BN302", where, f"p50={p50:.6g} > p99={p99:.6g}"))
            if p99 is not None and total is not None and p99 > total + EPS:
                out.append(TraceViolation(
                    path, "BN302", where,
                    f"p99={p99:.6g} > total makespan {total:.6g}"))
            if row.get("accepted", 0) > row.get("drafted", 0) + EPS:
                out.append(TraceViolation(
                    path, "BN303", where,
                    f"accepted={row['accepted']} exceeds "
                    f"drafted={row.get('drafted', 0)}"))
            rate, toks = row.get("decode_tok_rate"), row.get("decode_tokens")
            if rate is not None and toks is not None and total:
                # tokens/sec over the run can't exceed what the recorded
                # token count supports (and must be zero iff no tokens)
                if rate > toks / min(p50 or total, total) + EPS:
                    out.append(TraceViolation(
                        path, "BN304", where,
                        f"decode_tok_rate={rate:.6g} impossible for "
                        f"{toks} tokens in {total:.6g}s"))
                if (rate == 0) != (toks == 0):
                    out.append(TraceViolation(
                        path, "BN304", where,
                        f"decode_tok_rate={rate:.6g} with "
                        f"decode_tokens={toks}"))
    return out


# -- flat makespan goldens ---------------------------------------------------
def _check_flat(doc, path: str) -> List[TraceViolation]:
    out: List[TraceViolation] = []

    def chk(key, v):
        if isinstance(v, bool) or not isinstance(v, (int, float)):
            return
        if not math.isfinite(v) or v <= 0:
            out.append(TraceViolation(
                path, "GL301", key,
                f"makespan {v!r} is not finite and positive"))

    for key, v in doc.items():
        if isinstance(v, list):
            for i, x in enumerate(v):
                chk(f"{key}[{i}]", x)
        else:
            chk(key, v)
    return out


def check_trace(doc: Any, path: str = "<trace>") -> List[TraceViolation]:
    """Schema-sniff ``doc`` and run the matching rule set."""
    if not isinstance(doc, dict):
        return [TraceViolation(path, "TR000", "-",
                               f"expected a JSON object, got "
                               f"{type(doc).__name__}")]
    if doc.get("schema") == TRACE_SCHEMA or "events" in doc:
        return _check_full_trace(doc, path)
    if "regimes" in doc:
        return _check_bench(doc, path)
    return _check_flat(doc, path)


# -- recording ---------------------------------------------------------------
class _RecordingBackend:
    """Wraps a backend to capture, alongside its ``BackendRun``, the
    per-PU serve intervals of every *top-level* dispatch unit.  A
    timeline ``start`` is a unit's own iff the node carries a config and
    is not absorbed into a fused parent (members fan out with
    ``fused_into`` still set); the unit closes on its terminal or
    redispatch event."""

    def __init__(self, inner):
        self.inner = inner
        self.name = inner.name
        self.dispatches: List[dict] = []

    def execute(self, dag, scheduler, observer=None, timeout=3600.0):
        open_units: Dict[str, tuple] = {}

        def obs(t, ev, node):
            if observer is not None:
                observer(t, ev, node)
            if (ev == EV_START and node.config is not None
                    and "fused_into" not in node.payload):
                open_units[node.id] = (t, node.config[0])
            elif node.id in open_units and ev in (
                    EV_DONE, EV_CANCELLED, EV_REDISPATCH, EV_STRAGGLER,
                    EV_RETRY):
                t0, pu = open_units.pop(node.id)
                self.dispatches.append(
                    {"node": node.id, "pu": pu, "t0": t0, "t1": t})

        return self.inner.execute(dag, scheduler, observer=obs,
                                  timeout=timeout)


def _record_one(label: str, n_queries: int, stagger: float,
                wfs: Sequence[int], slos: Sequence[str] = ("interactive",),
                trace_idx: Optional[Sequence[int]] = None,
                shared_corpus: bool = False, **session_kw) -> dict:
    from repro.api import HeroSession
    from repro.api.options import SessionOptions
    from repro.rag import default_means, sample_traces, shared_corpus_traces

    sample = shared_corpus_traces if shared_corpus else sample_traces
    traces = sample("hotpotqa", max(n_queries, 8), seed=11)
    sess = HeroSession(world="sd8gen4", family="qwen3",
                       means=default_means(traces),
                       options=SessionOptions(**session_kw))
    rec = _RecordingBackend(sess.backend)
    sess.backend = rec
    for qi in range(n_queries):
        ti = trace_idx[qi] if trace_idx is not None else qi
        sess.submit(traces[ti], wf=wfs[qi % len(wfs)],
                    arrival_time=qi * stagger,
                    slo=slos[qi % len(slos)])
    sess.run()
    run = sess.last_run
    counters = {k: v for k, v in vars(run).items()
                if isinstance(v, (int, float)) and k != "makespan"}
    return {"schema": TRACE_SCHEMA, "label": label,
            "world": "sd8gen4", "family": "qwen3",
            "makespan": run.makespan,
            "pu_busy": dict(run.pu_busy),
            "events": [list(e) for e in run.events],
            "dispatches": rec.dispatches,
            "counters": counters}


# deterministic scenarios, one per serving-era subsystem: the baseline
# serial scheduler, continuous decode batching, the paged KV store under
# prefetch + preemption pressure, and speculative decode rounds
SCENARIOS = {
    "trace_pr2_coalesce_off": dict(n_queries=4, stagger=0.25, wfs=(1,),
                                   coalesce=False),
    "trace_pr3_decode_batch": dict(n_queries=4, stagger=0.0, wfs=(1,),
                                   coalesce=True),
    # a shared retrieval corpus gives cross-query prefix page hits;
    # mixed SLO classes under admission + preemption take the split paths
    "trace_pr6_kv_preempt": dict(n_queries=6, stagger=0.2, wfs=(1, 2),
                                 shared_corpus=True,
                                 slos=("batch", "interactive"),
                                 coalesce=True, kv_pages=True,
                                 kv_prefetch=True, preempt=True,
                                 slo_admission=True,
                                 batch_policy="adaptive"),
    "trace_pr9_specdec": dict(n_queries=4, stagger=0.0, wfs=(1,),
                              coalesce=True, spec_decode=True),
}


def record_goldens(out_dir: str) -> List[str]:
    written = []
    for label, kw in SCENARIOS.items():
        doc = _record_one(label, **kw)
        path = os.path.join(out_dir, f"{label}.json")
        with open(path, "w") as f:
            json.dump(doc, f, indent=1, sort_keys=True)
            f.write("\n")
        written.append(path)
    return written


# -- driver ------------------------------------------------------------------
def _default_paths() -> List[str]:
    root = os.getcwd()
    return sorted(glob.glob(os.path.join(root, "tests", "goldens",
                                         "*.json")))


def main(argv: Optional[Sequence[str]] = None) -> int:
    argv = list(sys.argv[1:] if argv is None else argv)
    if argv and argv[0] == "--record":
        out_dir = argv[1] if len(argv) > 1 else os.path.join(
            os.getcwd(), "tests", "goldens")
        for path in record_goldens(out_dir):
            print(f"recorded {path}")
        argv = []
    paths = argv or _default_paths()
    if not paths:
        print("repro.analysis.tracecheck: no trace files found",
              file=sys.stderr)
        return 1
    violations: List[TraceViolation] = []
    checked = 0
    for path in paths:
        try:
            with open(path) as f:
                doc = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            violations.append(TraceViolation(path, "TR000", "-", str(e)))
            continue
        violations.extend(check_trace(doc, path))
        checked += 1
    for v in violations:
        print(v)
    if violations:
        print(f"repro.analysis.tracecheck: {len(violations)} violation(s) "
              f"across {checked} file(s)", file=sys.stderr)
        return 1
    print(f"repro.analysis.tracecheck: OK ({checked} file(s))")
    return 0


if __name__ == "__main__":
    sys.exit(main())
