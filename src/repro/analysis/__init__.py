"""Static invariant checking for the scheduler core.

Three tools, one package:

- :mod:`repro.analysis.lint` — AST-based repo-specific rules
  (``python -m repro.analysis.lint src/``): event-name registry
  discipline, SchedulerConfig gate hygiene, ``perf_model.fit()``
  rng-stream ordering, core determinism, BackendRun/QueryResult
  counter pairing.
- :mod:`repro.analysis.validate` — pre-run structural validation of
  :class:`repro.api.spec.WorkflowSpec` and assembled
  :class:`repro.core.dag.DynamicDAG` graphs, wired into
  ``WorkflowSpec.build_dag`` behind ``SessionOptions.validate_spec``.
- :mod:`repro.analysis.tracecheck` — a happens-before checker over
  recorded timeline traces and bench artifacts
  (``python -m repro.analysis.tracecheck [files...]``): per-node
  lifecycle state machines, per-PU serve-interval monotonicity, and
  KV / counter conservation.

The rationale: every PR since PR 5 shipped alongside hand-found
protocol bugs — double-counted spec counters, dangling successor
entries after round GC, leaked soft-overflow accounting — all
violations of *implicit* invariants nothing checked mechanically.
These tools make the invariants explicit and CI-enforced.
"""
_EXPORTS = {
    "Violation": "repro.analysis.lint",
    "lint_paths": "repro.analysis.lint",
    "SpecIssue": "repro.analysis.validate",
    "SpecValidationError": "repro.analysis.validate",
    "ensure_valid": "repro.analysis.validate",
    "validate_dag": "repro.analysis.validate",
    "validate_spec": "repro.analysis.validate",
    "TraceViolation": "repro.analysis.tracecheck",
    "check_trace": "repro.analysis.tracecheck",
}

__all__ = sorted(_EXPORTS)


def __getattr__(name):
    # lazy so `python -m repro.analysis.<tool>` doesn't trip runpy's
    # found-in-sys.modules warning by importing its sibling tools
    if name in _EXPORTS:
        import importlib
        return getattr(importlib.import_module(_EXPORTS[name]), name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
