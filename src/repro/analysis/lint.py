"""Repo-specific AST lint for the scheduler core.

Usage::

    PYTHONPATH=src python -m repro.analysis.lint src/

Rules (each encodes an invariant a past PR re-derived by hand):

- **EVT001** — in the event-handling modules, timeline-event names must
  come from the ``EV_*`` registry in ``repro.core.events``: raw string
  literals in ``_note``/``_emit`` calls, ``_events.append`` tuples, or
  comparisons are rejected.  A typo'd emit fails *silently* today —
  the event lands on the timeline and every counter filter misses it.
- **EVT002** — a compared string within edit distance 1 of a registered
  event name is flagged as a probable typo even where raw strings are
  otherwise allowed.
- **CFG001** — every boolean ``SchedulerConfig`` knob defaults off
  unless declared in ``scheduler.BASELINE_ON_KNOBS``: a gate that
  defaults on silently changes the goldens' baseline physics.
- **CFG002** — every feature gate (boolean knob defaulting off) is
  actually *consulted*: read in a boolean context (``if``/``and``/
  ``not``/ternary) or passed through as a same-named keyword argument
  somewhere in the linted tree.  An unread gate means the feature
  cannot be turned off.
- **RNG001/RNG002** — in ``perf_model.fit()``, noiseless grid fits
  must come *after* every noisy (rng-drawing) fit, and ``rng`` must be
  bound exactly once via ``np.random.default_rng(seed)``.  This is the
  golden-bit-identity rule: a new grid drawing rng before an existing
  stream shifts every downstream sample.
- **DET001/DET002/DET003** — no ``time``/``random`` imports, no legacy
  ``np.random.<dist>`` calls, and no unseeded ``default_rng()`` in
  ``core/`` (the deterministic substrate); seeded
  ``np.random.default_rng(seed)`` is the one sanctioned rng.
- **CNT001** — every ``BackendRun`` counter has a matching
  ``QueryResult`` attribution field or is declared in
  ``backends.RUN_ONLY_COUNTERS`` (global-pressure counters that have
  no per-query attribution by design).

Adding a rule: write a ``check_*(tree, key, path)`` (per-file) or
``check_*(trees)`` (cross-file) function returning ``Violation``s and
register it in :func:`lint_paths`; add one positive + one negative
case to ``tests/test_analysis_lint.py``.
"""
from __future__ import annotations

import ast
import sys
from dataclasses import dataclass
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Set, Tuple

from repro.core.events import ALL_EVENTS


@dataclass(frozen=True)
class Violation:
    path: str
    line: int
    rule: str
    message: str

    def __str__(self) -> str:
        return f"{self.path}:{self.line}: {self.rule} {self.message}"


# modules that emit or dispatch on timeline events: raw event-string
# literals are banned here (the registry itself is exempt)
EVENT_MODULES = frozenset({
    "core/simulator.py", "core/kv_pages.py", "core/scheduler.py",
    "serving/executor.py", "api/backends.py", "api/results.py",
    "api/session.py",
})

# core/ modules allowed to use wall clock / stdlib random (none today;
# the sanctioned rng is seeded np.random.default_rng, allowed anywhere)
SANCTIONED_DET_MODULES: frozenset = frozenset()

# BackendRun fields that are structure, not counters (no pairing needed)
STRUCTURAL_RUN_FIELDS = frozenset({"events", "batching"})


def _module_key(path: str) -> str:
    """``.../src/repro/core/simulator.py -> core/simulator.py`` — the
    repo-relative module identity rules dispatch on."""
    p = Path(path).as_posix()
    i = p.rfind("repro/")
    return p[i + len("repro/"):] if i >= 0 else Path(p).name


# -- EVT: event-name registry discipline -------------------------------------
def _lev_le1(a: str, b: str) -> bool:
    """Levenshtein distance <= 1 (a != b assumed)."""
    if a == b:
        return True
    la, lb = len(a), len(b)
    if abs(la - lb) > 1:
        return False
    if la == lb:                       # one substitution
        return sum(x != y for x, y in zip(a, b)) <= 1
    if la > lb:
        a, b, la, lb = b, a, lb, la
    # one insertion into a
    i = 0
    while i < la and a[i] == b[i]:
        i += 1
    return a[i:] == b[i + 1:]


def _near_event(s: str) -> Optional[str]:
    """The registered event ``s`` is probably a typo of, or None."""
    if s in ALL_EVENTS or not (3 <= len(s) <= 20):
        return None
    for ev in sorted(ALL_EVENTS):
        if _lev_le1(s, ev):
            return ev
    return None


def _str_operands(node: ast.expr) -> List[Tuple[int, str]]:
    """String constants a comparison operand contributes: the operand
    itself, or the elements of a tuple/list/set literal (membership)."""
    out: List[Tuple[int, str]] = []
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        out.append((node.lineno, node.value))
    elif isinstance(node, (ast.Tuple, ast.List, ast.Set)):
        for e in node.elts:
            if isinstance(e, ast.Constant) and isinstance(e.value, str):
                out.append((e.lineno, e.value))
    return out


def check_event_literals(tree: ast.AST, key: str,
                         path: str) -> List[Violation]:
    if key not in EVENT_MODULES:
        return []
    out: List[Violation] = []
    for n in ast.walk(tree):
        if isinstance(n, ast.Call) and isinstance(n.func, ast.Attribute):
            # self._note(timeline, t, event, node) / self._emit(t, ev, n)
            if n.func.attr in ("_note", "_emit") and len(n.args) >= 2:
                ev_arg = n.args[-2]
                if (isinstance(ev_arg, ast.Constant)
                        and isinstance(ev_arg.value, str)):
                    out.append(Violation(
                        path, ev_arg.lineno, "EVT001",
                        f"raw event string {ev_arg.value!r} in "
                        f"{n.func.attr}() — use the EV_* constant from "
                        "repro.core.events"))
            # self._events.append(("name", node))
            elif (n.func.attr == "append"
                  and isinstance(n.func.value, ast.Attribute)
                  and n.func.value.attr == "_events" and n.args):
                tup = n.args[0]
                if isinstance(tup, (ast.Tuple, ast.List)) and tup.elts:
                    first = tup.elts[0]
                    if (isinstance(first, ast.Constant)
                            and isinstance(first.value, str)):
                        out.append(Violation(
                            path, first.lineno, "EVT001",
                            f"raw event string {first.value!r} queued on "
                            "_events — use the EV_* constant from "
                            "repro.core.events"))
        elif isinstance(n, ast.Compare):
            for op in [n.left] + list(n.comparators):
                for line, s in _str_operands(op):
                    if s in ALL_EVENTS:
                        out.append(Violation(
                            path, line, "EVT001",
                            f"comparison against raw event string {s!r} "
                            "— use the EV_* constant from "
                            "repro.core.events"))
                    else:
                        near = _near_event(s)
                        if near is not None:
                            out.append(Violation(
                                path, line, "EVT002",
                                f"string {s!r} looks like a typo of "
                                f"event {near!r} — typo'd event names "
                                "silently drop counters"))
    return out


# -- CFG: SchedulerConfig gate hygiene ---------------------------------------
def _frozenset_literal(node: ast.expr) -> Optional[Set[str]]:
    """Strings of a ``frozenset({...})`` / set-literal assignment."""
    if (isinstance(node, ast.Call) and isinstance(node.func, ast.Name)
            and node.func.id == "frozenset" and node.args):
        node = node.args[0]
    if isinstance(node, (ast.Set, ast.Tuple, ast.List)):
        vals = set()
        for e in node.elts:
            if not (isinstance(e, ast.Constant)
                    and isinstance(e.value, str)):
                return None
            vals.add(e.value)
        return vals
    return None


def _bool_fields(cls: ast.ClassDef) -> List[Tuple[str, bool, int]]:
    """(name, default, lineno) of every ``x: bool = ...`` field."""
    out = []
    for st in cls.body:
        if (isinstance(st, ast.AnnAssign)
                and isinstance(st.target, ast.Name)
                and isinstance(st.annotation, ast.Name)
                and st.annotation.id == "bool"
                and isinstance(st.value, ast.Constant)
                and isinstance(st.value.value, bool)):
            out.append((st.target.id, st.value.value, st.lineno))
    return out


def _gated_reads(tree: ast.AST) -> Set[str]:
    """Attribute names read in a boolean context (``if``/``while``/
    ``and``/``or``/``not``/ternary/assert/comprehension-filter) or
    passed through as a same-named keyword argument."""
    conds: List[ast.expr] = []
    reads: Set[str] = set()
    for n in ast.walk(tree):
        if isinstance(n, (ast.If, ast.While, ast.IfExp, ast.Assert)):
            conds.append(n.test)
        elif isinstance(n, ast.BoolOp):
            conds.extend(n.values)
        elif isinstance(n, ast.UnaryOp) and isinstance(n.op, ast.Not):
            conds.append(n.operand)
        elif isinstance(n, ast.comprehension):
            conds.extend(n.ifs)
        elif isinstance(n, ast.keyword) and n.arg is not None:
            # cfg pass-through: PagedKVCache(..., prefetch=cfg.kv_prefetch)
            # delegates the gate to the callee — the knob is consulted
            if isinstance(n.value, ast.Attribute):
                reads.add(n.value.attr)
    for c in conds:
        for m in ast.walk(c):
            if isinstance(m, ast.Attribute):
                reads.add(m.attr)
    return reads


def check_config_gates(trees: Dict[str, ast.AST]) -> List[Violation]:
    sched_path = next((p for p in trees
                       if _module_key(p) == "core/scheduler.py"), None)
    if sched_path is None:
        return []
    tree = trees[sched_path]
    cls = next((n for n in ast.walk(tree) if isinstance(n, ast.ClassDef)
                and n.name == "SchedulerConfig"), None)
    if cls is None:
        return []
    baseline: Set[str] = set()
    for n in ast.walk(tree):
        if (isinstance(n, ast.Assign) and len(n.targets) == 1
                and isinstance(n.targets[0], ast.Name)
                and n.targets[0].id == "BASELINE_ON_KNOBS"):
            baseline = _frozenset_literal(n.value) or set()
    out: List[Violation] = []
    reads: Set[str] = set()
    for t in trees.values():
        reads |= _gated_reads(t)
    for name, default, line in _bool_fields(cls):
        if default and name not in baseline:
            out.append(Violation(
                sched_path, line, "CFG001",
                f"boolean knob {name!r} defaults on — feature gates "
                "must default off (or be declared in BASELINE_ON_KNOBS "
                "with a rationale)"))
        if not default and name not in reads:
            out.append(Violation(
                sched_path, line, "CFG002",
                f"feature gate {name!r} is never consulted in a boolean "
                "context — the feature cannot be switched off"))
    return out


# -- RNG: perf_model.fit() stream ordering -----------------------------------
def _draws_rng(node: ast.AST, noisy_helpers: Set[str]) -> bool:
    for m in ast.walk(node):
        if isinstance(m, ast.Call):
            f = m.func
            if (isinstance(f, ast.Attribute)
                    and isinstance(f.value, ast.Name)
                    and f.value.id == "rng"):
                return True
            name = (f.attr if isinstance(f, ast.Attribute)
                    else f.id if isinstance(f, ast.Name) else None)
            if name in noisy_helpers:
                return True
    return False


def _assigns_self(node: ast.AST) -> bool:
    def _root_is_self(t: ast.expr) -> bool:
        while isinstance(t, (ast.Subscript, ast.Attribute)):
            if (isinstance(t, ast.Attribute)
                    and isinstance(t.value, ast.Name)
                    and t.value.id == "self"):
                return True
            t = t.value
        return False

    for m in ast.walk(node):
        if isinstance(m, ast.Assign):
            if any(_root_is_self(t) for t in m.targets):
                return True
        elif isinstance(m, (ast.AugAssign, ast.AnnAssign)):
            if _root_is_self(m.target):
                return True
    return False


def check_fit_rng_order(tree: ast.AST, key: str,
                        path: str) -> List[Violation]:
    if key != "core/perf_model.py":
        return []
    noisy_helpers = {
        fn.name for fn in ast.walk(tree)
        if isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef))
        and "rng" in [a.arg for a in fn.args.args + fn.args.kwonlyargs]
        and any(isinstance(m, ast.Call)
                and isinstance(m.func, ast.Attribute)
                and isinstance(m.func.value, ast.Name)
                and m.func.value.id == "rng" for m in ast.walk(fn))
    }
    fit = next((n for n in ast.walk(tree)
                if isinstance(n, ast.FunctionDef) and n.name == "fit"),
               None)
    if fit is None:
        return []
    out: List[Violation] = []
    rng_binds = [st for st in fit.body if isinstance(st, ast.Assign)
                 and any(isinstance(t, ast.Name) and t.id == "rng"
                         for t in st.targets)]
    ok_bind = (len(rng_binds) == 1
               and isinstance(rng_binds[0].value, ast.Call)
               and isinstance(rng_binds[0].value.func, ast.Attribute)
               and rng_binds[0].value.func.attr == "default_rng"
               and rng_binds[0].value.args)
    if not ok_bind:
        out.append(Violation(
            path, fit.lineno, "RNG002",
            "fit() must bind rng exactly once, via "
            "np.random.default_rng(seed)"))
    flags = [(st, _draws_rng(st, noisy_helpers), _assigns_self(st))
             for st in fit.body]
    last_noisy = max((i for i, (_, noisy, _a) in enumerate(flags)
                      if noisy), default=-1)
    for i, (st, noisy, selfa) in enumerate(flags):
        if i < last_noisy and not noisy and selfa:
            out.append(Violation(
                path, st.lineno, "RNG001",
                "noiseless grid fit precedes a noisy (rng-drawing) fit "
                f"at line {flags[last_noisy][0].lineno} — new profiled "
                "grids must draw AFTER all previously-fitted streams, "
                "or golden bit-identity breaks"))
    return out


# -- DET: determinism in core/ -----------------------------------------------
def check_core_determinism(tree: ast.AST, key: str,
                           path: str) -> List[Violation]:
    if not key.startswith("core/") or key in SANCTIONED_DET_MODULES:
        return []
    out: List[Violation] = []
    for n in ast.walk(tree):
        if isinstance(n, ast.Import):
            for a in n.names:
                if a.name in ("time", "random"):
                    out.append(Violation(
                        path, n.lineno, "DET001",
                        f"import {a.name} in core/ — the simulation "
                        "substrate must be deterministic (seeded "
                        "np.random.default_rng is the sanctioned rng)"))
        elif isinstance(n, ast.ImportFrom):
            if n.module in ("time", "random"):
                out.append(Violation(
                    path, n.lineno, "DET001",
                    f"from {n.module} import ... in core/ — the "
                    "simulation substrate must be deterministic"))
        elif isinstance(n, ast.Call):
            f = n.func
            if not isinstance(f, ast.Attribute):
                continue
            if f.attr == "default_rng" and not (n.args or n.keywords):
                out.append(Violation(
                    path, n.lineno, "DET003",
                    "unseeded default_rng() in core/ — pass an explicit "
                    "seed"))
            # np.random.<legacy dist>(...) — the unseeded global stream
            if (isinstance(f.value, ast.Attribute)
                    and f.value.attr == "random"
                    and isinstance(f.value.value, ast.Name)
                    and f.value.value.id in ("np", "numpy")
                    and f.attr != "default_rng"):
                out.append(Violation(
                    path, n.lineno, "DET002",
                    f"legacy np.random.{f.attr}() in core/ — draws from "
                    "the unseeded global stream; use a seeded "
                    "default_rng generator"))
    return out


# -- CNT: BackendRun / QueryResult counter pairing ---------------------------
def _dataclass_fields(tree: ast.AST, cls_name: str) -> Optional[Set[str]]:
    cls = next((n for n in ast.walk(tree) if isinstance(n, ast.ClassDef)
                and n.name == cls_name), None)
    if cls is None:
        return None
    return {st.target.id for st in cls.body
            if isinstance(st, ast.AnnAssign)
            and isinstance(st.target, ast.Name)}


def check_counter_pairing(trees: Dict[str, ast.AST]) -> List[Violation]:
    bk_path = next((p for p in trees
                    if _module_key(p) == "api/backends.py"), None)
    rs_path = next((p for p in trees
                    if _module_key(p) == "api/results.py"), None)
    if bk_path is None or rs_path is None:
        return []
    run_fields = _dataclass_fields(trees[bk_path], "BackendRun")
    qr_fields = _dataclass_fields(trees[rs_path], "QueryResult")
    if run_fields is None or qr_fields is None:
        return []
    run_only: Set[str] = set()
    for n in ast.walk(trees[bk_path]):
        if (isinstance(n, ast.Assign) and len(n.targets) == 1
                and isinstance(n.targets[0], ast.Name)
                and n.targets[0].id == "RUN_ONLY_COUNTERS"):
            run_only = _frozenset_literal(n.value) or set()
    out: List[Violation] = []
    for f in sorted(run_fields - qr_fields - run_only
                    - STRUCTURAL_RUN_FIELDS):
        out.append(Violation(
            bk_path, 0, "CNT001",
            f"BackendRun.{f} has no matching QueryResult attribution "
            "field — per-query results silently drop it; add the field "
            "(+ payload summation in collect_results) or declare it in "
            "RUN_ONLY_COUNTERS with a rationale"))
    return out


# -- driver ------------------------------------------------------------------
def lint_paths(paths: Sequence[str]) -> List[Violation]:
    files: List[Path] = []
    for p in paths:
        pth = Path(p)
        if pth.is_dir():
            files.extend(sorted(pth.rglob("*.py")))
        else:
            files.append(pth)
    trees: Dict[str, ast.AST] = {}
    out: List[Violation] = []
    for f in files:
        try:
            trees[str(f)] = ast.parse(f.read_text(), filename=str(f))
        except SyntaxError as e:
            out.append(Violation(str(f), e.lineno or 0, "PARSE", str(e)))
    for fpath, tree in trees.items():
        key = _module_key(fpath)
        out += check_event_literals(tree, key, fpath)
        out += check_fit_rng_order(tree, key, fpath)
        out += check_core_determinism(tree, key, fpath)
    out += check_config_gates(trees)
    out += check_counter_pairing(trees)
    return out


def main(argv: Optional[Sequence[str]] = None) -> int:
    args = list(argv if argv is not None else sys.argv[1:]) or ["src"]
    violations = lint_paths(args)
    for v in violations:
        print(v)
    n_files = sum(len(list(Path(p).rglob("*.py")))
                  if Path(p).is_dir() else 1 for p in args)
    if violations:
        print(f"repro.analysis.lint: {len(violations)} violation(s) "
              f"in {n_files} file(s)")
        return 1
    print(f"repro.analysis.lint: OK ({n_files} file(s))")
    return 0


if __name__ == "__main__":
    sys.exit(main())
