"""Pre-run structural validation of workflow specs and task graphs.

``python -m repro.analysis.validate`` validates the builtin W1–W3
specs and their assembled DAGs (the CI fast-leg gate).  Programmatic
use::

    from repro.analysis.validate import ensure_valid
    ensure_valid(spec=my_spec)          # raises SpecValidationError
    issues = validate_spec(my_spec)     # inspect without raising

Wired into ``WorkflowSpec.build_dag(validate=True)`` behind
``SessionOptions.validate_spec``: structural errors (dependency
cycles, unknown deps, colliding branch ids, DecodeSpec pins on
non-decode stages) surface before any node is materialized instead of
as a ``KeyError`` mid-run; convention traps (a ``shared_ctx`` prefill
off the ``*_prefill`` naming convention without a ``kv_stage``
override, prefill/decode family mismatches that would page KV under
the wrong profiled shape) surface as warnings.

Everything here is duck-typed over the spec/DAG attribute surface so
the core build path never imports this module (it is imported lazily,
and only when validation is requested).
"""
from __future__ import annotations

import sys
import warnings
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Set

ERROR, WARNING = "error", "warning"


@dataclass(frozen=True)
class SpecIssue:
    code: str        # S0xx/W1xx (spec level), D0xx (graph level)
    where: str       # spec/stage/node the issue anchors to
    message: str
    severity: str = ERROR

    def __str__(self) -> str:
        return f"{self.code} [{self.where}] {self.message}"


class SpecValidationError(ValueError):
    """Raised by :func:`ensure_valid` when error-severity issues exist."""

    def __init__(self, issues: Sequence[SpecIssue]):
        self.issues = list(issues)
        super().__init__(
            "; ".join(str(i) for i in issues[:8])
            + (f" (+{len(issues) - 8} more)" if len(issues) > 8 else ""))


# -- spec-level --------------------------------------------------------------
def _cycle(deps: Dict[str, Set[str]]) -> Optional[List[str]]:
    """One dependency cycle among ``deps`` (id -> dep ids), or None."""
    WHITE, GRAY, BLACK = 0, 1, 2
    color = {k: WHITE for k in deps}
    stack: List[str] = []

    def visit(u: str) -> Optional[List[str]]:
        color[u] = GRAY
        stack.append(u)
        for v in sorted(deps.get(u, ())):
            if v not in color:
                continue
            if color[v] == GRAY:
                return stack[stack.index(v):] + [v]
            if color[v] == WHITE:
                cyc = visit(v)
                if cyc is not None:
                    return cyc
        stack.pop()
        color[u] = BLACK
        return None

    for k in sorted(deps):
        if color[k] == WHITE:
            cyc = visit(k)
            if cyc is not None:
                return cyc
    return None


def validate_spec(spec) -> List[SpecIssue]:
    """Structural + convention checks over one ``WorkflowSpec``."""
    from repro.core.kv_pages import decode_stage_of
    from repro.core.spec_decode import draft_stage_of

    out: List[SpecIssue] = []
    name = getattr(spec, "name", "<spec>")
    statics = list(getattr(spec, "statics", ()))
    groups = list(getattr(spec, "groups", ()))
    col = getattr(spec, "collector", None)
    ids = [s.id for s in statics]
    by_id = {s.id: s for s in statics}

    # S001: duplicate static ids shadow each other in the id map
    seen: Set[str] = set()
    for sid in ids:
        if sid in seen:
            out.append(SpecIssue("S001", f"{name}/{sid}",
                                 "duplicate static stage id"))
        seen.add(sid)

    # S002: dep must name a static (branch deps may also use tokens)
    for s in statics:
        for d in s.deps:
            if d not in by_id:
                out.append(SpecIssue(
                    "S002", f"{name}/{s.id}",
                    f"dep {d!r} is not a static stage id"))

    # S003: static dependency cycle
    cyc = _cycle({s.id: set(s.deps) & set(by_id) for s in statics})
    if cyc is not None:
        out.append(SpecIssue("S003", f"{name}/{cyc[0]}",
                             "static dependency cycle: "
                             + " -> ".join(cyc)))

    # groups
    for g in groups:
        if g.source not in by_id:
            out.append(SpecIssue(
                "S004", f"{name}/{g.source}",
                "branch-group source is not a static stage id"))
        prev_ok = False
        for bs in g.stages:
            if "{i}" not in bs.id:
                out.append(SpecIssue(
                    "S006", f"{name}/{bs.id}",
                    "branch stage id has no '{i}' placeholder — every "
                    "branch would mint the same node id"))
            for d in bs.deps:
                if d == "$prev" and not prev_ok:
                    out.append(SpecIssue(
                        "S005", f"{name}/{bs.id}",
                        "'$prev' dep on the first stage of a branch"))
                elif d not in ("$source", "$prev") and d not in by_id:
                    out.append(SpecIssue(
                        "S005", f"{name}/{bs.id}",
                        f"branch dep {d!r} is neither '$source'/'$prev' "
                        "nor a static stage id"))
            prev_ok = True

    # collector
    if col is not None:
        if col.base_dep not in by_id:
            out.append(SpecIssue(
                "S007", f"{name}/{col.base_dep}",
                "collector base_dep is not a static stage id"))
        for pf, dc in ((col.refine_prefill, col.refine_decode),
                       (col.chat_prefill, col.chat_decode)):
            if decode_stage_of(pf) != dc:
                out.append(SpecIssue(
                    "W104", f"{name}/{pf}",
                    f"collector prefill stage {pf!r} does not pair with "
                    f"decode stage {dc!r} under the *_prefill/*_decode "
                    "convention — its KV pages would adopt under "
                    f"{decode_stage_of(pf)!r}", WARNING))

    # per-stage conventions
    for s in statics:
        dec = getattr(s, "decode", None)
        if dec is not None and s.kind != "stream_decode" and (
                dec.draft_model is not None or dec.draft_width is not None):
            out.append(SpecIssue(
                "S008", f"{name}/{s.id}",
                "DecodeSpec draft pins (draft_model/draft_width) on a "
                f"{s.kind!r} stage — speculation only applies to "
                "stream_decode stages"))
        if (s.kind == "stream_decode" and dec is not None
                and dec.draft_model is not None
                and draft_stage_of(s.stage) is None):
            out.append(SpecIssue(
                "W106", f"{name}/{s.id}",
                f"draft_model pinned but stage {s.stage!r} is not a "
                "*_decode verify target — no draft companion stage is "
                "derivable, so speculation stays off", WARNING))
        if (s.kind == "stream_prefill"
                and getattr(s, "shared_ctx", None) is not None
                and not s.stage.endswith("_prefill")
                and (dec is None or dec.kv_stage is None)):
            out.append(SpecIssue(
                "W101", f"{name}/{s.id}",
                f"shared_ctx prefill stage {s.stage!r} off the *_prefill "
                "naming convention with no DecodeSpec.kv_stage override "
                "— prefix caching is disabled for it at build time",
                WARNING))
        if s.kind == "stream_decode":
            for d in s.deps:
                dep = by_id.get(d)
                if (dep is not None and dep.kind == "stream_prefill"
                        and dep.stage.endswith("_prefill")
                        and decode_stage_of(dep.stage) != s.stage
                        and (getattr(dep, "decode", None) is None
                             or dep.decode.kv_stage is None)):
                    out.append(SpecIssue(
                        "W103", f"{name}/{dep.id}",
                        f"prefill stage {dep.stage!r} feeds decode stage "
                        f"{s.stage!r} but its pages adopt under "
                        f"{decode_stage_of(dep.stage)!r} — set "
                        "DecodeSpec.kv_stage on the prefill", WARNING))

    # W105: dangling static — produced by no-one's input
    referenced: Set[str] = set()
    for s in statics:
        referenced |= set(s.deps)
    for g in groups:
        referenced.add(g.source)
        for bs in g.stages:
            referenced |= set(bs.deps) - {"$source", "$prev"}
    if col is not None:
        referenced.add(col.base_dep)
    final = None
    for s in reversed(statics):
        if s.kind == "stream_decode":
            final = s.id
            break
    for s in statics:
        if s.id not in referenced and s.id != final and col is None:
            out.append(SpecIssue(
                "W105", f"{name}/{s.id}",
                "static stage is neither depended on nor the final "
                "decode — dead work every query pays", WARNING))
    return out


# -- graph-level -------------------------------------------------------------
def validate_dag(dag) -> List[SpecIssue]:
    """Structural checks over an assembled ``DynamicDAG`` (pre-run)."""
    out: List[SpecIssue] = []
    nodes = dict(getattr(dag, "nodes", {}))

    for nid, n in nodes.items():
        for d in n.deps:
            if d not in nodes:
                out.append(SpecIssue(
                    "D002", nid, f"dep {d!r} is not in the graph"))
        if n.payload.get("no_coalesce") and n.payload.get("batch_pu"):
            out.append(SpecIssue(
                "D003", nid,
                "contradictory directives: no_coalesce (opt out of "
                "fused dispatch) with batch_pu (continuous-batch "
                "residency anchor)"))
        if n.payload.get("decode_round") and not n.payload.get("members"):
            out.append(SpecIssue(
                "D004", nid, "decode_round node without members"))
        if int(n.payload.get("kv_ctx", 0)) < 0:
            out.append(SpecIssue(
                "D005", nid, "negative kv_ctx"))

    cyc = _cycle({nid: set(n.deps) & set(nodes)
                  for nid, n in nodes.items()})
    if cyc is not None:
        out.append(SpecIssue("D001", cyc[0],
                             "dependency cycle: " + " -> ".join(cyc)))
    return out


# -- driver ------------------------------------------------------------------
def ensure_valid(spec=None, dag=None) -> List[SpecIssue]:
    """Validate and enforce: warnings are emitted via ``warnings.warn``;
    error-severity issues raise :class:`SpecValidationError`.  Returns
    the full issue list when nothing fatal was found."""
    issues: List[SpecIssue] = []
    if spec is not None:
        issues += validate_spec(spec)
    if dag is not None:
        issues += validate_dag(dag)
    errors = [i for i in issues if i.severity == ERROR]
    for i in issues:
        if i.severity == WARNING:
            warnings.warn(f"repro.analysis.validate: {i}",
                          RuntimeWarning, stacklevel=2)
    if errors:
        raise SpecValidationError(errors)
    return issues


def main(argv: Optional[Sequence[str]] = None) -> int:
    """Validate the builtin W1–W3 specs and their assembled DAGs."""
    from repro.api.spec import builtin_spec
    from repro.rag import sample_traces

    trace = sample_traces("hotpotqa", 1, seed=11)[0]
    failed = 0
    for wf in ("w1", "w2", "w3"):
        spec = builtin_spec(wf)
        try:
            with warnings.catch_warnings():
                warnings.simplefilter("error", RuntimeWarning)
                ensure_valid(spec=spec)
                ensure_valid(dag=spec.build_dag(trace))
                ensure_valid(dag=spec.build_dag(trace,
                                                fine_grained=False))
        except (SpecValidationError, RuntimeWarning) as e:
            print(f"{wf}: FAIL {e}")
            failed += 1
            continue
        print(f"{wf}: OK ({len(spec.statics)} statics, "
              f"{len(spec.groups)} groups)")
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
