"""Configuration dataclasses for the repro framework.

One ``ModelConfig`` describes any architecture in the assigned pool (dense /
MoE+MLA / Mamba2-hybrid / xLSTM / enc-dec audio / VLM).  ``ShapeConfig``
describes one (seq_len, global_batch, kind) input-shape cell of the dry-run
matrix.  ``reduced()`` shrinks a config for CPU smoke tests while keeping the
family topology (MoE stays MoE, hybrid stays hybrid, ...).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Tuple


@dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts sub-config (DeepSeek-style)."""

    num_experts: int = 0              # routed experts
    num_shared_experts: int = 0       # always-on shared experts
    top_k: int = 0                    # routed experts per token
    d_ff: int = 0                     # per-expert FFN hidden size
    first_k_dense: int = 0            # leading dense layers (DeepSeek)
    dense_d_ff: int = 0               # FFN size of those dense layers
    router_aux_loss: float = 0.001    # load-balance loss coefficient

    @property
    def enabled(self) -> bool:
        return self.num_experts > 0


@dataclass(frozen=True)
class MLAConfig:
    """Multi-head latent attention sub-config (DeepSeek v2/v3)."""

    kv_lora_rank: int = 0
    q_lora_rank: int = 0
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128

    @property
    def enabled(self) -> bool:
        return self.kv_lora_rank > 0

    @property
    def qk_head_dim(self) -> int:
        return self.qk_nope_head_dim + self.qk_rope_head_dim


@dataclass(frozen=True)
class SSMConfig:
    """Mamba2 / xLSTM sub-config."""

    state_size: int = 0               # N: SSM state dimension per group
    conv_kernel: int = 4
    head_dim: int = 64                # P: channels per SSM head
    expand: int = 2                   # d_inner = expand * d_model
    ngroups: int = 1                  # B/C groups (shared across heads)
    chunk_size: int = 256             # chunked-scan block length
    # hybrid (Zamba2): a shared attention block applied every N ssm blocks
    attn_every: int = 0               # 0 = no interleaved attention
    # xLSTM: which block indices are sLSTM (rest mLSTM)
    slstm_layers: Tuple[int, ...] = ()

    @property
    def enabled(self) -> bool:
        return self.state_size > 0


@dataclass(frozen=True)
class EncDecConfig:
    """Encoder-decoder sub-config (Whisper)."""

    encoder_layers: int = 0
    source_positions: int = 1500      # post-conv audio frames
    frontend: str = "stub"            # modality frontend is a STUB per spec

    @property
    def enabled(self) -> bool:
        return self.encoder_layers > 0


@dataclass(frozen=True)
class VLMConfig:
    """Cross-attention VLM sub-config (Llama-3.2-Vision)."""

    cross_attn_every: int = 0         # cross-attn layer every N layers
    vision_tokens: int = 1601         # patch embeddings per image (stub)
    vision_dim: int = 0               # dim of the (stub) vision embeddings

    @property
    def enabled(self) -> bool:
        return self.cross_attn_every > 0


@dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    family: str = "dense"             # dense | moe | hybrid | ssm | audio | vlm
    num_layers: int = 2
    d_model: int = 128
    num_heads: int = 4
    num_kv_heads: int = 4
    head_dim: int = 0                 # 0 -> d_model // num_heads
    d_ff: int = 256
    vocab_size: int = 512
    qkv_bias: bool = False
    gated_mlp: bool = True            # SwiGLU (3 mats) vs GELU (2 mats)
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    max_seq_len: int = 1 << 20
    # sub-configs
    moe: MoEConfig = field(default_factory=MoEConfig)
    mla: MLAConfig = field(default_factory=MLAConfig)
    ssm: SSMConfig = field(default_factory=SSMConfig)
    encdec: EncDecConfig = field(default_factory=EncDecConfig)
    vlm: VLMConfig = field(default_factory=VLMConfig)
    # DeepSeek-v3 multi-token prediction depth (extra MTP module count)
    mtp_depth: int = 0
    # numerics
    dtype: str = "bfloat16"
    # remat policy for training: none | dots | full
    remat: str = "dots"
    # attention implementation for train/prefill: "reference" materializes
    # the full score matrix; "chunked" is the flash-pattern online-softmax
    # scan over KV blocks (§Perf iteration 1 — memory-roofline fix)
    attn_impl: str = "reference"
    # sub-quadratic? (drives long_500k eligibility)
    subquadratic: bool = False

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.num_heads)

    @property
    def is_encoder_decoder(self) -> bool:
        return self.encdec.enabled

    def param_count(self) -> int:
        """Analytic parameter count (matches the constructed pytree closely;
        used for roofline MODEL_FLOPS = 6*N*D and the perf model)."""
        d, L, V = self.d_model, self.num_layers, self.vocab_size
        n = V * d  # embedding
        if not self.tie_embeddings:
            n += V * d  # lm head
        for layer in range(L):
            n += self._attn_params(layer)
            n += self._ffn_params(layer)
            n += 2 * d  # norms
        if self.ssm.enabled and self.ssm.attn_every > 0:
            # hybrid: ONE weight-shared attention+MLP block (Zamba2)
            n += self._dense_attn_params() + self._mlp_params(self.d_ff) + 2 * d
        if self.encdec.enabled:
            # encoder stack (self-attn + FFN + norms per layer)
            n += self.encdec.encoder_layers * (
                self._dense_attn_params() + self._mlp_params(self.d_ff) + 2 * d)
        if self.mtp_depth:
            # each MTP module: one extra transformer layer + projection
            n += self.mtp_depth * (self._attn_params(L - 1) + self._ffn_params(0) + d * 2 * d)
        return n

    def active_param_count(self) -> int:
        """Active (per-token) parameters — for MoE roofline MODEL_FLOPS."""
        if not self.moe.enabled:
            return self.param_count()
        d, L, V = self.d_model, self.num_layers, self.vocab_size
        n = V * d * (1 if self.tie_embeddings else 2)
        for layer in range(L):
            n += self._attn_params(layer) + 2 * d
            if layer < self.moe.first_k_dense:
                n += 3 * d * self.moe.dense_d_ff
            else:
                active = self.moe.top_k + self.moe.num_shared_experts
                n += 3 * d * self.moe.d_ff * active + d * self.moe.num_experts  # + router
        return n

    def _dense_attn_params(self) -> int:
        d, hd = self.d_model, self.resolved_head_dim
        q = d * self.num_heads * hd
        kv = 2 * d * self.num_kv_heads * hd
        o = self.num_heads * hd * d
        bias = (self.num_heads + 2 * self.num_kv_heads) * hd if self.qkv_bias else 0
        return q + kv + o + bias

    def _mlp_params(self, d_ff: int) -> int:
        if d_ff == 0:
            return 0
        mats = 3 if self.gated_mlp else 2
        return mats * self.d_model * d_ff

    def _attn_params(self, layer: int) -> int:
        d = self.d_model
        if self.ssm.enabled:
            # Mamba2 / xLSTM block parameters (hybrid shared-attn counted
            # separately, once, in param_count)
            di = self.ssm.expand * d
            nheads = max(di // max(self.ssm.head_dim, 1), 1)
            if self.family == "ssm" and layer in self.ssm.slstm_layers:
                # sLSTM block: 4 gates (i,f,z,o) recurrent + input proj + out
                return d * 4 * d + 4 * d * self.num_heads * 0 + 2 * d * di
            if self.family == "ssm":   # xLSTM mLSTM block
                return d * 3 * di + di * d + di * self.ssm.conv_kernel
            # mamba2: in_proj (z,x,B,C,dt) + conv(x,B,C) + out_proj
            bc = 2 * self.ssm.ngroups * self.ssm.state_size
            return d * (2 * di + bc + nheads) \
                + self.ssm.conv_kernel * (di + bc) + di * d
        if self.mla.enabled:
            m = self.mla
            nh = self.num_heads
            p = d * m.q_lora_rank + m.q_lora_rank * nh * m.qk_head_dim       # q path
            p += d * (m.kv_lora_rank + m.qk_rope_head_dim)                   # kv down
            p += m.kv_lora_rank * nh * (m.qk_nope_head_dim + m.v_head_dim)   # kv up
            p += nh * m.v_head_dim * d                                       # o proj
            return p
        p = self._dense_attn_params()
        if self.vlm.enabled and self._is_cross_attn_layer(layer):
            p *= 2  # cross-attn layer adds a parallel attention block
        if self.encdec.enabled:
            p += self._dense_attn_params()  # decoder cross-attention
        return p

    def _ffn_params(self, layer: int) -> int:
        d = self.d_model
        if self.ssm.enabled:
            return 0  # folded into the block
        if self.moe.enabled:
            if layer < self.moe.first_k_dense:
                return 3 * d * self.moe.dense_d_ff
            total = self.moe.num_experts + self.moe.num_shared_experts
            return 3 * d * self.moe.d_ff * total + d * self.moe.num_experts
        return self._mlp_params(self.d_ff)

    def _is_cross_attn_layer(self, layer: int) -> bool:
        return self.vlm.enabled and (layer % self.vlm.cross_attn_every == 0)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                          # "train" | "prefill" | "decode"

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


SHAPES: Tuple[ShapeConfig, ...] = (
    ShapeConfig("train_4k", 4096, 256, "train"),
    ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    ShapeConfig("decode_32k", 32768, 128, "decode"),
    ShapeConfig("long_500k", 524288, 1, "decode"),
)

SHAPES_BY_NAME = {s.name: s for s in SHAPES}


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Whether an (arch, shape) cell runs, and the reason if skipped."""
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, "long_500k needs sub-quadratic attention; arch is full-attention"
    return True, ""


def reduced(cfg: ModelConfig, *, layers: int = 2, d_model: int = 64,
            vocab: int = 256) -> ModelConfig:
    """Shrink a config for CPU smoke tests, preserving family topology."""
    heads = 4
    kv = min(cfg.num_kv_heads, heads) if cfg.num_kv_heads < cfg.num_heads else heads
    kv = max(1, min(kv, heads))
    changes = dict(
        num_layers=layers, d_model=d_model, num_heads=heads, num_kv_heads=kv,
        head_dim=d_model // heads, d_ff=(128 if cfg.d_ff else 0),
        vocab_size=vocab, max_seq_len=4096, dtype="float32", remat="none",
    )
    if cfg.moe.enabled:
        changes["moe"] = dataclasses.replace(
            cfg.moe, num_experts=4, top_k=2,
            num_shared_experts=min(cfg.moe.num_shared_experts, 1),
            d_ff=64, first_k_dense=min(cfg.moe.first_k_dense, 1), dense_d_ff=128)
        changes["d_ff"] = 0
    if cfg.mla.enabled:
        changes["mla"] = dataclasses.replace(
            cfg.mla, kv_lora_rank=32, q_lora_rank=32,
            qk_nope_head_dim=16, qk_rope_head_dim=8, v_head_dim=16)
        changes["head_dim"] = 16
    if cfg.ssm.enabled:
        changes["ssm"] = dataclasses.replace(
            cfg.ssm, state_size=16, head_dim=16, chunk_size=32,
            slstm_layers=tuple(i for i in cfg.ssm.slstm_layers if i < layers))
    if cfg.encdec.enabled:
        changes["encdec"] = dataclasses.replace(
            cfg.encdec, encoder_layers=layers, source_positions=16)
    if cfg.vlm.enabled:
        changes["vlm"] = dataclasses.replace(
            cfg.vlm, cross_attn_every=2, vision_tokens=8, vision_dim=d_model)
    if cfg.mtp_depth:
        changes["mtp_depth"] = 1
    return dataclasses.replace(cfg, **changes)
