"""Architecture registry: ``get_config(arch_id)`` / ``list_archs()``."""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.configs.base import (  # noqa: F401 (re-export)
    MLAConfig, MoEConfig, ModelConfig, SSMConfig, EncDecConfig, VLMConfig,
    ShapeConfig, SHAPES, SHAPES_BY_NAME, reduced, shape_applicable)

_ARCH_MODULES: Dict[str, str] = {
    "deepseek-v3-671b": "repro.configs.deepseek_v3_671b",
    "deepseek-v2-236b": "repro.configs.deepseek_v2_236b",
    "zamba2-1.2b": "repro.configs.zamba2_1p2b",
    "qwen1.5-0.5b": "repro.configs.qwen1p5_0p5b",
    "granite-3-2b": "repro.configs.granite_3_2b",
    "codeqwen1.5-7b": "repro.configs.codeqwen1p5_7b",
    "mistral-large-123b": "repro.configs.mistral_large_123b",
    "llama-3.2-vision-90b": "repro.configs.llama_3p2_vision_90b",
    "xlstm-350m": "repro.configs.xlstm_350m",
    "whisper-large-v3": "repro.configs.whisper_large_v3",
}


def list_archs() -> List[str]:
    return list(_ARCH_MODULES)


def get_config(arch: str) -> ModelConfig:
    if arch not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_ARCH_MODULES)}")
    return importlib.import_module(_ARCH_MODULES[arch]).CONFIG


def get_family(name: str) -> Dict[str, ModelConfig]:
    """Paper RAG model families: 'qwen3' (Fig. 5) or 'bge' (Fig. 6)."""
    if name == "qwen3":
        return importlib.import_module("repro.configs.qwen3_family").FAMILY
    if name == "bge":
        return importlib.import_module("repro.configs.bge_family").FAMILY
    raise KeyError(f"unknown family {name!r}; known: qwen3, bge")
