"""Paper model family 2 (Fig. 6): BGE + Llama3 RAG stage models.
Embed: bge-large-en-v1.5 (0.3B), Rerank: bge-reranker-large (0.6B),
Search: Llama-3.2-1B, Chat: Llama-3.1-8B.  All INT8-quantized in the paper.
"""
from repro.configs.base import ModelConfig

EMBED = ModelConfig(
    name="bge-large-en-v1.5", family="dense", num_layers=24, d_model=1024,
    num_heads=16, num_kv_heads=16, head_dim=64, d_ff=4096, vocab_size=30522,
    gated_mlp=False)

RERANK = ModelConfig(
    name="bge-reranker-large", family="dense", num_layers=24, d_model=1024,
    num_heads=16, num_kv_heads=16, head_dim=64, d_ff=4096, vocab_size=250002,
    gated_mlp=False)

SEARCH = ModelConfig(
    name="llama-3.2-1b", family="dense", num_layers=16, d_model=2048,
    num_heads=32, num_kv_heads=8, head_dim=64, d_ff=8192, vocab_size=128256,
    tie_embeddings=True)

CHAT = ModelConfig(
    name="llama-3.1-8b", family="dense", num_layers=32, d_model=4096,
    num_heads=32, num_kv_heads=8, head_dim=128, d_ff=14336, vocab_size=128256)

FAMILY = {"embed": EMBED, "rerank": RERANK, "search": SEARCH, "chat": CHAT}
