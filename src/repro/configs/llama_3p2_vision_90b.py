"""llama-3.2-vision-90b [vlm] — cross-attn image layers every 5th layer.
[hf:meta-llama/Llama-3.2-11B-Vision; unverified]
100L d_model=8192 64H (kv=8) d_ff=28672 vocab=128256.
The vision frontend is a STUB: input_specs() supplies precomputed patch
embeddings of shape (batch, vision_tokens, vision_dim).
"""
from repro.configs.base import ModelConfig, VLMConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-90b",
    family="vlm",
    num_layers=100,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=28672,
    vocab_size=128256,
    vlm=VLMConfig(cross_attn_every=5, vision_tokens=1601, vision_dim=8192),
    remat="full",
)
