"""deepseek-v3-671b [moe] — MLA, 1 shared + 256 routed top-8, MTP.
[arXiv:2412.19437; hf]  61L d_model=7168 128H (GQA kv=128) expert d_ff=2048
vocab=129280.
"""
from repro.configs.base import MLAConfig, MoEConfig, ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    family="moe",
    num_layers=61,
    d_model=7168,
    num_heads=128,
    num_kv_heads=128,
    head_dim=128,            # v head dim; qk dims come from MLA
    d_ff=0,                  # all FFNs are MoE (after first_k_dense)
    vocab_size=129280,
    rope_theta=10_000.0,
    moe=MoEConfig(num_experts=256, num_shared_experts=1, top_k=8,
                  d_ff=2048, first_k_dense=3, dense_d_ff=18432),
    mla=MLAConfig(kv_lora_rank=512, q_lora_rank=1536,
                  qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128),
    mtp_depth=1,
    remat="full",
)
