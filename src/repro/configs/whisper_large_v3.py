"""whisper-large-v3 [audio] — enc-dec, conv frontend (stub).
[arXiv:2212.04356; unverified]
32L d_model=1280 20H (kv=20) d_ff=5120 vocab=51866.
The conv/mel frontend is a STUB: input_specs() supplies precomputed frame
embeddings of shape (batch, source_positions, d_model).
"""
from repro.configs.base import EncDecConfig, ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3",
    family="audio",
    num_layers=32,           # decoder layers
    d_model=1280,
    num_heads=20,
    num_kv_heads=20,
    head_dim=64,
    d_ff=5120,
    vocab_size=51866,
    gated_mlp=False,         # whisper uses plain GELU fc1/fc2
    encdec=EncDecConfig(encoder_layers=32, source_positions=1500),
)
