"""xlstm-350m [ssm] — sLSTM + mLSTM blocks.  [arXiv:2405.04517; unverified]
24L d_model=1024 4H (kv=4) d_ff=0 vocab=50304.
Sub-quadratic (recurrent) -> eligible for long_500k.
"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="xlstm-350m",
    family="ssm",
    num_layers=24,
    d_model=1024,
    num_heads=4,
    num_kv_heads=4,
    head_dim=256,
    d_ff=0,                  # projections live inside the xLSTM blocks
    vocab_size=50304,
    # xLSTM[7:1]-style: one sLSTM block per 8 layers, rest mLSTM
    ssm=SSMConfig(state_size=256, conv_kernel=4, head_dim=256, expand=2,
                  chunk_size=256, slstm_layers=(3, 11, 19)),
    subquadratic=True,
)
