"""Paper model family 1 (Fig. 5): Qwen3 RAG stage models.
Embed: Qwen3-Embedding-0.6B, Rerank: Qwen3-Reranker-0.6B,
Search: Qwen3-1.7B, Chat: Qwen3-4B.  All INT8-quantized in the paper.
"""
from repro.configs.base import ModelConfig

EMBED = ModelConfig(
    name="qwen3-embedding-0.6b", family="dense", num_layers=28, d_model=1024,
    num_heads=16, num_kv_heads=8, head_dim=128, d_ff=3072, vocab_size=151669,
    tie_embeddings=True)

RERANK = ModelConfig(
    name="qwen3-reranker-0.6b", family="dense", num_layers=28, d_model=1024,
    num_heads=16, num_kv_heads=8, head_dim=128, d_ff=3072, vocab_size=151669,
    tie_embeddings=True)

SEARCH = ModelConfig(
    name="qwen3-1.7b", family="dense", num_layers=28, d_model=2048,
    num_heads=16, num_kv_heads=8, head_dim=128, d_ff=6144, vocab_size=151936,
    tie_embeddings=True)

CHAT = ModelConfig(
    name="qwen3-4b", family="dense", num_layers=36, d_model=2560,
    num_heads=32, num_kv_heads=8, head_dim=128, d_ff=9728, vocab_size=151936,
    tie_embeddings=True)

FAMILY = {"embed": EMBED, "rerank": RERANK, "search": SEARCH, "chat": CHAT}
