"""zamba2-1.2b [hybrid] — Mamba2 backbone + shared attention blocks.
[arXiv:2411.15242; hf]  38L d_model=2048 32H (kv=32) d_ff=8192 vocab=32000
ssm_state=64.  Sub-quadratic -> eligible for long_500k.
"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    num_layers=38,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    head_dim=64,
    d_ff=8192,               # MLP of the shared attention block
    vocab_size=32000,
    ssm=SSMConfig(state_size=64, conv_kernel=4, head_dim=64, expand=2,
                  chunk_size=256, attn_every=6),
    subquadratic=True,       # attention blocks use sliding window at long ctx
)
