"""Serving launcher: HeRo-orchestrated agentic RAG over real executors.

    PYTHONPATH=src python -m repro.launch.serve --workflow 2 --queries 3

Runs the full executable pipeline — chunker, embedder, vector DB, reranker,
rewriter/planner agents, chat generation — with reduced-config stage models
on heterogeneous PU-group executors under the HeRo scheduler.  On a pod
this is the deployment entry point: each PUExecutor wraps one mesh slice;
here each wraps a CPU worker (same control plane, the point of the dry-run
separation).
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.api import HeroSession, SessionOptions
from repro.configs import get_family, reduced
from repro.core.spec_decode import DEFAULT_DRAFT_MODEL, is_draft_stage
from repro.models import build_model
from repro.rag import (HashTokenizer, VectorDB, chunk_documents,
                       default_means, sample_traces, shared_corpus_traces,
                       synth_documents, synth_query)
from repro.rag.agents import LMAgent
from repro.rag.embedder import Embedder, Reranker
from repro.rag.stages import DRAFT_MODELS


def build_pipeline(seed: int = 0):
    fam = {k: reduced(v) for k, v in get_family("qwen3").items()}
    key = jax.random.PRNGKey(seed)
    models = {}
    for role, cfg in fam.items():
        params = build_model(cfg).init(jax.random.fold_in(key, hash(role) % 97))
        models[role] = (cfg, params)
    tok = HashTokenizer(fam["embed"].vocab_size)
    embedder = Embedder(*models["embed"])
    rerank = Reranker(*models["rerank"])
    rewriter = LMAgent(*models["search"], max_len=256)
    chat = LMAgent(*models["chat"], max_len=512)
    dcfg = reduced(DRAFT_MODELS[DEFAULT_DRAFT_MODEL])
    draft = LMAgent(dcfg, build_model(dcfg).init(
        jax.random.fold_in(key, 991)), max_len=256)
    return tok, embedder, rerank, rewriter, chat, draft


def build_stage_fns(seed: int = 0):
    """Wire the executable pipeline into perf-stage callables — the
    ``stage_fns`` a live-backend :class:`HeroSession` dispatches to."""
    tok, embedder, reranker, rewriter, chat, draft = build_pipeline(seed)

    docs = synth_documents(4, 400, seed=7)
    chunks = chunk_documents(docs, tok)
    db = VectorDB(dim=embedder.cfg.d_model)
    query = synth_query(seed=3)
    q_ids = tok.encode(query)

    def fn_embed(node, batch):
        if node.stage == "embed" and "embed_chunks" in node.id:
            take = chunks[: max(batch, 1)]
            db.add(np.asarray(embedder.embed([c.token_ids for c in take])))
            return len(take)
        return np.asarray(embedder.embed([q_ids]))

    def fn_vsearch(node, batch):
        return db.search(np.asarray(embedder.embed([q_ids])), k=4)

    def fn_rerank(node, batch):
        scores = reranker.score(q_ids, [chunks[i % len(chunks)].token_ids
                                        for i in range(min(batch, 8))])
        return scores.tolist()

    def fn_llm(node, batch):
        if is_draft_stage(node.stage):
            # speculative draft sub-dispatch: the small draft model
            # streams spec_width candidate tokens per verify pass
            # (workload = passes × width); candidates are greedy, so the
            # verify fn reproduces them for its acceptance comparison
            return draft.generate(q_ids[:16],
                                  max_new=min(node.workload, 16)).token_ids
        agent = rewriter if node.stage.startswith(("rewrite", "plan")) \
            else chat
        if node.kind == "stream_prefill":
            return "prefill"
        members = node.payload.get("members")
        if members:
            # resident continuous-batching decode round: ONE width-B JAX
            # call serves every member's token group; results slice back
            # per query (member id -> tokens)
            group = max(1, min(batch, 8))
            outs = agent.generate_batch([q_ids[:16]] * len(members),
                                        max_new=group)
            if node.payload.get("spec_width"):
                # speculative verify pass: accept the drafted prefix that
                # matches the target's own greedy tokens (both models are
                # deterministic, so regenerating the draft's candidates
                # here is exact) and stamp the per-member accept counts
                # the round boundary folds into the accept-rate EWMA
                douts = draft.generate_batch([q_ids[:16]] * len(members),
                                             max_new=group)
                node.payload["spec_accepts"] = {
                    m.id: sum(1 for a, b in zip(g.token_ids, dg.token_ids)
                              if a == b)
                    for m, g, dg in zip(members, outs, douts)}
            return {m.id: g.token_ids for m, g in zip(members, outs)}
        return agent.generate(q_ids[:16], max_new=min(batch, 8)).token_ids

    stage_fns = {s: fn_llm for s in
                 ("rewrite_prefill", "rewrite_decode", "plan_prefill",
                  "plan_decode", "refine_prefill", "refine_decode",
                  "chat_prefill", "chat_decode", "rewrite_draft",
                  "plan_draft", "refine_draft", "chat_draft")}
    stage_fns.update(embed=fn_embed, vsearch=fn_vsearch, rerank=fn_rerank,
                     __io__=lambda n, b: time.sleep(0.05))
    return stage_fns


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--workflow", type=int, default=2, choices=[1, 2, 3])
    ap.add_argument("--queries", type=int, default=2)
    ap.add_argument("--dataset", default="finqabench")
    ap.add_argument("--serve", action="store_true",
                    help="continuous serving mode: staggered admission into "
                         "one shared DAG with cross-query coalescing and "
                         "continuous decode batching (default: the paper's "
                         "isolated single-query latency protocol)")
    ap.add_argument("--inter-arrival", type=float, default=0.5,
                    help="seconds between arrivals in --serve mode")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="turn on the paged-KV subsystem and point every "
                         "query at one shared retrieved corpus, so later "
                         "prefills hit the cross-query prefix cache "
                         "(implies --serve admission)")
    ap.add_argument("--spec-decode", action="store_true",
                    help="speculative decoding: decode rounds dispatch as "
                         "coupled (draft, verify) pairs — the real draft "
                         "model streams candidates the target verifies in "
                         "one sweep (implies --serve admission)")
    args = ap.parse_args()

    if args.prefix_cache or args.spec_decode:
        args.serve = True
    if args.prefix_cache:
        traces = shared_corpus_traces(args.dataset, args.queries, seed=1)
    else:
        traces = sample_traces(args.dataset, args.queries, seed=1)
    sess = HeroSession(world="sd8gen4", family="qwen3", backend="live",
                       means=default_means(traces),
                       options=SessionOptions(
                           coalesce=bool(args.serve),
                           kv_pages=bool(args.prefix_cache),
                           spec_decode=bool(args.spec_decode)),
                       stage_fns=build_stage_fns())
    for qi, tr in enumerate(traces):
        sess.submit(tr, wf=args.workflow,
                    arrival_time=qi * args.inter_arrival if args.serve
                    else 0.0)
    results = sess.run(mode="shared" if args.serve else "isolated",
                       timeout=600)
    for res in results:
        extra = (f", {res.decode_rounds} batched decode rounds"
                 if res.decode_rounds else "")
        if res.kv_page_hits:
            extra += (f", {res.kv_page_hits} KV page hits "
                      f"({res.kv_hit_tokens} prefill tokens skipped)")
        print(f"query {res.qid}: {res.n_nodes} sub-stages in "
              f"{res.makespan:.2f}s wall{extra}")
    print(f"mean wall latency: {np.mean([r.makespan for r in results]):.2f}s "
          f"over {len(results)} queries")
    run = sess.last_run
    if args.prefix_cache and run is not None:
        print(f"prefix cache: {run.kv_page_hits} page hits, "
              f"{run.kv_hit_tokens} tokens skipped, "
              f"{run.kv_evictions} evictions")
    if args.spec_decode and run is not None:
        rate = (run.accepted_tokens / run.drafted_tokens
                if run.drafted_tokens else 0.0)
        print(f"spec decode: {run.spec_rounds} speculative rounds, "
              f"{run.drafted_tokens} drafted / {run.accepted_tokens} "
              f"accepted tokens (rate {rate:.2f})")


if __name__ == "__main__":
    main()
