"""Recursive HLO cost model for the dry-run roofline.

XLA's ``compiled.cost_analysis()`` counts each called computation ONCE —
a ``lax.scan`` over 88 layers reports 1/88th of the real FLOPs, and the
FSDP all-gathers inside the layer loop vanish from any flat accounting.
This walker parses the optimized (partitioned) HLO text and:

- multiplies ``while`` bodies by their trip count (read from the loop
  condition's comparison constant),
- descends into fusions / calls / conditionals,
- counts dot FLOPs from operand shapes (symbol table) + contracting dims,
- counts HBM bytes at fusion boundaries (operands + results of top-level
  instructions — XLA's own bytes-accessed convention),
- attributes collective bytes (all-gather / all-reduce / reduce-scatter /
  all-to-all / collective-permute) by result size, including inside loops.

Everything is per-device (the SPMD module is the per-device program).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {"f64": 8, "s64": 8, "u64": 8, "f32": 4, "s32": 4, "u32": 4,
                "bf16": 2, "f16": 2, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE = re.compile(r"(\w+)\[([0-9,]*)\]")
_CONTRACT = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}")

_MATH_OPS = {"add", "multiply", "subtract", "divide", "exponential", "tanh",
             "rsqrt", "sqrt", "log", "maximum", "minimum", "compare",
             "select", "convert", "negate", "power", "exponential-minus-one",
             "logistic", "cosine", "sine"}
_FREE_OPS = {"parameter", "constant", "get-tuple-element", "tuple",
             "bitcast", "after-all", "iota", "partition-id", "replica-id"}


def _shape_numel_bytes(shapes: List[Tuple[str, str]]) -> Tuple[int, int]:
    numel = nbytes = 0
    for dt, dims in shapes:
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        numel += n
        nbytes += n * _DTYPE_BYTES[dt]
    return numel, nbytes


@dataclass
class Instr:
    name: str
    op: str
    line: str
    result_shapes: List[Tuple[str, str]]
    operand_names: List[str]


@dataclass
class Computation:
    name: str
    instrs: List[Instr] = field(default_factory=list)
    symbols: Dict[str, List[Tuple[str, str]]] = field(default_factory=dict)
    params: List[str] = field(default_factory=list)


_HEADER = re.compile(r"(?:ENTRY\s+)?%?([\w.\-]+)\s*\((.*)\)\s*->")
_INSTR = re.compile(r"\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")
_OPTOK = re.compile(r"(?<![\w\-])([a-z][a-z0-9\-]*)\(")


def parse_hlo(text: str) -> Tuple[Dict[str, Computation], Optional[str]]:
    comps: Dict[str, Computation] = {}
    entry: Optional[str] = None
    cur: Optional[Computation] = None
    for raw in text.splitlines():
        if raw and not raw[0].isspace():
            if raw.rstrip().endswith("{"):
                m = _HEADER.match(raw)
                if m:
                    cur = Computation(m.group(1))
                    comps[cur.name] = cur
                    if raw.startswith("ENTRY"):
                        entry = cur.name
                    # parameters from the signature (order matters: they
                    # map positionally to fusion operands)
                    for pm in re.finditer(
                            r"([\w.\-]+):\s*(\([^)]*\)|\w+\[[0-9,]*\])",
                            m.group(2)):
                        cur.symbols[pm.group(1)] = _SHAPE.findall(pm.group(2))
                        cur.params.append(pm.group(1))
                else:
                    cur = None
            continue
        if cur is None:
            continue
        im = _INSTR.match(raw)
        if im is None:
            continue
        name, rhs = im.groups()
        om = _OPTOK.search(rhs)
        if om is None:
            continue
        op = om.group(1)
        head = rhs[: om.start()]
        res = _SHAPE.findall(head)
        cur.symbols[name] = res
        # operand names: %-refs inside the first balanced paren group
        depth, start, end = 0, om.end() - 1, len(rhs)
        for i in range(start, len(rhs)):
            if rhs[i] == "(":
                depth += 1
            elif rhs[i] == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        opnames = re.findall(r"%([\w.\-]+)", rhs[start:end])
        cur.instrs.append(Instr(name, op, rhs, res, opnames))
    return comps, entry


def _trip_count(cond: Computation) -> int:
    best = 1
    for ins in cond.instrs:
        for m in re.finditer(r"constant\((\d+)\)", ins.line):
            best = max(best, int(m.group(1)))
    return best


@dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll: Dict[str, float] = field(default_factory=lambda: {
        k: 0.0 for k in COLLECTIVES})

    def add(self, other: "Cost", mult: float = 1.0):
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        for k in COLLECTIVES:
            self.coll[k] += other.coll[k] * mult


class HloCostModel:
    def __init__(self, text: str):
        self.comps, self.entry = parse_hlo(text)
        self._memo: Dict[str, Cost] = {}

    def cost(self) -> Cost:
        if self.entry is None:
            return Cost()
        return self.comp_cost(self.entry)

    def comp_cost(self, name: str) -> Cost:
        if name in self._memo:
            return self._memo[name]
        self._memo[name] = Cost()          # cycle guard
        comp = self.comps.get(name)
        if comp is None:
            return self._memo[name]
        total = Cost()
        for ins in comp.instrs:
            total.add(self.instr_cost(comp, ins))
        self._memo[name] = total
        return total

    def _fusion_bytes(self, comp: Computation, ins: Instr,
                      fused: Computation) -> float:
        """Boundary bytes of a fusion with slice-aware aliasing."""
        # pass-through resolution: DUS/DS often address a bitcast/copy of
        # the parameter, not the parameter itself
        passthru: Dict[str, str] = {}
        for fins in fused.instrs:
            if fins.op in ("bitcast", "copy", "reshape", "transpose",
                           "convert") and \
                    len(fins.operand_names) == 1:
                passthru[fins.name] = fins.operand_names[0]

        def root(n: str) -> str:
            seen = set()
            while n in passthru and n not in seen:
                seen.add(n)
                n = passthru[n]
            return n

        sliced: Dict[str, int] = {}     # fused param -> slice bytes read
        dus_targets: Dict[str, int] = {}  # fused param -> update bytes
        for fins in fused.instrs:
            if fins.op == "dynamic-slice" and fins.operand_names:
                tgt = root(fins.operand_names[0])
                sb = _shape_numel_bytes(fins.result_shapes)[1]
                if tgt in fused.symbols:
                    sliced[tgt] = sliced.get(tgt, 0) + sb
            if fins.op == "dynamic-update-slice" and \
                    len(fins.operand_names) >= 2:
                tgt = root(fins.operand_names[0])
                upd = fins.operand_names[1]
                dus_targets[tgt] = dus_targets.get(tgt, 0) + \
                    _shape_bytes_of(fused.symbols, upd)
        total = 0.0
        for i, opname in enumerate(ins.operand_names):
            opb = _shape_bytes_of(comp.symbols, opname)
            pname = fused.params[i] if i < len(fused.params) else None
            if pname in dus_targets:
                opb = 0                     # aliased in-place target
            elif pname in sliced:
                opb = min(opb, sliced[pname])
            total += opb
        res_bytes = _shape_numel_bytes(ins.result_shapes)[1]
        if dus_targets:
            # in-place update: only the written slices count
            res_bytes = min(res_bytes, sum(dus_targets.values()))
        return total + res_bytes

    def _operand_shapes(self, comp: Computation, ins: Instr):
        out = []
        for n in ins.operand_names:
            out.extend(comp.symbols.get(n, []))
        return out

    def instr_cost(self, comp: Computation, ins: Instr) -> Cost:
        c = Cost()
        op = ins.op
        if op in _FREE_OPS:
            return c
        _, res_bytes = _shape_numel_bytes(ins.result_shapes)
        opd_shapes = self._operand_shapes(comp, ins)
        _, opd_bytes = _shape_numel_bytes(opd_shapes)

        if op == "while":
            cm = re.search(r"condition=%?([\w.\-]+)", ins.line)
            bm = re.search(r"body=%?([\w.\-]+)", ins.line)
            trips = _trip_count(self.comps[cm.group(1)]) \
                if cm and cm.group(1) in self.comps else 1
            if bm and bm.group(1) in self.comps:
                c.add(self.comp_cost(bm.group(1)), mult=trips)
            if cm and cm.group(1) in self.comps:
                c.add(self.comp_cost(cm.group(1)), mult=trips)
            return c
        if op == "conditional":
            bm = _BRANCHES.search(ins.line)
            if bm:
                costs = [self.comp_cost(b.strip().lstrip("%"))
                         for b in bm.group(1).split(",")]
                if costs:
                    c.add(max(costs, key=lambda x: x.flops + x.bytes))
            return c

        base = op.replace("-start", "").replace("-done", "")
        if base in COLLECTIVES:
            if op.endswith("-done"):
                return c
            c.coll[base] += res_bytes
            c.bytes += res_bytes + opd_bytes
            return c

        # descend into called computations (fusions, reduces, sorts, ...)
        # for FLOPs/collectives only: instructions inside a fusion do not
        # touch HBM — bytes are counted once at the fusion boundary.
        called = re.findall(r"(?:calls|to_apply|apply)=%?([\w.\-]+)",
                            ins.line)
        for sub in called:
            if sub in self.comps:
                sc = self.comp_cost(sub)
                c.add(Cost(flops=sc.flops, bytes=0.0,
                           coll=dict(sc.coll)))
        if op == "fusion" and called and called[0] in self.comps:
            # slice-aware boundary bytes: dynamic-slice reads and in-place
            # dynamic-update-slice writes touch only the slice, not the
            # full (possibly 100s-of-GB, scan-carried) operand
            c.bytes += self._fusion_bytes(comp, ins, self.comps[called[0]])
            return c

        if op == "dot":
            numel, _ = _shape_numel_bytes(ins.result_shapes)
            contract = 1
            cm = _CONTRACT.search(ins.line)
            if cm and opd_shapes:
                lhs_dims = opd_shapes[0][1].split(",")
                for idx in cm.group(1).split(","):
                    if idx and int(idx) < len(lhs_dims) and lhs_dims[int(idx)]:
                        contract *= int(lhs_dims[int(idx)])
            c.flops += 2.0 * numel * contract
        elif op == "convolution":
            numel, _ = _shape_numel_bytes(ins.result_shapes)
            kn = _shape_numel_bytes(opd_shapes[1:2])[0] if len(
                opd_shapes) > 1 else 1
            c.flops += 2.0 * numel * kn
        elif op in _MATH_OPS:
            numel, _ = _shape_numel_bytes(ins.result_shapes)
            c.flops += numel
        c.bytes += res_bytes + opd_bytes
        return c


def _shape_bytes_of(sym: Dict[str, List[Tuple[str, str]]], name: str) -> int:
    return _shape_numel_bytes(sym.get(name, []))[1]


def analyze(text: str) -> Dict[str, object]:
    cm = HloCostModel(text)
    c = cm.cost()
    return {"flops": c.flops, "bytes": c.bytes, "collectives": dict(c.coll)}
