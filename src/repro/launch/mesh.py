"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state.  Single pod: (data=16, model=16) = 256 chips.
Multi-pod: (pod=2, data=16, model=16) = 512 chips — the `pod` axis is pure
data parallelism (gradient all-reduce over DCN, int8-compressible via
training.grad_compression).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1, data: int = 1):
    """Small mesh over whatever devices exist (tests on CPU)."""
    n = len(jax.devices())
    model = min(model, n)
    data = max(min(data, n // model), 1)
    return jax.make_mesh((data, model), ("data", "model"))
