"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state.  Single pod: (data=16, model=16) = 256 chips.
Multi-pod: (pod=2, data=16, model=16) = 512 chips — the `pod` axis is pure
data parallelism (gradient all-reduce over DCN, int8-compressible via
training.grad_compression).
"""
from __future__ import annotations

from typing import Sequence, Tuple

import jax


def compat_make_mesh(shape: Tuple[int, ...], axes: Sequence[str]):
    """``jax.make_mesh`` with explicitly-Auto axis types where the
    installed jax supports them.

    ``jax.sharding.AxisType`` (and the ``axis_types=`` kwarg) only exist
    from jax 0.5.x; older releases treat every axis as Auto implicitly, so
    passing nothing is the same mesh.  Newer releases may flip the default
    toward Explicit sharding — pinning Auto keeps HLO lowering identical
    across versions (the hlo_cost walker depends on that)."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        try:
            return jax.make_mesh(
                shape, axes, axis_types=(axis_type.Auto,) * len(axes))
        except TypeError:          # AxisType present but kwarg not accepted
            pass
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat_make_mesh(shape, axes)


def make_host_mesh(model: int = 1, data: int = 1):
    """Small mesh over whatever devices exist (tests on CPU)."""
    n = len(jax.devices())
    model = min(model, n)
    data = max(min(data, n // model), 1)
    return compat_make_mesh((data, model), ("data", "model"))
