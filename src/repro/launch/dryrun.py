import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture × input shape)
cell on the production mesh, record memory_analysis / cost_analysis /
collective bytes, and emit the roofline terms.

This is how the distribution config is proven coherent without hardware:
a sharding mismatch, compile-time OOM, or unsupported collective fails the
cell.  Run:

    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen1.5-0.5b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod both \
        --out results/dryrun.jsonl
"""
import argparse      # noqa: E402
import json          # noqa: E402
import re            # noqa: E402
import time          # noqa: E402
from typing import Any, Dict, Optional  # noqa: E402

import jax           # noqa: E402
import numpy as np   # noqa: E402

from repro.configs import (  # noqa: E402
    SHAPES_BY_NAME, get_config, list_archs, shape_applicable)
from repro.launch.hlo_cost import analyze as hlo_analyze  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.models import build_model, input_specs, make_step_fn  # noqa: E402
from repro.models.sharding import (  # noqa: E402
    input_shardings, param_shardings, set_activation_mesh)
from repro.training.optimizer import AdamWConfig  # noqa: E402
from repro.training.train_loop import TrainConfig, make_train_step  # noqa: E402

# v5e hardware constants (roofline)
PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
ICI_BW = 50e9                # bytes/s per link (~3 links usable per chip)

_DTYPE_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s8": 1,
                "u8": 1, "pred": 1, "s64": 8, "u64": 8, "f64": 8, "s16": 2,
                "u16": 2, "f8e4m3fn": 1, "f8e5m2": 1}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Sum output-shape bytes of every collective op in the (optimized,
    partitioned) HLO.  cost_analysis() does not expose these."""
    out = {k: 0.0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        ls = line.strip()
        m = re.match(r"(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.*)$", ls)
        if m is None:
            continue
        rhs = m.group(1)
        opm = re.match(r"(?:\(|tuple\()?.*?\s*(" + "|".join(_COLLECTIVES)
                       + r")(?:-start|-done)?\(", rhs)
        if opm is None:
            continue
        op = opm.group(1)
        if f" {op}(" not in rhs and not rhs.startswith(op) and \
                f" {op}-start(" not in rhs:
            # op name must be the actual instruction, not operand text
            pass
        # shapes before the op name = result shapes
        head = rhs.split(op)[0]
        nbytes = 0
        for dt, dims in _SHAPE_RE.findall(head):
            if dt not in _DTYPE_BYTES:
                continue
            numel = 1
            for d in dims.split(","):
                if d:
                    numel *= int(d)
            nbytes += numel * _DTYPE_BYTES[dt]
        if "-done(" in rhs:
            continue                      # avoid double count of async pairs
        out[op] += nbytes
    return out


def _train_cfg(cfg, grad_accum: int = 1) -> TrainConfig:
    big = cfg.param_count() > 50e9
    return TrainConfig(optimizer=AdamWConfig(
        state_dtype="bfloat16" if big else "float32"),
        grad_accum=grad_accum)


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool,
               donate: bool = True, overrides: Optional[Dict] = None):
    """Returns (lowered, meta) for one dry-run cell.  ``overrides`` are
    dataclasses.replace fields on the ModelConfig (perf iterations)."""
    import dataclasses
    overrides = dict(overrides or {})
    grad_accum = int(overrides.pop("grad_accum", 1))
    cfg = get_config(arch)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    shape = SHAPES_BY_NAME[shape_name]
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        return None, {"skipped": why}
    mesh = make_production_mesh(multi_pod=multi_pod)
    set_activation_mesh(mesh)   # model code pins activation layouts
    model = build_model(cfg)
    specs = input_specs(cfg, shape)
    in_sh = input_shardings(cfg, shape, mesh, specs)
    p_shapes = jax.eval_shape(model.init, jax.random.key(0))
    p_sh = param_shardings(p_shapes, mesh)

    if shape.kind == "train":
        tcfg = _train_cfg(cfg, grad_accum)
        init_fn, step = make_train_step(cfg, tcfg)
        _, opt_shapes = jax.eval_shape(init_fn, jax.random.key(0))
        opt_sh = param_shardings(opt_shapes, mesh)
        lowered = jax.jit(
            step,
            in_shardings=(p_sh, opt_sh, in_sh["batch"]),
            donate_argnums=(0, 1) if donate else (),
        ).lower(p_shapes, opt_shapes, specs["batch"])
    elif shape.kind == "prefill":
        step = make_step_fn(cfg, shape)
        lowered = jax.jit(
            step, in_shardings=(p_sh, in_sh["batch"]),
        ).lower(p_shapes, specs["batch"])
    else:  # decode
        step = make_step_fn(cfg, shape)
        lowered = jax.jit(
            step,
            in_shardings=(p_sh, in_sh["tokens"], in_sh["cache"]),
            donate_argnums=(2,) if donate else (),
        ).lower(p_shapes, specs["tokens"], specs["cache"])
    return lowered, {"mesh": "2x16x16" if multi_pod else "16x16",
                     "devices": 512 if multi_pod else 256}


def roofline(cost: Dict[str, Any], coll: Dict[str, float], chips: int,
             cfg, shape) -> Dict[str, float]:
    """Three roofline terms (seconds).  cost_analysis on the partitioned
    SPMD module reports PER-DEVICE flops/bytes; collective bytes parsed
    from HLO are also per-device program values."""
    flops_dev = float(cost.get("flops", 0.0))
    bytes_dev = float(cost.get("bytes accessed", 0.0))
    coll_dev = float(sum(coll.values()))
    t_compute = flops_dev / PEAK_FLOPS
    t_memory = bytes_dev / HBM_BW
    t_coll = coll_dev / (3 * ICI_BW)       # ~3 usable links/chip on v5e
    n = (cfg.active_param_count() if cfg.moe.enabled else cfg.param_count())
    toks = shape.global_batch * (shape.seq_len if shape.kind == "train" else
                                 (shape.seq_len if shape.kind == "prefill"
                                  else 1))
    mult = 6 if shape.kind == "train" else 2
    model_flops = mult * n * toks
    hlo_flops_global = flops_dev * chips
    return {
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "flops_per_device": flops_dev,
        "bytes_per_device": bytes_dev,
        "collective_bytes_per_device": coll_dev,
        "model_flops": model_flops,
        "hlo_flops_global": hlo_flops_global,
        "useful_flops_frac": (model_flops / hlo_flops_global
                              if hlo_flops_global else 0.0),
        "bottleneck": max(
            [("compute", t_compute), ("memory", t_memory),
             ("collective", t_coll)], key=lambda kv: kv[1])[0],
    }


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             verbose: bool = True,
             overrides: Optional[Dict] = None) -> Dict[str, Any]:
    t0 = time.time()
    rec: Dict[str, Any] = {"arch": arch, "shape": shape_name,
                           "mesh": "2x16x16" if multi_pod else "16x16"}
    try:
        lowered, meta = lower_cell(arch, shape_name, multi_pod=multi_pod,
                                   overrides=overrides)
        if lowered is None:
            rec.update(status="skipped", reason=meta["skipped"])
            return rec
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        # HLO-walking cost model: multiplies scan bodies by trip count
        # (XLA's cost_analysis counts called computations once) — see
        # launch/hlo_cost.py.  xla_* kept for cross-checking.
        xla_cost = compiled.cost_analysis()
        hc = hlo_analyze(compiled.as_text())
        cost = {"flops": hc["flops"], "bytes accessed": hc["bytes"],
                "xla_flops": float(xla_cost.get("flops", 0.0))}
        coll = hc["collectives"]
        chips = meta["devices"]
        import dataclasses as _dc
        cfg = get_config(arch)
        model_over = {k: v for k, v in (overrides or {}).items()
                      if k != "grad_accum"}
        if model_over:
            cfg = _dc.replace(cfg, **model_over)
        shape = SHAPES_BY_NAME[shape_name]
        rec.update(
            status="ok",
            lower_s=round(t_lower, 1), compile_s=round(t_compile, 1),
            memory={
                "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
                "output_bytes": getattr(mem, "output_size_in_bytes", 0),
                "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
                "peak_bytes": getattr(mem, "temp_size_in_bytes", 0)
                + getattr(mem, "argument_size_in_bytes", 0),
            },
            collectives=coll,
            roofline=roofline(cost, coll, chips, cfg, shape),
        )
        hbm = rec["memory"]["peak_bytes"]
        rec["fits_16gb_hbm"] = bool(hbm < 16e9)
    except Exception as e:  # a failing cell is a bug in the system
        rec.update(status="error", error=f"{type(e).__name__}: {e}")
    if verbose:
        print(json.dumps(rec)[:400])
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default=None)
    ap.add_argument("--resume", action="store_true",
                    help="skip cells already present in --out")
    args = ap.parse_args()

    cells = []
    archs = list_archs() if (args.all or args.arch is None) else [args.arch]
    shapes = (list(SHAPES_BY_NAME) if (args.all or args.shape is None)
              else [args.shape])
    pods = {"single": [False], "multi": [True],
            "both": [False, True]}[args.multi_pod]
    for mp in pods:
        for a in archs:
            for s in shapes:
                cells.append((a, s, mp))

    done = set()
    if args.resume and args.out and os.path.exists(args.out):
        with open(args.out) as f:
            for line in f:
                r = json.loads(line)
                done.add((r["arch"], r["shape"], r["mesh"]))

    out_f = open(args.out, "a") if args.out else None
    n_ok = n_skip = n_err = 0
    for a, s, mp in cells:
        key = (a, s, "2x16x16" if mp else "16x16")
        if key in done:
            continue
        rec = run_cell(a, s, multi_pod=mp)
        n_ok += rec["status"] == "ok"
        n_skip += rec["status"] == "skipped"
        n_err += rec["status"] == "error"
        if out_f:
            out_f.write(json.dumps(rec) + "\n")
            out_f.flush()
    print(f"dry-run complete: ok={n_ok} skipped={n_skip} errors={n_err}")
    if out_f:
        out_f.close()
    return 1 if n_err else 0


if __name__ == "__main__":
    raise SystemExit(main())
