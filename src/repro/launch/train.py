"""Multi-pod training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-0.5b \
        --steps 100 --batch 8 --seq 256 --reduced --ckpt-dir /tmp/ckpt

On real hardware this runs under `jax.distributed.initialize()` with one
process per host; the mesh comes from make_production_mesh and params /
optimizer states take the shardings from models.sharding.  On this CPU
container, --reduced trains the smoke-scale config end-to-end (the same
code path, a 1-device mesh).

Fault tolerance: async checkpoints every --ckpt-every steps; on restart
the loop resumes from the newest complete checkpoint (restart-from-latest);
pre-emption is survivable at the cost of one checkpoint interval.
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro.checkpoint import Checkpointer
from repro.configs import get_config, reduced
from repro.training import TrainConfig, train
from repro.training.optimizer import AdamWConfig


def synthetic_data(cfg, batch: int, seq: int, seed: int = 0):
    step = 0
    while True:
        key = jax.random.PRNGKey(seed + step)
        toks = jax.random.randint(key, (batch, seq), 4, cfg.vocab_size)
        batch_d = {"tokens": toks, "labels": toks}
        if cfg.vlm.enabled:
            batch_d["vision_embeds"] = jax.random.normal(
                key, (batch, cfg.vlm.vision_tokens, cfg.vlm.vision_dim),
                jnp.dtype(cfg.dtype))
        if cfg.encdec.enabled:
            batch_d["audio_frames"] = jax.random.normal(
                key, (batch, cfg.encdec.source_positions, cfg.d_model),
                jnp.dtype(cfg.dtype))
        yield batch_d
        step += 1


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    tcfg = TrainConfig(optimizer=AdamWConfig(lr=args.lr,
                                             total_steps=args.steps),
                       grad_accum=args.grad_accum)
    ck = Checkpointer(args.ckpt_dir) if args.ckpt_dir else None
    params, _, hist = train(cfg, synthetic_data(cfg, args.batch, args.seq),
                            steps=args.steps, tcfg=tcfg, checkpointer=ck,
                            checkpoint_every=args.ckpt_every,
                            restore=args.resume)
    for h in hist:
        print(f"step {h['step']:5d}  loss {h['loss']:.4f}  "
              f"gnorm {h['grad_norm']:.3f}  wall {h['wall']:.1f}s")


if __name__ == "__main__":
    main()
