"""Mixture-of-Experts with capacity-based scatter dispatch (GShard-style).

Experts are sharded over the ``model`` mesh axis (expert parallelism); tokens
are scattered into an (E, C, d) grouped buffer, run through a batched expert
matmul, and gathered back with router-gate weighting.  Dropless behaviour is
approximated with a configurable capacity factor; dropped tokens fall through
via the residual connection (standard GShard semantics).

DeepSeek specifics implemented: shared experts (always-on), sigmoid routing
with top-k renormalisation (v3) / softmax routing (v2), and an auxiliary
load-balance loss returned to the caller.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import MoEConfig
from repro.models.layers import dense_init
from repro.models.sharding import constrain

Params = Dict[str, Any]


def init_moe(key, d: int, cfg: MoEConfig, dtype) -> Params:
    E, ff = cfg.num_experts, cfg.d_ff
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], (d, E), jnp.float32),
        "w_gate": dense_init(ks[1], (E, d, ff), dtype),
        "w_up": dense_init(ks[2], (E, d, ff), dtype),
        "w_down": dense_init(ks[3], (E, ff, d), dtype),
    }
    if cfg.num_shared_experts:
        sff = ff * cfg.num_shared_experts
        ks2 = jax.random.split(ks[4], 3)
        p["shared"] = {
            "w_gate": dense_init(ks2[0], (d, sff), dtype),
            "w_up": dense_init(ks2[1], (d, sff), dtype),
            "w_down": dense_init(ks2[2], (sff, d), dtype),
        }
    return p


def _router(p: Params, x2: jax.Array, cfg: MoEConfig
            ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """x2: (T, d) -> gates (T, k), idx (T, k), aux_loss (scalar)."""
    logits = jnp.einsum("td,de->te", x2.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, cfg.top_k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    # switch-style load-balance auxiliary loss
    E = cfg.num_experts
    me = jnp.mean(probs, axis=0)                                   # (E,)
    ce = jnp.mean(jax.nn.one_hot(idx[:, 0], E, dtype=jnp.float32), axis=0)
    aux = E * jnp.sum(me * ce) * cfg.router_aux_loss
    return gates.astype(x2.dtype), idx, aux


def moe_ffn(p: Params, x: jax.Array, cfg: MoEConfig, *,
            capacity_factor: float = 1.25) -> Tuple[jax.Array, jax.Array]:
    """x: (b, s, d) -> (y, aux_loss)."""
    b, s, d = x.shape
    T = b * s
    E, k = cfg.num_experts, cfg.top_k
    x2 = x.reshape(T, d)
    gates, idx, aux = _router(p, x2, cfg)                          # (T,k)

    # capacity per expert (static shape; ceil to a multiple of 8)
    C = int(max(8, -(-int(T * k * capacity_factor) // E)))
    C = -(-C // 8) * 8

    flat_e = idx.reshape(-1)                                        # (T*k,)
    flat_g = gates.reshape(-1)
    # position of each assignment within its expert queue
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)             # (T*k, E)
    pos_in_e = (jnp.cumsum(onehot, axis=0) - onehot)                # exclusive
    slot = jnp.take_along_axis(pos_in_e, flat_e[:, None], axis=1)[:, 0]
    keep = slot < C                                                 # drop overflow
    slot_c = jnp.where(keep, slot, 0)
    src = jnp.repeat(jnp.arange(T), k)                              # token of each slot

    # scatter tokens into the grouped buffer (E, C, d) — expert-sharded
    grouped = jnp.zeros((E, C, d), x.dtype)
    upd = jnp.where(keep[:, None], x2[src], 0)
    grouped = grouped.at[flat_e, slot_c].add(upd, mode="drop")
    # decode (small T): d sharded on "data" keeps expert weights
    # stationary — the expert matmul psums the tiny activations instead of
    # all-gathering FSDP-sharded weights every layer (§Perf hillclimb C:
    # 30x collective reduction on the 512-chip mesh).  Prefill/train keep
    # d replicated: there the activations dwarf the weights.
    grouped = constrain(grouped, ("model", None, "data") if T <= 4096
                        else ("model", None, None))

    # expert FFN: batched over the (sharded) expert dim
    gate = jnp.einsum("ecd,edf->ecf", grouped, p["w_gate"])
    up = jnp.einsum("ecd,edf->ecf", grouped, p["w_up"])
    act = jax.nn.silu(gate) * up
    out_g = jnp.einsum("ecf,efd->ecd", act, p["w_down"])            # (E, C, d)

    # gather back with gate weighting
    picked = out_g[flat_e, slot_c]                                  # (T*k, d)
    picked = jnp.where(keep[:, None], picked, 0) * flat_g[:, None]
    y = jnp.zeros((T, d), x.dtype).at[src].add(picked)

    if cfg.num_shared_experts:
        sp = p["shared"]
        h = jax.nn.silu(jnp.einsum("td,df->tf", x2, sp["w_gate"])) \
            * jnp.einsum("td,df->tf", x2, sp["w_up"])
        y = y + jnp.einsum("tf,fd->td", h, sp["w_down"])
    return y.reshape(b, s, d), aux
