"""Public model facade: build_model(cfg) + input_specs(cfg, shape).

``input_specs`` returns ShapeDtypeStruct stand-ins for every model input of a
given (arch, shape) cell — weak-type-correct, shardable, no allocation — used
by the multi-pod dry-run and the roofline extraction.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import lm

Params = Dict[str, Any]


class Model(NamedTuple):
    cfg: ModelConfig
    init: Callable[[jax.Array], Params]
    apply: Callable[..., Tuple[jax.Array, jax.Array, Optional[Params]]]
    loss_fn: Callable[[Params, Dict[str, jax.Array]],
                      Tuple[jax.Array, Dict[str, jax.Array]]]
    prefill: Callable[..., Tuple[jax.Array, Params]]
    decode_step: Callable[..., Tuple[jax.Array, Params]]
    init_cache: Callable[[int, int], Params]


def build_model(cfg: ModelConfig) -> Model:
    def init(key):
        return lm.init_params(key, cfg)

    def apply(params, batch, *, mode="train", cache=None):
        return lm.apply(params, cfg, batch, mode=mode, cache=cache)

    def loss_fn(params, batch):
        return lm.loss_fn(params, cfg, batch)

    def prefill(params, batch, cache):
        logits, _, new_cache = lm.apply(params, cfg, batch, mode="prefill",
                                        cache=cache)
        return logits, new_cache

    def decode_step(params, tokens, cache, extras=None):
        batch = {"tokens": tokens}
        if extras:
            batch.update(extras)
        logits, _, new_cache = lm.apply(params, cfg, batch, mode="decode",
                                        cache=cache)
        return logits[:, -1], new_cache

    def init_cache(batch_size, max_len):
        return lm.init_cache(cfg, batch_size, max_len)

    return Model(cfg, init, apply, loss_fn, prefill, decode_step, init_cache)


# ---------------------------------------------------------------------------
# dry-run input specs
# ---------------------------------------------------------------------------

def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, jnp.dtype(dtype))


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for one (arch × shape) dry-run cell.

    train/prefill: {"batch": {...}}.
    decode: {"tokens": ..., "cache": <full cache spec at seq_len>}.
    """
    B, S = shape.global_batch, shape.seq_len
    dt = cfg.dtype

    def frontends(b):
        ex = {}
        if cfg.vlm.enabled:
            ex["vision_embeds"] = _sds((b, cfg.vlm.vision_tokens,
                                        cfg.vlm.vision_dim), dt)
        if cfg.encdec.enabled:
            ex["audio_frames"] = _sds((b, cfg.encdec.source_positions,
                                       cfg.d_model), dt)
        return ex

    if shape.kind == "train":
        batch = {"tokens": _sds((B, S), jnp.int32),
                 "labels": _sds((B, S), jnp.int32), **frontends(B)}
        return {"batch": batch}
    if shape.kind == "prefill":
        batch = {"tokens": _sds((B, S), jnp.int32), **frontends(B)}
        return {"batch": batch}
    # decode: one new token against a cache of length S
    cache = jax.eval_shape(lambda: lm.init_cache(cfg, B, S))
    return {"tokens": _sds((B, 1), jnp.int32), "cache": cache}


def make_step_fn(cfg: ModelConfig, shape: ShapeConfig):
    """The function the dry-run lowers for this cell: train_step(grad) for
    train shapes, forward for prefill, serve_step for decode shapes."""
    model = build_model(cfg)

    if shape.kind == "train":
        def train_fwd(params, batch):
            loss, _ = model.loss_fn(params, batch)
            return loss

        def train_step(params, batch):
            loss, grads = jax.value_and_grad(train_fwd)(params, batch)
            return loss, grads
        return train_step

    if shape.kind == "prefill":
        def prefill_step(params, batch):
            logits, _, _ = model.apply(params, batch, mode="train")
            return logits[:, -1]
        return prefill_step

    def serve_step(params, tokens, cache):
        return model.decode_step(params, tokens, cache)
    return serve_step
