"""xLSTM blocks: mLSTM (matrix memory, chunkwise-parallel) and sLSTM
(scalar memory, sequential scan), following arXiv:2405.04517.

mLSTM state: (C (b,H,P,P) matrix memory, n (b,H,P) normalizer, m (b,H)
log-space stabilizer).  The chunkwise form processes Q-token chunks with an
intra-chunk masked quadratic term plus the carried inter-chunk state —
sub-quadratic in sequence length, O(1)-state decode.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import SSMConfig
from repro.models.layers import dense_init
from repro.models.sharding import constrain

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def init_mlstm(key, d: int, s: SSMConfig, dtype) -> Params:
    di = s.expand * d
    H = max(di // s.head_dim, 1)
    ks = jax.random.split(key, 6)
    return {
        "wq": dense_init(ks[0], (d, di), dtype),
        "wk": dense_init(ks[1], (d, di), dtype),
        "wv": dense_init(ks[2], (d, di), dtype),
        "wgate": dense_init(ks[3], (d, 2 * H), jnp.float32),  # i,f gate logits
        "gate_bias": jnp.concatenate(
            [jnp.zeros((H,)), 3.0 + jnp.arange(H, dtype=jnp.float32) * 0.5]),
        "conv": dense_init(ks[4], (s.conv_kernel, di), dtype),
        "w_out": dense_init(ks[5], (di, d), dtype, scale=di ** -0.5),
    }


def _mlstm_chunk(q, k, v, ig, fg, state):
    """One chunk of the stabilized chunkwise mLSTM.

    q/k/v (b,Q,H,P); ig/fg (b,Q,H) gate log-values; state (C,n,m).
    Returns (h (b,Q,H,P), new_state).
    """
    b, Q, H, P = q.shape
    C0, n0, m0 = state                                    # (b,H,P,P),(b,H,P),(b,H)
    lf = jax.nn.log_sigmoid(fg)                            # (b,Q,H)
    F = jnp.cumsum(lf, axis=1)                             # inclusive cumsum
    # intra-chunk log decay matrix: D[i,j] = F_i - F_j + ig_j  (j <= i)
    logD = (F[:, :, None, :] - F[:, None, :, :]
            + ig[:, None, :, :])                           # (b,Qi,Qj,H)
    mask = jnp.tril(jnp.ones((Q, Q), bool))[None, :, :, None]
    logD = jnp.where(mask, logD, -jnp.inf)
    # inter-chunk log decay: F_i + m0
    log_inter = F + m0[:, None, :]                         # (b,Q,H)
    m_new = jnp.maximum(jnp.max(logD, axis=2), log_inter)  # (b,Q,H) row max
    m_new = jnp.maximum(m_new, -1e30)                      # guard -inf rows
    D = jnp.exp(logD - m_new[:, :, None, :])               # (b,Qi,Qj,H)
    inter_w = jnp.exp(log_inter - m_new)                   # (b,Q,H)

    qf = q.astype(jnp.float32) / jnp.sqrt(jnp.float32(P))
    kf, vf = k.astype(jnp.float32), v.astype(jnp.float32)
    scores = jnp.einsum("bqhp,bkhp->bqkh", qf, kf) * D     # (b,Qi,Qj,H)
    num = (jnp.einsum("bqkh,bkhp->bqhp", scores, vf)
           + inter_w[..., None] * jnp.einsum("bqhp,bhpe->bqhe", qf, C0))
    den = (scores.sum(axis=2)
           + inter_w * jnp.einsum("bqhp,bhp->bqh", qf, n0))
    h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_new))[..., None]

    # chunk-end state update
    Fend = F[:, -1, :]                                     # (b,H)
    m_end = jnp.maximum(Fend + m0, jnp.max(F[:, -1:, :] - F + ig, axis=1))
    w_prev = jnp.exp(Fend + m0 - m_end)                    # (b,H)
    w_tok = jnp.exp(Fend[:, None] - F + ig - m_end[:, None])  # (b,Q,H)
    C1 = (w_prev[..., None, None] * C0
          + jnp.einsum("bqh,bqhp,bqhe->bhpe", w_tok, kf, vf))
    n1 = w_prev[..., None] * n0 + jnp.einsum("bqh,bqhp->bhp", w_tok, kf)
    return h, (C1, n1, m_end)


def mlstm_forward(p: Params, x: jax.Array, s: SSMConfig, *,
                  init_state: Optional[Params] = None,
                  return_state: bool = False
                  ) -> Tuple[jax.Array, Optional[Params]]:
    """x (b,l,d), l a multiple of chunk (or l < chunk)."""
    from repro.models.ssm import _causal_conv
    b, l_real, d = x.shape
    di = s.expand * d
    H, P = max(di // s.head_dim, 1), s.head_dim
    Q = min(s.chunk_size, l_real)
    # pad to a chunk multiple; padded positions made state-neutral:
    # f-gate -> 1 (log 0), i-gate -> 0 (log -inf)
    l = -(-l_real // Q) * Q
    if l != l_real:
        x = jnp.pad(x, ((0, 0), (0, l - l_real), (0, 0)))
    nc = l // Q
    dtype = x.dtype

    conv_s = init_state["conv"] if init_state else None
    gates = (jnp.einsum("bld,dg->blg", x.astype(jnp.float32), p["wgate"])
             + p["gate_bias"])
    ig, fg = gates[..., :H], gates[..., H:]
    if l != l_real:
        valid = (jnp.arange(l) < l_real)[None, :, None]
        ig = jnp.where(valid, ig, -1e30)
        fg = jnp.where(valid, fg, 30.0)   # log_sigmoid(30) ~ 0
    # mLSTM heads (H=4) cannot shard a 16-way model axis; forcing the
    # projections model-sharded makes every chunk-scan step all-gather.
    # Gather ONCE here (replicated inner activations) instead — §Perf
    # hillclimb B: collective term -6x at prefill.  Single-token decode
    # keeps the sharded layout (replication costs more than it saves).
    inner_spec = ("batch", None, None) if l_real > 1 else \
        ("batch", None, "model")
    xq, new_conv = _causal_conv(
        constrain(jnp.einsum("bld,de->ble", x, p["wq"]), inner_spec),
        p["conv"], conv_s, state_len=l_real)
    k = constrain(jnp.einsum("bld,de->ble", x, p["wk"]),
                  inner_spec).reshape(b, l, H, P)
    v = constrain(jnp.einsum("bld,de->ble", x, p["wv"]),
                  inner_spec).reshape(b, l, H, P)
    q = xq.reshape(b, l, H, P)

    if init_state is not None:
        st = (init_state["C"].astype(jnp.float32),
              init_state["n"].astype(jnp.float32),
              init_state["m"].astype(jnp.float32))
    else:
        st = (jnp.zeros((b, H, P, P), jnp.float32),
              jnp.zeros((b, H, P), jnp.float32),
              jnp.full((b, H), -1e30, jnp.float32))

    def step(carry, inp):
        qc, kc, vc, igc, fgc = inp
        h, new = _mlstm_chunk(qc, kc, vc, igc, fgc, carry)
        return new, h

    xs = (q.reshape(b, nc, Q, H, P).transpose(1, 0, 2, 3, 4),
          k.reshape(b, nc, Q, H, P).transpose(1, 0, 2, 3, 4),
          v.reshape(b, nc, Q, H, P).transpose(1, 0, 2, 3, 4),
          ig.reshape(b, nc, Q, H).transpose(1, 0, 2, 3),
          fg.reshape(b, nc, Q, H).transpose(1, 0, 2, 3))
    final, hs = jax.lax.scan(step, st, xs)                 # hs (nc,b,Q,H,P)
    h = hs.transpose(1, 0, 2, 3, 4).reshape(b, l, di).astype(dtype)
    out = jnp.einsum("ble,ed->bld", h, p["w_out"])
    if l != l_real:
        out = out[:, :l_real]
    if not return_state:
        return out, None
    C1, n1, m1 = final
    return out, {"C": C1.astype(jnp.float32), "n": n1.astype(jnp.float32),
                 "m": m1, "conv": new_conv}


def init_mlstm_state(batch: int, d: int, s: SSMConfig, dtype) -> Params:
    di = s.expand * d
    H, P = max(di // s.head_dim, 1), s.head_dim
    return {"C": jnp.zeros((batch, H, P, P), jnp.float32),
            "n": jnp.zeros((batch, H, P), jnp.float32),
            "m": jnp.full((batch, H), -1e30, jnp.float32),
            "conv": jnp.zeros((batch, s.conv_kernel - 1, di), dtype)}


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def init_slstm(key, d: int, dtype) -> Params:
    ks = jax.random.split(key, 3)
    return {
        "W": dense_init(ks[0], (d, 4 * d), dtype),     # i,f,z,o input weights
        "R": dense_init(ks[1], (d, 4 * d), dtype),     # recurrent weights
        "bias": jnp.zeros((4 * d,), jnp.float32),
        "w_out": dense_init(ks[2], (d, d), dtype),
    }


def slstm_forward(p: Params, x: jax.Array, *,
                  init_state: Optional[Params] = None,
                  return_state: bool = False
                  ) -> Tuple[jax.Array, Optional[Params]]:
    """Sequential scan over time.  x (b,l,d)."""
    b, l, d = x.shape
    dtype = x.dtype
    if init_state is not None:
        st = tuple(init_state[k].astype(jnp.float32) for k in "cnhm")
    else:
        z = jnp.zeros((b, d), jnp.float32)
        st = (z, z, z, jnp.full((b, d), -1e30, jnp.float32))

    wx = jnp.einsum("bld,de->ble", x, p["W"]).astype(jnp.float32) + p["bias"]

    def step(carry, wx_t):
        c, n, h, m = carry
        g = wx_t + jnp.einsum("bd,de->be", h.astype(dtype),
                              p["R"]).astype(jnp.float32)
        gi, gf, gz, go = jnp.split(g, 4, axis=-1)
        m_new = jnp.maximum(gf + m, gi)                 # exp-gate stabilizer
        i = jnp.exp(gi - m_new)
        f = jnp.exp(gf + m - m_new)
        c = f * c + i * jnp.tanh(gz)
        n = f * n + i
        h = jax.nn.sigmoid(go) * c / jnp.maximum(n, 1.0)
        return (c, n, h, m_new), h

    final, hs = jax.lax.scan(step, st, wx.transpose(1, 0, 2))
    h = hs.transpose(1, 0, 2).astype(dtype)
    out = jnp.einsum("bld,de->ble", h, p["w_out"])
    if not return_state:
        return out, None
    c, n, h_l, m = final
    return out, {"c": c, "n": n, "h": h_l, "m": m}


def init_slstm_state(batch: int, d: int) -> Params:
    z = jnp.zeros((batch, d), jnp.float32)
    return {"c": z, "n": z, "h": z, "m": jnp.full((batch, d), -1e30,
                                                  jnp.float32)}
