"""Generic language model assembling the layer zoo per ModelConfig.

Design notes:
- Pure functional: ``init_params`` builds a pytree, ``apply`` runs it.
- Homogeneous layer stacks are **scanned** (stacked params with a leading
  layer dim) — O(1) HLO size in depth, which keeps 100-layer dry-run
  compiles tractable and is what production JAX frameworks do.
- One code path serves train / prefill / decode, switched by whether a
  cache pytree is provided.  Caches for scanned stacks are stacked arrays
  fed through ``lax.scan`` xs/ys.
- Sliding-window ring caches (bounded memory) activate for sub-quadratic
  archs at long context (Zamba2 long_500k).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import mla as MLA
from repro.models import moe as MOE
from repro.models import ssm as SSM
from repro.models import xlstm as XL
from repro.models.sharding import constrain

Params = Dict[str, Any]

NEG_POS = -(1 << 30)  # ring-buffer "empty slot" position


def _stack_init(fn, key, n: int):
    return jax.vmap(fn)(jax.random.split(key, n))


def _remat(fn, cfg: ModelConfig, mode: str):
    if mode != "train" or cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        pol = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        return jax.checkpoint(fn, policy=pol)
    return jax.checkpoint(fn)


# ---------------------------------------------------------------------------
# dense transformer block (attn + mlp) — used by dense/vlm/audio/hybrid-shared
# ---------------------------------------------------------------------------

def init_dense_block(key, cfg: ModelConfig, *, d_ff: Optional[int] = None,
                     cross: bool = False) -> Params:
    d = cfg.d_model
    dtype = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 4)
    p = {
        "ln1": L.init_rmsnorm(d, dtype),
        "attn": L.init_attention(ks[0], d, cfg.num_heads, cfg.num_kv_heads,
                                 cfg.resolved_head_dim, cfg.qkv_bias, dtype),
        "ln2": L.init_rmsnorm(d, dtype),
        "mlp": L.init_mlp(ks[1], d, d_ff or cfg.d_ff, cfg.gated_mlp, dtype),
    }
    if cross:
        p["ln_x"] = L.init_rmsnorm(d, dtype)
        p["xattn"] = L.init_attention(ks[2], d, cfg.num_heads,
                                      cfg.num_kv_heads, cfg.resolved_head_dim,
                                      False, dtype)
        p["xgate"] = jnp.zeros((), jnp.float32)
    return p


def dense_block(p: Params, cfg: ModelConfig, x, *, positions, causal=True,
                cache=None, cache_idx=None, window=0, cross_kv=None,
                cross_cache=None):
    """Returns (x, new_cache, new_cross_cache)."""
    h, new_cache = _attend(p["attn"], cfg, L.rmsnorm(p["ln1"], x, cfg.norm_eps),
                           positions=positions, causal=causal, cache=cache,
                           cache_idx=cache_idx, window=window)
    x = constrain(x + h, ("batch", None, None))
    new_cross = None
    if "xattn" in p and (cross_kv is not None or cross_cache is not None):
        if cross_cache is not None:
            kv = (cross_cache["k"], cross_cache["v"])
            new_cross = cross_cache
        else:
            k = jnp.einsum("bsd,dne->bsne", cross_kv, p["xattn"]["wk"])
            v = jnp.einsum("bsd,dne->bsne", cross_kv, p["xattn"]["wv"])
            kv = (k, v)
            new_cross = {"k": k, "v": v}
        h, _ = L.attention(p["xattn"], L.rmsnorm(p["ln_x"], x, cfg.norm_eps),
                           positions=positions, theta=cfg.rope_theta,
                           kv_override=kv)
        x = x + jnp.tanh(p["xgate"]).astype(x.dtype) * h
    x = constrain(x + L.mlp(p["mlp"], L.rmsnorm(p["ln2"], x, cfg.norm_eps)),
                  ("batch", None, None))
    return x, new_cache, new_cross


def _attend(p, cfg: ModelConfig, x, *, positions, causal, cache, cache_idx,
            window):
    """Dense attention with optional ring (windowed) cache."""
    if cache is not None and "pos" in cache:
        # ring buffer: write at idx % W
        W = cache["k"].shape[1]
        s = x.shape[1]
        slots = (cache_idx + jnp.arange(s)) % W
        q = jnp.einsum("bsd,dhe->bshe", x, p["wq"])
        if "bq" in p:
            q = q + p["bq"]
        k = jnp.einsum("bsd,dne->bsne", x, p["wk"])
        v = jnp.einsum("bsd,dne->bsne", x, p["wv"])
        if "bk" in p:
            k, v = k + p["bk"], v + p["bv"]
        q = L.apply_rope(q, positions, cfg.rope_theta)
        k = L.apply_rope(k, positions, cfg.rope_theta)
        kc = cache["k"].at[:, slots].set(k.astype(cache["k"].dtype))
        vc = cache["v"].at[:, slots].set(v.astype(cache["v"].dtype))
        pc = cache["pos"].at[slots].set(positions.astype(jnp.int32))
        out = L.mha(q, kc, vc, causal=True, q_positions=positions,
                    kv_positions=pc, window=window)
        y = jnp.einsum("bshe,hed->bsd", out.astype(x.dtype), p["wo"])
        return y, {"k": kc, "v": vc, "pos": pc}
    return L.attention(p, x, positions=positions, theta=cfg.rope_theta,
                       causal=causal, cache=cache, cache_idx=cache_idx,
                       window=window, impl=cfg.attn_impl)


# ---------------------------------------------------------------------------
# MoE (DeepSeek) block
# ---------------------------------------------------------------------------

def init_moe_block(key, cfg: ModelConfig, *, dense_ffn: bool) -> Params:
    d = cfg.d_model
    dtype = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 2)
    p = {
        "ln1": L.init_rmsnorm(d, dtype),
        "mla": MLA.init_mla(ks[0], d, cfg.num_heads, cfg.mla, dtype),
        "ln2": L.init_rmsnorm(d, dtype),
    }
    if dense_ffn:
        p["mlp"] = L.init_mlp(ks[1], d, cfg.moe.dense_d_ff, True, dtype)
    else:
        p["moe"] = MOE.init_moe(ks[1], d, cfg.moe, dtype)
    return p


def moe_block(p: Params, cfg: ModelConfig, x, *, positions, cache=None,
              cache_idx=None, capacity_factor=1.25):
    h, new_cache = MLA.mla_attention(
        p["mla"], L.rmsnorm(p["ln1"], x, cfg.norm_eps), cfg.mla,
        positions=positions, theta=cfg.rope_theta, cache=cache,
        cache_idx=cache_idx)
    x = x + h
    aux = jnp.zeros((), jnp.float32)
    h2 = L.rmsnorm(p["ln2"], x, cfg.norm_eps)
    if "moe" in p:
        y, aux = MOE.moe_ffn(p["moe"], h2, cfg.moe,
                             capacity_factor=capacity_factor)
    else:
        y = L.mlp(p["mlp"], h2)
    return constrain(x + y, ("batch", None, None)), aux, new_cache


# ---------------------------------------------------------------------------
# parameter init (per family)
# ---------------------------------------------------------------------------

def init_params(key, cfg: ModelConfig) -> Params:
    dtype = jnp.dtype(cfg.dtype)
    d, V = cfg.d_model, cfg.vocab_size
    keys = jax.random.split(key, 8)
    params: Params = {
        "embed": L.embed_init(keys[0], (V, d), dtype),
        "final_norm": L.init_rmsnorm(d, dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = L.dense_init(keys[1], (d, V), dtype)

    fam = cfg.family
    if fam in ("dense",):
        params["blocks"] = _stack_init(
            lambda k: init_dense_block(k, cfg), keys[2], cfg.num_layers)
    elif fam == "vlm":
        n_groups = cfg.num_layers // (cfg.vlm.cross_attn_every)
        per_group = cfg.vlm.cross_attn_every - 1  # 1 cross + (N-1) self
        params["groups"] = _stack_init(
            lambda k: {
                "cross": init_dense_block(jax.random.fold_in(k, 0), cfg,
                                          cross=True),
                "selfs": _stack_init(
                    lambda k2: init_dense_block(k2, cfg),
                    jax.random.fold_in(k, 1), per_group),
            }, keys[2], n_groups)
    elif fam == "moe":
        nk = cfg.moe.first_k_dense
        params["dense_blocks"] = _stack_init(
            lambda k: init_moe_block(k, cfg, dense_ffn=True), keys[2], nk)
        params["moe_blocks"] = _stack_init(
            lambda k: init_moe_block(k, cfg, dense_ffn=False), keys[3],
            cfg.num_layers - nk)
        if cfg.mtp_depth:
            params["mtp"] = {
                "proj": L.dense_init(keys[4], (2 * d, d), dtype),
                "ln": L.init_rmsnorm(d, dtype),
                "block": init_moe_block(keys[5], cfg, dense_ffn=True),
            }
    elif fam == "hybrid":
        params["blocks"] = _stack_init(
            lambda k: {"ln": L.init_rmsnorm(d, dtype),
                       "mamba": SSM.init_mamba2(k, d, cfg.ssm, dtype)},
            keys[2], cfg.num_layers)
        params["shared"] = init_dense_block(keys[3], cfg)  # ONE shared block
    elif fam == "ssm":
        blocks = []
        for i in range(cfg.num_layers):
            k = jax.random.fold_in(keys[2], i)
            if i in cfg.ssm.slstm_layers:
                blocks.append({"ln": L.init_rmsnorm(d, dtype),
                               "slstm": XL.init_slstm(k, d, dtype)})
            else:
                blocks.append({"ln": L.init_rmsnorm(d, dtype),
                               "mlstm": XL.init_mlstm(k, d, cfg.ssm, dtype)})
        params["blocks_list"] = blocks
    elif fam == "audio":
        params["encoder"] = {
            "blocks": _stack_init(lambda k: init_dense_block(k, cfg),
                                  keys[2], cfg.encdec.encoder_layers),
            "final_norm": L.init_rmsnorm(d, dtype),
        }
        params["blocks"] = _stack_init(
            lambda k: init_dense_block(k, cfg, cross=True), keys[3],
            cfg.num_layers)
    else:
        raise ValueError(f"unknown family {fam!r}")
    return params


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------

def _window_for(cfg: ModelConfig, max_len: int) -> int:
    """Sliding window for sub-quadratic archs at long context."""
    if cfg.subquadratic and cfg.family == "hybrid" and max_len > 32768:
        return 4096
    return 0


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> Params:
    dtype = jnp.dtype(cfg.dtype)
    d = cfg.d_model
    hd, nkv = cfg.resolved_head_dim, cfg.num_kv_heads
    cache: Params = {"idx": jnp.zeros((), jnp.int32)}
    fam = cfg.family

    def attn_cache(n_layers, length, ring=False):
        c = {"k": jnp.zeros((n_layers, batch, length, nkv, hd), dtype),
             "v": jnp.zeros((n_layers, batch, length, nkv, hd), dtype)}
        if ring:
            c["pos"] = jnp.full((n_layers, length), NEG_POS, jnp.int32)
        return c

    if fam == "dense":
        cache["layers"] = attn_cache(cfg.num_layers, max_len)
    elif fam == "vlm":
        every = cfg.vlm.cross_attn_every
        n_groups = cfg.num_layers // every
        cache["cross_layers"] = attn_cache(n_groups, max_len)
        cache["self_layers"] = attn_cache(n_groups * (every - 1), max_len)
        cache["cross_kv"] = {
            "k": jnp.zeros((n_groups, batch, cfg.vlm.vision_tokens, nkv, hd),
                           dtype),
            "v": jnp.zeros((n_groups, batch, cfg.vlm.vision_tokens, nkv, hd),
                           dtype)}
    elif fam == "moe":
        m = cfg.mla
        cache["layers"] = {
            "ckv": jnp.zeros((cfg.num_layers, batch, max_len, m.kv_lora_rank),
                             dtype),
            "krope": jnp.zeros(
                (cfg.num_layers, batch, max_len, m.qk_rope_head_dim), dtype)}
    elif fam == "hybrid":
        W = _window_for(cfg, max_len)
        n_attn = cfg.num_layers // cfg.ssm.attn_every
        cache["mamba"] = jax.vmap(
            lambda _: SSM.init_mamba2_state(batch, d, cfg.ssm, dtype))(
                jnp.arange(cfg.num_layers))
        cache["attn"] = attn_cache(n_attn, W or max_len, ring=bool(W))
    elif fam == "ssm":
        mstates, sstates = [], []
        for i in range(cfg.num_layers):
            if i in cfg.ssm.slstm_layers:
                sstates.append(XL.init_slstm_state(batch, d))
            else:
                mstates.append(XL.init_mlstm_state(batch, d, cfg.ssm, dtype))
        cache["mlstm"] = jax.tree.map(lambda *xs: jnp.stack(xs), *mstates)
        if sstates:
            cache["slstm"] = jax.tree.map(lambda *xs: jnp.stack(xs), *sstates)
    elif fam == "audio":
        cache["layers"] = attn_cache(cfg.num_layers, max_len)
        cache["cross_kv"] = {
            "k": jnp.zeros((cfg.num_layers, batch,
                            cfg.encdec.source_positions, nkv, hd), dtype),
            "v": jnp.zeros((cfg.num_layers, batch,
                            cfg.encdec.source_positions, nkv, hd), dtype)}
    return cache


# ---------------------------------------------------------------------------
# forward (per family)
# ---------------------------------------------------------------------------

def apply(params: Params, cfg: ModelConfig, batch: Dict[str, jax.Array], *,
          mode: str = "train", cache: Optional[Params] = None
          ) -> Tuple[jax.Array, jax.Array, Optional[Params]]:
    """Returns (logits, aux_loss, new_cache).

    batch: tokens (b, s) [+ vision_embeds / audio_frames].
    mode: "train" (no cache) | "prefill" (fills cache) | "decode".
    """
    tokens = batch["tokens"]
    b, s = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0)
    x = constrain(x, ("batch", None, None))
    cache_idx = cache["idx"] if cache is not None else None
    positions = (jnp.arange(s) if cache is None
                 else cache_idx + jnp.arange(s))
    aux = jnp.zeros((), jnp.float32)

    fam = cfg.family
    new_cache: Optional[Params] = dict(cache) if cache is not None else None

    if fam == "dense":
        x, (lc, _) = _run_dense_stack(
            params["blocks"], cfg, x, positions,
            None if cache is None else cache["layers"], cache_idx, mode)
        if new_cache is not None:
            new_cache["layers"] = lc
    elif fam == "vlm":
        x, new_cache = _run_vlm(params, cfg, batch, x, positions, cache,
                                cache_idx, mode, new_cache)
    elif fam == "moe":
        x, aux, new_cache = _run_moe(params, cfg, x, positions, cache,
                                     cache_idx, mode, new_cache)
    elif fam == "hybrid":
        x, new_cache = _run_hybrid(params, cfg, x, positions, cache,
                                   cache_idx, mode, new_cache)
    elif fam == "ssm":
        x, new_cache = _run_xlstm(params, cfg, x, cache, mode, new_cache)
    elif fam == "audio":
        x, new_cache = _run_audio(params, cfg, batch, x, positions, cache,
                                  cache_idx, mode, new_cache)

    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = _logits(params, x)
    if new_cache is not None:
        new_cache["idx"] = cache_idx + s
    return logits, aux, new_cache


def _logits(params: Params, x: jax.Array) -> jax.Array:
    if "lm_head" in params:
        return constrain(jnp.einsum("bsd,dv->bsv", x, params["lm_head"]),
                         ("batch", None, "model"))
    # tied embeddings: scale logits by 1/sqrt(d) (Gemma-style) since the
    # embedding table is unit-scale
    d = x.shape[-1]
    logits = jnp.einsum("bsd,vd->bsv", x, params["embed"]) * (d ** -0.5)
    return constrain(logits, ("batch", None, "model"))


def _run_dense_stack(stacked: Params, cfg: ModelConfig, x, positions,
                     caches, cache_idx, mode, *, causal=True,
                     cross_kv=None, cross_caches=None, window=0):
    """lax.scan over a stacked homogeneous dense-block stack."""

    def body(carry, xs):
        h = carry
        p, c, xc = xs
        h, nc, nxc = dense_block(p, cfg, h, positions=positions,
                                 causal=causal, cache=c, cache_idx=cache_idx,
                                 window=window, cross_kv=cross_kv,
                                 cross_cache=xc)
        return h, (nc, nxc)

    body = _remat(body, cfg, mode)
    x, (new_caches, new_cross) = jax.lax.scan(
        body, x, (stacked, caches, cross_caches))
    return x, (new_caches, new_cross)


def _run_vlm(params, cfg, batch, x, positions, cache, cache_idx, mode,
             new_cache):
    every = cfg.vlm.cross_attn_every
    per_group = every - 1
    vision = batch.get("vision_embeds")
    b = x.shape[0]
    if vision is None and cache is None:
        vision = jnp.zeros((b, cfg.vlm.vision_tokens, cfg.vlm.vision_dim),
                           x.dtype)

    sc = None if cache is None else cache["self_layers"]
    cc = None if cache is None else cache["cross_layers"]
    xkv = None if (cache is None or mode == "prefill") else cache["cross_kv"]

    def body(carry, xs):
        h = carry
        g, c_cross, c_selfs, c_xkv = xs
        h, ncc, nxkv = dense_block(
            g["cross"], cfg, h, positions=positions, cache=c_cross,
            cache_idx=cache_idx, cross_kv=vision, cross_cache=c_xkv)

        def inner(carry2, xs2):
            p2, c2 = xs2
            h2, nc2, _ = dense_block(p2, cfg, carry2, positions=positions,
                                     cache=c2, cache_idx=cache_idx)
            return h2, nc2

        h, nsc = jax.lax.scan(inner, h, (g["selfs"], c_selfs))
        return h, (ncc, nsc, nxkv)

    body = _remat(body, cfg, mode)
    n_groups = cfg.num_layers // every
    # reshape self caches (n_groups*per_group, ...) -> (n_groups, per_group,...)
    sc_g = (None if sc is None else
            jax.tree.map(lambda a: a.reshape((n_groups, per_group) +
                                             a.shape[1:]), sc))
    x, (ncc, nsc, nxkv) = jax.lax.scan(body, x, (params["groups"], cc, sc_g,
                                                 xkv))
    if new_cache is not None:
        new_cache["cross_layers"] = ncc
        new_cache["self_layers"] = jax.tree.map(
            lambda a: a.reshape((n_groups * per_group,) + a.shape[2:]), nsc)
        if mode == "prefill":
            new_cache["cross_kv"] = nxkv
    return x, new_cache


def _run_moe(params, cfg, x, positions, cache, cache_idx, mode, new_cache):
    T = x.shape[0] * x.shape[1]
    cap = 2.0 if T < 4096 else 1.25
    nk = cfg.moe.first_k_dense
    aux_total = jnp.zeros((), jnp.float32)

    def mk_body(dense_ffn):
        def body(carry, xs):
            h, aux = carry
            p, c = xs
            h, a, nc = moe_block(p, cfg, h, positions=positions, cache=c,
                                 cache_idx=cache_idx, capacity_factor=cap)
            return (h, aux + a), nc
        return _remat(body, cfg, mode)

    lc = None if cache is None else cache["layers"]
    lc_d = None if lc is None else jax.tree.map(lambda a: a[:nk], lc)
    lc_m = None if lc is None else jax.tree.map(lambda a: a[nk:], lc)

    (x, aux_total), ncd = jax.lax.scan(
        mk_body(True), (x, aux_total), (params["dense_blocks"], lc_d))
    (x, aux_total), ncm = jax.lax.scan(
        mk_body(False), (x, aux_total), (params["moe_blocks"], lc_m))
    if new_cache is not None:
        new_cache["layers"] = jax.tree.map(
            lambda a, b2: jnp.concatenate([a, b2], axis=0), ncd, ncm)
    return x, aux_total, new_cache


def _run_hybrid(params, cfg, x, positions, cache, cache_idx, mode, new_cache):
    every = cfg.ssm.attn_every
    n_attn = cfg.num_layers // every
    # ring caches are allocated at exactly the window size
    W = cache["attn"]["k"].shape[2] if (
        cache is not None and "pos" in cache["attn"]) else 0

    mc = None if cache is None else cache["mamba"]
    ac = None if cache is None else cache["attn"]

    def mamba_body(carry, xs):
        h = carry
        p, st = xs
        y, nst = SSM.mamba2_forward(
            p["mamba"], L.rmsnorm(p["ln"], h, cfg.norm_eps), cfg.ssm,
            init_state=st, return_state=st is not None)
        return h + y, nst

    mamba_body = _remat(mamba_body, cfg, mode)

    # scan groups of `every` mamba layers, then the weight-shared attn block
    n_groups = cfg.num_layers // every
    rem = cfg.num_layers - n_groups * every

    def group_body(carry, xs):
        h = carry
        g_params, g_state, a_cache = xs
        h, n_states = jax.lax.scan(mamba_body, h, (g_params, g_state))
        h, na, _ = dense_block(params["shared"], cfg, h, positions=positions,
                               cache=a_cache, cache_idx=cache_idx, window=W)
        return h, (n_states, na)

    group_body = _remat(group_body, cfg, mode)

    def split_groups(tree, n, size):
        return jax.tree.map(
            lambda a: a[: n * size].reshape((n, size) + a.shape[1:]), tree)

    gp = split_groups(params["blocks"], n_groups, every)
    gs = None if mc is None else split_groups(mc, n_groups, every)
    x, (nms, nac) = jax.lax.scan(group_body, x, (gp, gs, ac))

    nmc_tail = None
    if rem:
        tail_p = jax.tree.map(lambda a: a[n_groups * every:], params["blocks"])
        tail_s = None if mc is None else jax.tree.map(
            lambda a: a[n_groups * every:], mc)
        x, nmc_tail = jax.lax.scan(mamba_body, x, (tail_p, tail_s))

    if new_cache is not None:
        flat = jax.tree.map(
            lambda a: a.reshape((n_groups * every,) + a.shape[2:]), nms)
        if rem:
            flat = jax.tree.map(lambda a, t: jnp.concatenate([a, t], 0),
                                flat, nmc_tail)
        new_cache["mamba"] = flat
        new_cache["attn"] = nac
    return x, new_cache


def _run_xlstm(params, cfg, x, cache, mode, new_cache):
    mi, si = 0, 0
    nm_states, ns_states = [], []
    for i, p in enumerate(params["blocks_list"]):
        h = L.rmsnorm(p["ln"], x, cfg.norm_eps)
        if "slstm" in p:
            st = (None if cache is None else
                  jax.tree.map(lambda a: a[si], cache["slstm"]))
            y, nst = XL.slstm_forward(p["slstm"], h, init_state=st,
                                      return_state=st is not None)
            if nst is not None:
                ns_states.append(nst)
            si += 1
        else:
            st = (None if cache is None else
                  jax.tree.map(lambda a: a[mi], cache["mlstm"]))
            y, nst = XL.mlstm_forward(p["mlstm"], h, cfg.ssm, init_state=st,
                                      return_state=st is not None)
            if nst is not None:
                nm_states.append(nst)
            mi += 1
        x = x + y
    if new_cache is not None:
        new_cache["mlstm"] = jax.tree.map(lambda *xs: jnp.stack(xs),
                                          *nm_states)
        if ns_states:
            new_cache["slstm"] = jax.tree.map(lambda *xs: jnp.stack(xs),
                                              *ns_states)
    return x, new_cache


def _run_audio(params, cfg, batch, x, positions, cache, cache_idx, mode,
               new_cache):
    frames = batch.get("audio_frames")
    b = x.shape[0]
    if frames is None and cache is None:
        frames = jnp.zeros((b, cfg.encdec.source_positions, cfg.d_model),
                           x.dtype)

    # encoder (train, or prefill when frames are given)
    memory = None
    if frames is not None:
        mem = frames
        enc_pos = jnp.arange(frames.shape[1])

        def enc_body(carry, p):
            h, _, _ = dense_block(p, cfg, carry, positions=enc_pos,
                                  causal=False)
            return h, None

        enc_body = _remat(enc_body, cfg, mode)
        mem, _ = jax.lax.scan(enc_body, mem, params["encoder"]["blocks"])
        memory = L.rmsnorm(params["encoder"]["final_norm"], mem, cfg.norm_eps)

    lc = None if cache is None else cache["layers"]
    xkv = None
    if cache is not None and mode == "decode":
        xkv = cache["cross_kv"]

    def body(carry, xs):
        h = carry
        p, c, xc = xs
        h, nc, nxkv = dense_block(p, cfg, h, positions=positions, cache=c,
                                  cache_idx=cache_idx, cross_kv=memory,
                                  cross_cache=xc)
        return h, (nc, nxkv)

    body = _remat(body, cfg, mode)
    x, (nlc, nxkv) = jax.lax.scan(body, x, (params["blocks"], lc, xkv))
    if new_cache is not None:
        new_cache["layers"] = nlc
        if mode == "prefill":
            new_cache["cross_kv"] = nxkv
    return x, new_cache


# ---------------------------------------------------------------------------
# loss
# ---------------------------------------------------------------------------

def cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Shard-friendly CE: the gold logit is extracted with a fused one-hot
    contraction instead of take_along_axis — a dynamic gather over the
    vocab dim would force GSPMD to all-gather the full logits tensor
    (hundreds of GB at train_4k shapes)."""
    logits = logits.astype(jnp.float32)
    m = jax.lax.stop_gradient(jnp.max(logits, axis=-1, keepdims=True))
    shifted = logits - m
    lse = jnp.log(jnp.sum(jnp.exp(shifted), axis=-1)) + m[..., 0]
    onehot = jax.nn.one_hot(labels, logits.shape[-1], dtype=logits.dtype)
    gold = jnp.sum(shifted * onehot, axis=-1) + m[..., 0]
    return jnp.mean(lse - gold)


def loss_fn(params: Params, cfg: ModelConfig, batch: Dict[str, jax.Array]
            ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    logits, aux, _ = apply(params, cfg, batch, mode="train")
    labels = batch["labels"]
    ce = cross_entropy(logits[:, :-1], labels[:, 1:])
    loss = ce + aux
    metrics = {"ce": ce, "aux": aux}
    if cfg.mtp_depth and "mtp" in params:
        mtp = params["mtp"]
        h = jnp.take(params["embed"], batch["tokens"][:, 1:], axis=0)
        h0 = L.rmsnorm(mtp["ln"],
                       jnp.take(params["embed"], batch["tokens"][:, :-1],
                                axis=0), cfg.norm_eps)
        h = jnp.einsum("bsd,dk->bsk", jnp.concatenate([h0, h], -1),
                       mtp["proj"])
        pos = jnp.arange(h.shape[1])
        h, _, _ = moe_block(mtp["block"], cfg, h, positions=pos)
        mtp_logits = _logits(params, h)
        mtp_ce = cross_entropy(mtp_logits[:, :-1], labels[:, 2:])
        loss = loss + 0.3 * mtp_ce
        metrics["mtp_ce"] = mtp_ce
    return loss, metrics
