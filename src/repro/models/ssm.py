"""Mamba2 (SSD) block: chunked-scan training/prefill + O(1) decode step.

Chunked scan follows the SSD formulation (Dao & Gu, 2024): the sequence is
split into chunks of ``Q`` tokens; the intra-chunk term is a masked
quadratic (attention-like) contraction, inter-chunk information flows through
a per-chunk state recurrence of shape (heads, head_dim, state).

Shapes: x (b, l, d); d_inner = expand*d; H = d_inner // P heads;
B/C projections are per-group (G groups, shared across H//G heads).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import SSMConfig
from repro.models.layers import dense_init
from repro.models.sharding import constrain

Params = Dict[str, Any]


def init_mamba2(key, d: int, s: SSMConfig, dtype) -> Params:
    di = s.expand * d
    H = di // s.head_dim
    gn = s.ngroups * s.state_size
    ks = jax.random.split(key, 8)
    return {
        "wz": dense_init(ks[0], (d, di), dtype),
        "wx": dense_init(ks[1], (d, di), dtype),
        "wB": dense_init(ks[2], (d, gn), dtype),
        "wC": dense_init(ks[3], (d, gn), dtype),
        "wdt": dense_init(ks[4], (d, H), dtype),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H)).astype(jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "conv_x": dense_init(ks[5], (s.conv_kernel, di), dtype),
        "conv_B": dense_init(ks[6], (s.conv_kernel, gn), dtype),
        "conv_C": dense_init(ks[7], (s.conv_kernel, gn), dtype),
        "w_out": dense_init(jax.random.fold_in(key, 9), (di, d), dtype,
                         scale=di ** -0.5),
    }


def _causal_conv(x: jax.Array, w: jax.Array,
                 state: Optional[jax.Array] = None,
                 state_len: Optional[int] = None
                 ) -> Tuple[jax.Array, jax.Array]:
    """Depthwise causal conv.  x (b,l,c), w (K,c).  state (b,K-1,c) carries
    the last K-1 inputs for streaming decode.  ``state_len`` = number of
    *real* (unpadded) positions; the new state is the last K-1 real inputs.
    Returns (y, new_state)."""
    K = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)                    # (b, l+K-1, c)
    y = sum(xp[:, i:i + x.shape[1], :] * w[i] for i in range(K))
    if K > 1:
        sl = x.shape[1] if state_len is None else state_len
        new_state = jax.lax.dynamic_slice_in_dim(xp, sl, K - 1, axis=1)
    else:
        new_state = state
    return jax.nn.silu(y), new_state


def _segsum(a: jax.Array) -> jax.Array:
    """a (..., q) -> (..., q, q) with out[i,j] = sum_{j<t<=i} a_t (i>=j),
    -inf above the diagonal."""
    q = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((q, q), bool))
    return jnp.where(mask, diff, -jnp.inf)


def mamba2_forward(p: Params, x: jax.Array, s: SSMConfig, *,
                   init_state: Optional[Params] = None,
                   return_state: bool = False
                   ) -> Tuple[jax.Array, Optional[Params]]:
    """Chunked scan.  x (b,l,d); l must be a multiple of chunk (padded by
    caller otherwise).  init_state/new_state: {"ssm": (b,H,P,N), "conv_*"}."""
    b, l_real, d = x.shape
    di = s.expand * d
    H, P, N, G = di // s.head_dim, s.head_dim, s.state_size, s.ngroups
    Q = min(s.chunk_size, l_real)
    # pad to a chunk multiple; padded positions are made state-neutral by
    # forcing dt=0 there (decay=1, zero contribution)
    l = -(-l_real // Q) * Q
    if l != l_real:
        x = jnp.pad(x, ((0, 0), (0, l - l_real), (0, 0)))
    nc = l // Q
    dtype = x.dtype

    z = constrain(jnp.einsum("bld,de->ble", x, p["wz"]),
                  ("batch", None, "model"))
    xc = constrain(jnp.einsum("bld,de->ble", x, p["wx"]),
                   ("batch", None, "model"))
    Bc = jnp.einsum("bld,de->ble", x, p["wB"])
    Cc = jnp.einsum("bld,de->ble", x, p["wC"])
    dt = jax.nn.softplus(
        jnp.einsum("bld,dh->blh", x, p["wdt"]).astype(jnp.float32)
        + p["dt_bias"])                                           # (b,l,H)
    if l != l_real:
        dt = dt * (jnp.arange(l) < l_real)[None, :, None]

    conv_xs = init_state["conv_x"] if init_state else None
    conv_Bs = init_state["conv_B"] if init_state else None
    conv_Cs = init_state["conv_C"] if init_state else None
    xc, ncx = _causal_conv(xc, p["conv_x"], conv_xs, state_len=l_real)
    Bc, ncB = _causal_conv(Bc, p["conv_B"], conv_Bs, state_len=l_real)
    Cc, ncC = _causal_conv(Cc, p["conv_C"], conv_Cs, state_len=l_real)

    A = -jnp.exp(p["A_log"])                                      # (H,)
    xh = xc.reshape(b, l, H, P)
    Bg = Bc.reshape(b, l, G, N)
    Cg = Cc.reshape(b, l, G, N)
    rep = H // G

    # chunked views
    xh = xh.reshape(b, nc, Q, H, P)
    Bg = Bg.reshape(b, nc, Q, G, N)
    Cg = Cg.reshape(b, nc, Q, G, N)
    dt = dt.reshape(b, nc, Q, H)
    dA = dt * A                                                   # (b,nc,Q,H)
    dtx = (dt[..., None] * xh.astype(jnp.float32))                # dt-weighted x

    # ---- intra-chunk (quadratic within chunk) ----
    L = jnp.exp(_segsum(dA.transpose(0, 1, 3, 2)))                # (b,nc,H,Q,Q)
    scores = jnp.einsum("bcqgn,bckgn->bcgqk", Cg.astype(jnp.float32),
                        Bg.astype(jnp.float32))                   # (b,nc,G,Q,Q)
    scores = jnp.repeat(scores, rep, axis=2)                      # per head
    y_intra = jnp.einsum("bchqk,bckhp->bcqhp", scores * L, dtx)

    # ---- chunk states ----
    dA_cum = jnp.cumsum(dA, axis=2)                               # (b,nc,Q,H)
    decay_to_end = jnp.exp(dA_cum[:, :, -1:, :] - dA_cum)         # (b,nc,Q,H)
    # S_c = sum_j decay_j * B_j ⊗ dtx_j  -> (b,nc,H,N,P)
    Bh = jnp.repeat(Bg, rep, axis=3).astype(jnp.float32)          # (b,nc,Q,H*? )
    S = jnp.einsum("bcqhn,bcqhp->bchnp", Bh * decay_to_end[..., None], dtx)

    # ---- inter-chunk recurrence over nc chunks ----
    chunk_decay = jnp.exp(dA_cum[:, :, -1, :])                    # (b,nc,H)
    s0 = (init_state["ssm"].astype(jnp.float32) if init_state
          else jnp.zeros((b, H, P, N), jnp.float32))

    def step(carry, inp):
        S_c, g = inp                                              # (b,H,N,P),(b,H)
        prev = carry
        new = prev * g[..., None, None] + S_c.transpose(0, 1, 3, 2)
        return new, prev                                          # emit state *before* chunk

    final_state, prev_states = jax.lax.scan(
        step, s0, (S.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)            # (b,nc,H,P,N)

    # ---- inter-chunk output ----
    in_decay = jnp.exp(dA_cum)                                    # (b,nc,Q,H)
    Ch = jnp.repeat(Cg, rep, axis=3).astype(jnp.float32)
    y_inter = jnp.einsum("bcqhn,bchpn->bcqhp", Ch * in_decay[..., None],
                         prev_states)

    y = (y_intra + y_inter).reshape(b, l, H, P)
    y = y + p["D"][:, None] * xc.reshape(b, l, H, P).astype(jnp.float32)
    y = y.reshape(b, l, di).astype(dtype)
    y = y * jax.nn.silu(z)
    out = jnp.einsum("ble,ed->bld", y, p["w_out"])
    if l != l_real:
        out = out[:, :l_real]
    if not return_state:
        return out, None
    return out, {"ssm": final_state.astype(dtype), "conv_x": ncx,
                 "conv_B": ncB, "conv_C": ncC}


def mamba2_step(p: Params, x: jax.Array, s: SSMConfig, state: Params
                ) -> Tuple[jax.Array, Params]:
    """Single-token decode.  x (b,1,d).  O(1) in context length."""
    out, new_state = mamba2_forward(
        p, x, s, init_state=state, return_state=True)
    return out, new_state


def init_mamba2_state(batch: int, d: int, s: SSMConfig, dtype) -> Params:
    di = s.expand * d
    H, P, N = di // s.head_dim, s.head_dim, s.state_size
    gn = s.ngroups * s.state_size
    K = s.conv_kernel
    return {"ssm": jnp.zeros((batch, H, P, N), dtype),
            "conv_x": jnp.zeros((batch, K - 1, di), dtype),
            "conv_B": jnp.zeros((batch, K - 1, gn), dtype),
            "conv_C": jnp.zeros((batch, K - 1, gn), dtype)}
