"""Core neural-net layers shared by all architectures.

Pure-functional JAX: parameters are nested dicts of arrays, every layer is a
``init_*`` / ``apply_*`` pair.  Einsum dimension names used throughout:
``b`` batch, ``s``/``q``/``k`` sequence, ``d`` d_model, ``h`` heads,
``n`` kv-heads, ``g`` q-heads-per-kv-group, ``e`` head_dim, ``f`` d_ff.

Numerics: matmuls run in the param dtype (bf16 on TPU), softmax / norms in
float32.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.sharding import constrain

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------

def dense_init(key, shape, dtype, scale: Optional[float] = None):
    fan_in = shape[0]
    scale = scale if scale is not None else fan_in ** -0.5
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * scale).astype(dtype)


def embed_init(key, shape, dtype):
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            ).astype(dtype)


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------

def init_rmsnorm(d: int, dtype) -> Params:
    return {"scale": jnp.ones((d,), dtype=jnp.float32)}


def rmsnorm(p: Params, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * p["scale"]).astype(dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    """(head_dim//2,) inverse frequencies."""
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., seq, heads, head_dim); positions: (..., seq)."""
    head_dim = x.shape[-1]
    inv = rope_freqs(head_dim, theta)                       # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * inv    # (..., s, hd/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    cos = cos[..., None, :]                                  # (..., s, 1, hd/2)
    sin = sin[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Dense (GQA) attention
# ---------------------------------------------------------------------------

def init_attention(key, d: int, n_heads: int, n_kv: int, head_dim: int,
                   bias: bool, dtype) -> Params:
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, n_heads, head_dim), dtype),
        "wk": dense_init(ks[1], (d, n_kv, head_dim), dtype),
        "wv": dense_init(ks[2], (d, n_kv, head_dim), dtype),
        "wo": dense_init(ks[3], (n_heads, head_dim, d), dtype,
                         scale=(n_heads * head_dim) ** -0.5),
    }
    if bias:
        p["bq"] = jnp.zeros((n_heads, head_dim), dtype)
        p["bk"] = jnp.zeros((n_kv, head_dim), dtype)
        p["bv"] = jnp.zeros((n_kv, head_dim), dtype)
    return p


def _gqa_scores(q: jax.Array, k: jax.Array) -> jax.Array:
    """q: (b, sq, h, e), k: (b, sk, n, e) -> scores (b, n, g, sq, sk)."""
    b, sq, h, e = q.shape
    n = k.shape[2]
    g = h // n
    q = q.reshape(b, sq, n, g, e)
    return jnp.einsum("bqnge,bkne->bngqk", q, k,
                      preferred_element_type=jnp.float32)


def _gqa_out(probs: jax.Array, v: jax.Array) -> jax.Array:
    """probs: (b, n, g, sq, sk), v: (b, sk, n, e) -> (b, sq, h, e)."""
    b, n, g, sq, sk = probs.shape
    out = jnp.einsum("bngqk,bkne->bqnge", probs, v)
    return out.reshape(b, sq, n * g, out.shape[-1])


def mha(q: jax.Array, k: jax.Array, v: jax.Array, *,
        causal: bool, q_positions: Optional[jax.Array] = None,
        kv_positions: Optional[jax.Array] = None,
        kv_valid_len: Optional[jax.Array] = None,
        window: int = 0,
        bias_extra: Optional[jax.Array] = None) -> jax.Array:
    """Reference multi-head GQA attention (the jnp oracle path; the Pallas
    flash kernels in repro.kernels implement the same contract).

    q (b,sq,h,e), k/v (b,sk,n,e).  ``kv_valid_len`` masks a KV cache tail.
    ``window`` > 0 enables sliding-window attention (sub-quadratic archs).
    """
    b, sq, h, e = q.shape
    sk = k.shape[1]
    scores = _gqa_scores(q, k) / jnp.sqrt(e).astype(jnp.float32)
    if bias_extra is not None:
        scores = scores + bias_extra
    mask = None
    if q_positions is None:
        q_positions = jnp.arange(sq)
    if kv_positions is None:
        kv_positions = jnp.arange(sk)
    qp = q_positions.reshape(-1, 1) if q_positions.ndim == 1 else q_positions
    kp = kv_positions.reshape(1, -1) if kv_positions.ndim == 1 else kv_positions
    if causal:
        mask = qp >= kp                                  # (sq, sk) or (b,...)
    if window > 0:
        wmask = qp - kp < window
        mask = wmask if mask is None else (mask & wmask)
    if kv_valid_len is not None:
        vmask = kv_positions.reshape(1, -1) < kv_valid_len.reshape(-1, 1)
        vmask = vmask[:, None, None, None, :]            # (b,1,1,1,sk)
        scores = jnp.where(vmask, scores, -jnp.inf)
    if mask is not None:
        while mask.ndim < 5:
            mask = mask[None]
        scores = jnp.where(mask, scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1)
    # rows that are fully masked produce NaN; zero them out
    probs = jnp.where(jnp.isnan(probs), 0.0, probs).astype(v.dtype)
    return _gqa_out(probs, v)


def mha_chunked(q: jax.Array, k: jax.Array, v: jax.Array, *,
                causal: bool, block: int = 1024) -> jax.Array:
    """Flash-pattern attention in pure XLA: lax.scan over KV blocks with an
    online softmax.  Materializes (b, n, g, sq, block) instead of the full
    (…, sq, sk) score matrix — the memory-roofline fix for long-sequence
    train/prefill (§Perf iteration 1); exact (not approximate).

    On TPU the Pallas flash kernel replaces this; the XLA form keeps the
    dry-run roofline honest and is the CPU-correct fallback."""
    b, sq, h, e = q.shape
    sk, n = k.shape[1], k.shape[2]
    g = h // n
    blk = min(block, sk)
    nb = -(-sk // blk)
    pad = nb * blk - sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    qf = q.astype(jnp.float32).reshape(b, sq, n, g, e) / jnp.sqrt(
        jnp.float32(e))
    kb = k.reshape(b, nb, blk, n, e).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(b, nb, blk, n, e).transpose(1, 0, 2, 3, 4)
    qpos = jnp.arange(sq)
    kpos = jnp.arange(nb * blk).reshape(nb, blk)

    def body(carry, inp):
        m, l, acc = carry
        kblk, vblk, kp = inp
        s = jnp.einsum("bqnge,bkne->bngqk", qf, kblk.astype(jnp.float32))
        mask = kp[None, :] < sk
        if causal:
            mask = mask & (qpos[:, None] >= kp[None, :])
        s = jnp.where(mask[None, None, None], s, -jnp.inf)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        p = jnp.where(jnp.isnan(p), 0.0, p)
        alpha = jnp.exp(m - m_new)
        alpha = jnp.where(jnp.isnan(alpha), 0.0, alpha)
        l_new = alpha * l + jnp.sum(p, axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bngqk,bkne->bngqe", p, vblk.astype(jnp.float32))
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, n, g, sq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((b, n, g, sq), jnp.float32)
    a0 = jnp.zeros((b, n, g, sq, e), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), (kb, vb, kpos))
    safe = jnp.where(l == 0.0, 1.0, l)
    out = (acc / safe[..., None]).astype(q.dtype)          # (b,n,g,sq,e)
    return out.transpose(0, 3, 1, 2, 4).reshape(b, sq, h, e)


def attention(p: Params, x: jax.Array, *, positions: jax.Array,
              theta: float, causal: bool = True,
              cache: Optional[Params] = None,
              cache_idx: Optional[jax.Array] = None,
              window: int = 0,
              kv_override: Optional[Tuple[jax.Array, jax.Array]] = None,
              impl: str = "reference",
              ) -> Tuple[jax.Array, Optional[Params]]:
    """Full attention block: qkv projection + rope + mha + output proj.

    cache: {"k": (b, S, n, e), "v": ...} updated at ``cache_idx``.
    kv_override: precomputed (k, v) for cross-attention (no rope on kv).
    """
    dtype = x.dtype
    q = constrain(jnp.einsum("bsd,dhe->bshe", x, p["wq"]),
                  ("batch", None, "model", None))
    if "bq" in p:
        q = q + p["bq"]
    if kv_override is not None:
        k, v = kv_override
        q = q.astype(dtype)
        out = mha(q, k, v, causal=False)
    else:
        k = jnp.einsum("bsd,dne->bsne", x, p["wk"])
        v = jnp.einsum("bsd,dne->bsne", x, p["wv"])
        if "bk" in p:
            k, v = k + p["bk"], v + p["bv"]
        q = apply_rope(q, positions, theta)
        k = apply_rope(k, positions, theta)
        if cache is None and impl == "chunked" and window == 0:
            out = mha_chunked(q, k, v, causal=causal)
            y = jnp.einsum("bshe,hed->bsd", out.astype(dtype), p["wo"])
            return y, None
        if cache is not None:
            # decode / chunked prefill: write new kv at cache_idx, attend to
            # the whole (valid prefix of the) cache
            S = cache["k"].shape[1]
            sq = q.shape[1]
            k_cache = jax.lax.dynamic_update_slice_in_dim(
                cache["k"], k.astype(cache["k"].dtype), cache_idx, axis=1)
            v_cache = jax.lax.dynamic_update_slice_in_dim(
                cache["v"], v.astype(cache["v"].dtype), cache_idx, axis=1)
            cache = {"k": k_cache, "v": v_cache}
            valid = (cache_idx + sq) * jnp.ones((x.shape[0],), jnp.int32)
            out = mha(q, k_cache, v_cache, causal=True,
                      q_positions=positions,
                      kv_positions=jnp.arange(S), kv_valid_len=valid,
                      window=window)
        else:
            out = mha(q, k, v, causal=causal, q_positions=positions,
                      kv_positions=positions, window=window)
    y = jnp.einsum("bshe,hed->bsd", out.astype(dtype), p["wo"])
    return y, cache


def init_cache_attention(batch: int, max_len: int, n_kv: int, head_dim: int,
                         dtype) -> Params:
    return {"k": jnp.zeros((batch, max_len, n_kv, head_dim), dtype),
            "v": jnp.zeros((batch, max_len, n_kv, head_dim), dtype)}


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def init_mlp(key, d: int, d_ff: int, gated: bool, dtype) -> Params:
    ks = jax.random.split(key, 3)
    p = {"w_up": dense_init(ks[0], (d, d_ff), dtype),
         "w_down": dense_init(ks[1], (d_ff, d), dtype)}
    if gated:
        p["w_gate"] = dense_init(ks[2], (d, d_ff), dtype)
    return p


def mlp(p: Params, x: jax.Array) -> jax.Array:
    up = jnp.einsum("bsd,df->bsf", x, p["w_up"])
    if "w_gate" in p:
        gate = jnp.einsum("bsd,df->bsf", x, p["w_gate"])
        h = jax.nn.silu(gate) * up
    else:
        h = jax.nn.gelu(up)
    h = constrain(h, ("batch", None, "model"))
    return jnp.einsum("bsf,fd->bsd", h, p["w_down"])
