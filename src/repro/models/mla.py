"""Multi-head Latent Attention (DeepSeek v2/v3).

Train/prefill use the *naive* form (materialize per-head K/V from the latent)
which is compute-optimal; decode uses the *absorbed* form (scores computed
directly against the cached latent) which is memory-optimal — exactly the KV
reduction MLA was designed for.  Cache = {ckv: (b, S, r), krope: (b, S, e_r)}.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import MLAConfig
from repro.models.layers import apply_rope, dense_init, init_rmsnorm, rmsnorm
from repro.models.sharding import constrain

Params = Dict[str, Any]


def init_mla(key, d: int, n_heads: int, m: MLAConfig, dtype) -> Params:
    ks = jax.random.split(key, 7)
    return {
        "wq_a": dense_init(ks[0], (d, m.q_lora_rank), dtype),
        "q_norm": init_rmsnorm(m.q_lora_rank, dtype),
        "wq_b": dense_init(ks[1], (m.q_lora_rank, n_heads, m.qk_head_dim), dtype),
        "wkv_a": dense_init(ks[2], (d, m.kv_lora_rank + m.qk_rope_head_dim), dtype),
        "kv_norm": init_rmsnorm(m.kv_lora_rank, dtype),
        "wk_b": dense_init(ks[3], (m.kv_lora_rank, n_heads, m.qk_nope_head_dim), dtype),
        "wv_b": dense_init(ks[4], (m.kv_lora_rank, n_heads, m.v_head_dim), dtype),
        "wo": dense_init(ks[5], (n_heads, m.v_head_dim, d), dtype,
                         scale=(n_heads * m.v_head_dim) ** -0.5),
    }


def _project_q(p: Params, x: jax.Array, m: MLAConfig, positions, theta):
    """-> q_nope (b,s,h,e_n), q_rope (b,s,h,e_r)."""
    ql = rmsnorm(p["q_norm"], jnp.einsum("bsd,dr->bsr", x, p["wq_a"]))
    q = constrain(jnp.einsum("bsr,rhe->bshe", ql, p["wq_b"]),
                  ("batch", None, "model", None))
    q_nope = q[..., : m.qk_nope_head_dim]
    q_rope = apply_rope(q[..., m.qk_nope_head_dim:], positions, theta)
    return q_nope, q_rope


def _project_kv_latent(p: Params, x: jax.Array, m: MLAConfig, positions, theta):
    """-> ckv (b,s,r), k_rope (b,s,e_r) — what gets cached."""
    kv = jnp.einsum("bsd,dr->bsr", x, p["wkv_a"])
    ckv = rmsnorm(p["kv_norm"], kv[..., : m.kv_lora_rank])
    k_rope = apply_rope(kv[..., None, m.kv_lora_rank:], positions, theta)
    return ckv, k_rope[..., 0, :]


def mla_attention(p: Params, x: jax.Array, m: MLAConfig, *,
                  positions: jax.Array, theta: float,
                  cache: Optional[Params] = None,
                  cache_idx: Optional[jax.Array] = None,
                  ) -> Tuple[jax.Array, Optional[Params]]:
    dtype = x.dtype
    b, s, d = x.shape
    scale = 1.0 / jnp.sqrt(jnp.float32(m.qk_head_dim))
    q_nope, q_rope = _project_q(p, x, m, positions, theta)
    ckv, k_rope = _project_kv_latent(p, x, m, positions, theta)

    if cache is None:
        # naive (compute-optimal) form for train / prefill
        k_nope = jnp.einsum("bsr,rhe->bshe", ckv, p["wk_b"])
        v = jnp.einsum("bsr,rhe->bshe", ckv, p["wv_b"])
        scores = (jnp.einsum("bqhe,bkhe->bhqk", q_nope, k_nope,
                             preferred_element_type=jnp.float32)
                  + jnp.einsum("bqhe,bke->bhqk", q_rope, k_rope,
                               preferred_element_type=jnp.float32)) * scale
        mask = positions.reshape(-1, 1) >= positions.reshape(1, -1)
        scores = jnp.where(mask[None, None], scores, -jnp.inf)
        probs = jax.nn.softmax(scores, axis=-1).astype(dtype)
        out = jnp.einsum("bhqk,bkhe->bqhe", probs, v)
        new_cache = None
    else:
        # absorbed (memory-optimal) form for decode: fold wk_b into q and
        # wv_b after the latent-space attention — KV reads touch only the
        # (b, S, r + e_r) latent cache.
        S = cache["ckv"].shape[1]
        ckv_c = jax.lax.dynamic_update_slice_in_dim(
            cache["ckv"], ckv.astype(cache["ckv"].dtype), cache_idx, axis=1)
        krope_c = jax.lax.dynamic_update_slice_in_dim(
            cache["krope"], k_rope.astype(cache["krope"].dtype), cache_idx, axis=1)
        new_cache = {"ckv": ckv_c, "krope": krope_c}
        q_lat = jnp.einsum("bqhe,rhe->bqhr", q_nope, p["wk_b"])
        scores = (jnp.einsum("bqhr,bkr->bhqk", q_lat, ckv_c,
                             preferred_element_type=jnp.float32)
                  + jnp.einsum("bqhe,bke->bhqk", q_rope, krope_c,
                               preferred_element_type=jnp.float32)) * scale
        valid = jnp.arange(S).reshape(1, -1) < (cache_idx + s)
        kv_pos = jnp.arange(S)
        causal = positions.reshape(-1, 1) >= kv_pos.reshape(1, -1)
        mask = causal[None, None] & valid[None, None]
        scores = jnp.where(mask, scores, -jnp.inf)
        probs = jax.nn.softmax(scores, axis=-1).astype(dtype)
        out_lat = jnp.einsum("bhqk,bkr->bqhr", probs, ckv_c)
        out = jnp.einsum("bqhr,rhe->bqhe", out_lat, p["wv_b"])
    y = jnp.einsum("bqhe,hed->bqd", out.astype(dtype), p["wo"])
    return y, new_cache


def init_cache_mla(batch: int, max_len: int, m: MLAConfig, dtype) -> Params:
    return {"ckv": jnp.zeros((batch, max_len, m.kv_lora_rank), dtype),
            "krope": jnp.zeros((batch, max_len, m.qk_rope_head_dim), dtype)}
