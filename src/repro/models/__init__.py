from repro.models.model import Model, build_model, input_specs, make_step_fn  # noqa: F401
