"""Sharding rules: params / inputs / caches -> PartitionSpec pytrees.

Scheme (GSPMD FSDP+TP, MaxText-style):
- 2D weights shard (in=data, out=model) for "up" matmuls and
  (in=model, out=data) for "down" matmuls — fully sharded params (the 671B
  model does not fit a 256-chip pod under TP-only).
- MoE experts shard E on `model` (expert parallelism) and d on `data`.
- The `pod` axis is pure DP: params replicated across pods, batch sharded
  over (pod, data).
- Dims that do not divide the mesh axis are left unsharded (GSPMD could pad,
  but even sharding keeps collectives regular), except vocab where uneven
  padding is accepted.
- long_500k (batch=1) shards decode KV caches on the *sequence* dim over
  `data` (sequence parallelism); softmax reductions over the sharded axis
  lower to collectives.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig


def _axis_size(mesh: Mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.shape else 1


# ---------------------------------------------------------------------------
# activation sharding constraints (logical-axis annotations)
# ---------------------------------------------------------------------------
# GSPMD propagation alone picks weight-stationary layouts for FSDP-sharded
# params (batch ends up replicated — hundreds of GB of activations at
# train_4k).  Launchers bind the mesh here; model code then pins activation
# layouts with ``constrain``.  A None mesh (tests, CPU examples) is a no-op.

_ACTIVATION_MESH: Optional[Mesh] = None


def set_activation_mesh(mesh: Optional[Mesh]):
    global _ACTIVATION_MESH
    _ACTIVATION_MESH = mesh


def get_activation_mesh() -> Optional[Mesh]:
    return _ACTIVATION_MESH


def constrain(x, names: Tuple[Optional[str], ...]):
    """names per dim: "batch" (pod+data), "data", "model", or None.
    Dims that do not divide their axis stay unsharded."""
    mesh = _ACTIVATION_MESH
    if mesh is None:
        return x
    spec = []
    for dim, name in zip(x.shape, names):
        if name is None:
            spec.append(None)
        elif name == "batch":
            axes = batch_axes(mesh)
            total = int(np.prod([_axis_size(mesh, a) for a in axes]) or 1)
            spec.append(axes if (axes and dim % total == 0) else None)
        else:
            spec.append(name if dim % _axis_size(mesh, name) == 0 else None)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*spec)))


def _div(n: int, mesh: Mesh, axis: str) -> Optional[str]:
    return axis if n % max(_axis_size(mesh, axis), 1) == 0 else None


def batch_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def _batch_spec_dim(mesh: Mesh, b: int):
    axes = batch_axes(mesh)
    total = int(np.prod([_axis_size(mesh, a) for a in axes])) if axes else 1
    if axes and b % total == 0:
        return axes
    # fall back to data-only, then replicated
    if "data" in mesh.shape and b % _axis_size(mesh, "data") == 0:
        return ("data",)
    return None


# ---------------------------------------------------------------------------
# parameter shardings
# ---------------------------------------------------------------------------

# leaf-name -> (rule ndim, spec builder).  Extra *leading* dims (stacked
# layers / groups) are padded with None.
def _param_rule(name: str, shape: Tuple[int, ...], mesh: Mesh,
                is_expert: bool = False):
    D, M = "data", "model"

    def spec(*dims):
        return P(*dims)

    if name in ("scale", "bias", "dt_bias", "gate_bias", "A_log", "D",
                "xgate"):
        return P(), 0
    if name == "embed":
        return spec(_div(shape[-2], mesh, M), _div(shape[-1], mesh, D)), 2
    if name == "lm_head":
        return spec(_div(shape[-2], mesh, D), _div(shape[-1], mesh, M)), 2
    if name in ("wq", "wk", "wv") and len(shape) >= 3:   # attn (d, h, e)
        return spec(_div(shape[-3], mesh, D), _div(shape[-2], mesh, M),
                    None), 3
    if name in ("wq", "wk", "wv"):                       # mLSTM (d, di)
        return spec(_div(shape[-2], mesh, D), _div(shape[-1], mesh, M)), 2
    if name == "wo":              # attention out-proj (h, e, d)
        return spec(_div(shape[-3], mesh, M), None,
                    _div(shape[-1], mesh, D)), 3
    if name == "w_out":           # ssm/xlstm down-proj (di, d)
        return spec(_div(shape[-2], mesh, M), _div(shape[-1], mesh, D)), 2
    if name in ("bq", "bk", "bv"):
        return spec(_div(shape[-2], mesh, M), None), 2
    # routed-expert weights are identified by PATH (under 'moe', not
    # 'shared') — a stacked dense MLP (L, d, ff) is also rank-3, and
    # treating it as (E, d, ff) leaves ff unsharded (16x replication)
    if name in ("w_up", "w_gate") and is_expert:        # experts (E, d, ff)
        return spec(_div(shape[-3], mesh, M), _div(shape[-2], mesh, D),
                    None), 3
    if name == "w_down" and is_expert:                  # experts (E, ff, d)
        return spec(_div(shape[-3], mesh, M), None,
                    _div(shape[-1], mesh, D)), 3
    if name in ("w_up", "w_gate"):
        return spec(_div(shape[-2], mesh, D), _div(shape[-1], mesh, M)), 2
    if name == "w_down":
        return spec(_div(shape[-2], mesh, M), _div(shape[-1], mesh, D)), 2
    if name == "router":
        return P(), 0
    if name in ("wq_a", "wkv_a"):
        return spec(_div(shape[-2], mesh, D), None), 2
    if name in ("wq_b", "wk_b", "wv_b"):
        return spec(None, _div(shape[-2], mesh, M), None), 3
    if name in ("wz", "wx", "W", "R", "proj"):
        return spec(_div(shape[-2], mesh, D), _div(shape[-1], mesh, M)), 2
    if name in ("wB", "wC", "wgate", "wdt"):
        return spec(_div(shape[-2], mesh, D), _div(shape[-1], mesh, M)), 2
    if name in ("conv", "conv_x", "conv_B", "conv_C"):
        return spec(None, _div(shape[-1], mesh, M)), 2
    return P(), 0


def param_shardings(params_shape: Any, mesh: Mesh) -> Any:
    """params_shape: pytree of ShapeDtypeStructs (from jax.eval_shape)."""

    def one(path, leaf):
        name = None
        keys = [str(e.key) for e in path
                if isinstance(e, jax.tree_util.DictKey)]
        name = keys[-1] if keys else ""
        is_expert = "moe" in keys and "shared" not in keys
        shape = leaf.shape
        spec, rule_nd = _param_rule(name or "", shape, mesh, is_expert)
        pad = len(shape) - len(spec)
        if pad > 0:
            spec = P(*([None] * pad), *spec)
        elif pad < 0:  # rule wider than leaf (e.g. scalar xgate)
            spec = P()
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(one, params_shape)


# ---------------------------------------------------------------------------
# input / cache shardings
# ---------------------------------------------------------------------------

def _cache_rule(name: str, shape: Tuple[int, ...], mesh: Mesh, batch: int):
    """Caches carry a leading stacked-layer dim: (L, B, S, ...).

    KV caches dominate decode memory, so the sequence dim shards over
    ``model`` (heads rarely divide the axis: GQA kv=8/20, MLA has no head
    dim in its latent cache) — attention's softmax reduction over the
    sharded S lowers to collectives.  batch=1 (long_500k) additionally
    shards S over ``data`` (sequence parallelism)."""
    bspec = _batch_spec_dim(mesh, batch)
    seq_shard = bspec is None  # batch=1 -> sequence parallelism on the cache
    M, D = "model", "data"

    def seq_spec(s):
        axes = []
        if seq_shard:
            axes.append(D)
        axes.append(M)
        total = int(np.prod([_axis_size(mesh, a) for a in axes]))
        return tuple(axes) if s % max(total, 1) == 0 else None

    if name in ("k", "v"):        # (L, B, S, n, e)
        return P(None, bspec, seq_spec(shape[-3]), None, None)
    if name == "ckv":             # (L, B, S, r)
        return P(None, bspec, seq_spec(shape[-2]), None)
    if name == "krope":
        return P(None, bspec, seq_spec(shape[-2]), None)
    if name == "pos":             # (L, W) ring positions
        return P(*([None] * len(shape)))
    if name == "ssm":             # (L, B, H, P, N)
        return P(None, bspec, _div(shape[-3], mesh, M), None, None)
    if name in ("conv_x", "conv_B", "conv_C", "conv"):  # (L, B, K-1, c)
        return P(None, bspec, None, _div(shape[-1], mesh, M))
    if name == "C":               # mlstm (L, B, H, P, P)
        return P(None, bspec, _div(shape[-3], mesh, M), None, None)
    if name in ("n",):            # (L, B, H, P)
        return P(None, bspec, _div(shape[-2], mesh, M), None)
    if name in ("m",):            # (L, B, H)
        return P(None, bspec, _div(shape[-1], mesh, M))
    if name in ("c", "h"):        # slstm (L, B, d)
        return P(None, bspec, None)
    if name == "idx":
        return P()
    return P(*([None] * len(shape)))


def cache_shardings(cache_shape: Any, cfg: ModelConfig, mesh: Mesh,
                    batch: int) -> Any:
    def one(path, leaf):
        name = ""
        for entry in reversed(path):
            if isinstance(entry, jax.tree_util.DictKey):
                name = str(entry.key)
                break
        spec = _cache_rule(name, leaf.shape, mesh, batch)
        if len(spec) != len(leaf.shape):
            # slstm m vs mlstm m etc. — fall back by rank
            spec = P(*list(spec)[: len(leaf.shape)]) if len(spec) > len(
                leaf.shape) else P(*list(spec) + [None] * (
                    len(leaf.shape) - len(spec)))
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(one, cache_shape)


def batch_shardings(batch_shape: Any, mesh: Mesh) -> Any:
    def one(leaf):
        b = leaf.shape[0]
        spec = [_batch_spec_dim(mesh, b)] + [None] * (len(leaf.shape) - 1)
        return NamedSharding(mesh, P(*spec))

    return jax.tree.map(one, batch_shape)


def input_shardings(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                    specs: Dict[str, Any]) -> Dict[str, Any]:
    """Shardings matching models.input_specs(cfg, shape) structure."""
    out: Dict[str, Any] = {}
    if "batch" in specs:
        out["batch"] = batch_shardings(specs["batch"], mesh)
    if "tokens" in specs:
        out["tokens"] = batch_shardings({"t": specs["tokens"]}, mesh)["t"]
    if "cache" in specs:
        out["cache"] = cache_shardings(specs["cache"], cfg, mesh,
                                       shape.global_batch)
    return out
