"""Executable agents for the real (JAX) pipeline: query rewriter, search
planner, context refiner, chat — thin generation loops over the model zoo.

These run the tiny reduced configs in tests/examples (the full-size stage
models are exercised through the dry-run); semantics match the simulator's
workflow builders so the two paths stay in lockstep.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import Model, build_model
from repro.rag.tokenizer import EOS


@dataclass
class GenResult:
    token_ids: List[int]
    steps: int


class LMAgent:
    """Greedy decoding agent with prefill + stepwise decode (KV cache)."""

    def __init__(self, cfg: ModelConfig, params, max_len: int = 512):
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        self.model: Model = build_model(cfg)
        self._decode = jax.jit(self.model.decode_step)

    def generate(self, prompt_ids: Sequence[int], max_new: int = 32,
                 stop_at_eos: bool = True) -> GenResult:
        prompt = jnp.asarray([list(prompt_ids)], jnp.int32)
        cache = self.model.init_cache(1, self.max_len)
        logits, cache = self.model.prefill(self.params,
                                           {"tokens": prompt}, cache)
        tok = int(jnp.argmax(logits[0, -1]))
        out = [tok]
        for _ in range(max_new - 1):
            if stop_at_eos and tok == EOS:
                break
            logits, cache = self._decode(
                self.params, jnp.asarray([[tok]], jnp.int32), cache)
            tok = int(jnp.argmax(logits[0]))
            out.append(tok)
        return GenResult(out, len(out))

    def generate_batch(self, prompts: Sequence[Sequence[int]],
                       max_new: int = 32) -> List[GenResult]:
        """One batched prefill + stepwise decode over ``len(prompts)``
        concurrent streams — the continuous-batching serving path: a fused
        decode dispatch runs a single width-B JAX call per token step
        instead of B sequential single-stream loops.  The model applies no
        padding mask, so ragged prompts are LEFT-CROPPED to the shortest
        length (keeping each stream's most recent context) rather than
        padded — pad tokens would leak into attention at real positions."""
        B = len(prompts)
        if B == 1:
            return [self.generate(prompts[0], max_new, stop_at_eos=False)]
        assert all(len(p) > 0 for p in prompts), "empty prompt in batch"
        width = min(len(p) for p in prompts)
        cropped = [list(p)[-width:] for p in prompts]
        tokens = jnp.asarray(cropped, jnp.int32)
        cache = self.model.init_cache(B, self.max_len)
        logits, cache = self.model.prefill(self.params,
                                           {"tokens": tokens}, cache)
        tok = jnp.argmax(logits[:, -1], axis=-1)
        outs = [[int(t)] for t in np.asarray(tok)]
        for _ in range(max_new - 1):
            logits, cache = self._decode(
                self.params, tok[:, None].astype(jnp.int32), cache)
            tok = jnp.argmax(logits, axis=-1)
            for seq, t in zip(outs, np.asarray(tok)):
                seq.append(int(t))
        return [GenResult(seq, len(seq)) for seq in outs]


class QueryRewriter(LMAgent):
    """Emits n sub-queries; token groups release downstream retrieval early
    (the real-pipeline analogue of the workflow expander)."""

    def rewrite(self, query_ids: Sequence[int], n_subqueries: int,
                tokens_each: int = 12) -> List[List[int]]:
        g = self.generate(query_ids, max_new=n_subqueries * tokens_each,
                          stop_at_eos=False)
        toks = g.token_ids
        return [toks[i * tokens_each:(i + 1) * tokens_each]
                for i in range(n_subqueries)]


class SearchPlanner(LMAgent):
    def plan(self, query_ids: Sequence[int], n_requests: int
             ) -> List[List[int]]:
        g = self.generate(query_ids, max_new=n_requests * 8,
                          stop_at_eos=False)
        return [g.token_ids[i * 8:(i + 1) * 8] for i in range(n_requests)]


class ContextRefiner(LMAgent):
    def refine(self, context_ids: Sequence[int], budget: int
               ) -> List[int]:
        g = self.generate(list(context_ids)[:self.max_len - budget - 1],
                          max_new=budget, stop_at_eos=False)
        return g.token_ids
