"""Synthetic dataset trace generators matching the paper's four benchmarks
(§6.1): FinqaBench and TruthfulQA (short queries ≤70 tokens, ~200-token
contexts) vs HotpotQA and 2WikiMultihopQA (longer, multi-hop contexts up to
1k tokens, more agent branching).  Extreme-length outliers are excluded, as
in the paper.

A trace drives one workflow execution: workload sizes per stage + the agent
decisions (how many sub-queries the rewriter emits, whether the planner
fires web searches) — the *dynamic dependencies* of §3.1.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Dict, List

import numpy as np


@dataclass(frozen=True)
class QueryTrace:
    dataset: str
    query_tokens: int
    context_tokens: int          # retrieved context budget for the chat stage
    n_docs: int                  # documents to index (workflow 1 ingest)
    n_chunks: int                # chunks produced by the chunker
    rerank_candidates: int
    # agent decisions (dynamic):
    n_subqueries: int            # rewriter output (W2/W3)
    rewrite_tokens: int          # rewriter decode length
    n_web_searches: int          # planner output (W3)
    plan_tokens: int             # planner decode length
    refine_tokens: int           # refiner decode length
    answer_tokens: int           # chat decode length
    # identities of the retrieved chunks, in rank order — the content keys
    # the paged-KV prefix cache hashes per page boundary.  Empty (the
    # default, and what sample_traces emits) = no prefix identity: every
    # prefill is unique, exactly the pre-paging behavior
    chunk_ids: tuple = ()


@dataclass(frozen=True)
class DatasetSpec:
    name: str
    query_tok: tuple             # (lo, hi)
    ctx_tok: tuple
    doc_tok: tuple               # per-document length
    n_docs: tuple
    subq: tuple                  # rewriter branching
    web: tuple                   # planner branching
    answer_tok: tuple


DATASETS: Dict[str, DatasetSpec] = {
    "finqabench": DatasetSpec("finqabench", (16, 70), (120, 240),
                              (300, 900), (2, 5), (1, 3), (1, 2), (24, 72)),
    "truthfulqa": DatasetSpec("truthfulqa", (10, 48), (100, 220),
                              (200, 600), (1, 4), (1, 3), (1, 2), (16, 56)),
    "hotpotqa": DatasetSpec("hotpotqa", (18, 90), (400, 1000),
                            (500, 1600), (4, 10), (2, 4), (1, 3), (32, 96)),
    "2wikimqa": DatasetSpec("2wikimqa", (16, 80), (400, 1000),
                            (500, 1800), (4, 10), (2, 5), (2, 4), (32, 96)),
}


def sample_traces(dataset: str, n: int, seed: int = 0,
                  chunk_size: int = 128, overlap: int = 10
                  ) -> List[QueryTrace]:
    spec = DATASETS[dataset]
    rng = np.random.default_rng(seed)

    def u(lohi):
        return int(rng.integers(lohi[0], lohi[1] + 1))

    out = []
    for _ in range(n):
        n_docs = u(spec.n_docs)
        doc_tokens = [u(spec.doc_tok) for _ in range(n_docs)]
        step = chunk_size - overlap
        n_chunks = sum(max(1, -(-max(t - overlap, 1) // step))
                       for t in doc_tokens)
        out.append(QueryTrace(
            dataset=dataset,
            query_tokens=u(spec.query_tok),
            context_tokens=u(spec.ctx_tok),
            n_docs=n_docs,
            n_chunks=n_chunks,
            rerank_candidates=min(max(8, n_chunks // 2), 32),
            n_subqueries=u(spec.subq),
            rewrite_tokens=u((16, 48)),
            n_web_searches=u(spec.web),
            plan_tokens=u((16, 40)),
            refine_tokens=u((24, 64)),
            answer_tokens=u(spec.answer_tok),
        ))
    return out


# --- real-text corpus for the executable pipeline --------------------------

_WORDS = ("market growth revenue quarter fiscal policy model system data "
          "retrieval neural mobile device latency memory bandwidth processor "
          "energy thermal schedule graph agent query document answer context "
          "index vector embedding rank search web page result fact entity "
          "relation hop reasoning finance question report analysis").split()


def synth_documents(n_docs: int, tokens_per_doc: int, seed: int = 0
                    ) -> List[str]:
    rng = np.random.default_rng(seed)
    docs = []
    for _ in range(n_docs):
        words = rng.choice(_WORDS, size=tokens_per_doc)
        docs.append(" ".join(words.tolist()))
    return docs


def synth_query(seed: int = 0, tokens: int = 24) -> str:
    rng = np.random.default_rng(seed + 10_007)
    return " ".join(rng.choice(_WORDS, size=tokens).tolist())
