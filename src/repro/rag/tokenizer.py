"""Deterministic hash tokenizer — a self-contained stand-in for the models'
BPE vocabularies (no external assets in this container).

Word-level with stable hashing into the configured vocab; reserves ids for
special tokens.  Round-trip fidelity is not needed by any experiment (RAG
quality is not the evaluated metric — latency is); what matters is stable,
length-preserving tokenization so workload sizes are realistic.
"""
from __future__ import annotations

import hashlib
import re
from typing import List

PAD, BOS, EOS, SEP = 0, 1, 2, 3
_SPECIALS = 4
_WORD_RE = re.compile(r"\w+|[^\w\s]")


class HashTokenizer:
    def __init__(self, vocab_size: int = 32000):
        assert vocab_size > _SPECIALS
        self.vocab_size = vocab_size

    def _tok(self, w: str) -> int:
        h = int.from_bytes(hashlib.blake2s(w.encode(), digest_size=4).digest(),
                           "little")
        return _SPECIALS + h % (self.vocab_size - _SPECIALS)

    def encode(self, text: str, *, bos: bool = False,
               eos: bool = False) -> List[int]:
        ids = [self._tok(w) for w in _WORD_RE.findall(text)]
        if bos:
            ids = [BOS] + ids
        if eos:
            ids = ids + [EOS]
        return ids

    def count(self, text: str) -> int:
        return len(_WORD_RE.findall(text))
