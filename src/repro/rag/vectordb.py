"""In-memory vector database (the FAISS role) — exact inner-product search
backed by the fused ``topk_retrieval`` Pallas kernel (jnp reference on CPU).

Supports incremental adds (chunk-indexing sub-stages append batches — the
partitioner's unit of work) and sharded corpora: at pod scale the corpus is
sharded row-wise across the ``data`` mesh axis; exact search is a sharded
matmul + per-shard top-k + global merge, expressed with pjit-compatible ops.
"""
from __future__ import annotations

from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops


class VectorDB:
    def __init__(self, dim: int, capacity: int = 65536,
                 dtype=jnp.float32):
        self.dim = dim
        self.capacity = capacity
        self._vecs = jnp.zeros((capacity, dim), dtype)
        self._n = 0
        self._ids: List[int] = []

    def __len__(self) -> int:
        return self._n

    def add(self, vectors: jax.Array, ids: Optional[List[int]] = None):
        """vectors (m, dim), L2-normalized by caller for cosine metric."""
        m = vectors.shape[0]
        if self._n + m > self.capacity:
            raise RuntimeError("vector db capacity exceeded")
        self._vecs = jax.lax.dynamic_update_slice_in_dim(
            self._vecs, vectors.astype(self._vecs.dtype), self._n, axis=0)
        self._ids.extend(ids if ids is not None
                         else range(self._n, self._n + m))
        self._n += m

    def search(self, queries: jax.Array, k: int,
               use_pallas: Optional[bool] = None
               ) -> Tuple[np.ndarray, np.ndarray]:
        """queries (q, dim) -> (scores (q,k), ids (q,k)).  Exact IP search
        over the valid prefix; empty slots are masked by construction
        (zero vectors score 0; callers use normalized embeddings)."""
        if self._n == 0:
            raise RuntimeError("search on empty db")
        k = min(k, self._n)
        # over-fetch to survive masking of lane-padding slots
        kk = min(self._round_n(), k + (self._round_n() - self._n))
        vals, idxs = ops.topk_retrieval(queries, self._vecs[: self._round_n()],
                                        kk, use_pallas=use_pallas)
        vals, idxs = np.asarray(vals).copy(), np.asarray(idxs)
        vals[idxs >= self._n] = -np.inf          # mask padding slots
        order = np.argsort(-vals, axis=1)[:, :k]
        vals = np.take_along_axis(vals, order, axis=1)
        idxs = np.take_along_axis(idxs, order, axis=1)
        ids = np.asarray(self._ids)
        return vals, ids[np.clip(idxs, 0, self._n - 1)]

    def _round_n(self) -> int:
        # keep the scanned prefix lane-aligned for the kernel
        return min(self.capacity, -(-self._n // 128) * 128)
