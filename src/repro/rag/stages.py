"""Stage catalogs: map a model family (configs.get_family) to the perf-model
StageModel dict + the role map used by baseline static mappings."""
from __future__ import annotations

from typing import Dict

from repro.configs import ModelConfig
from repro.core.perf_model import StageModel


def build_stages(family: Dict[str, ModelConfig]) -> Dict[str, StageModel]:
    e, r = family["embed"], family["rerank"]
    s, c = family["search"], family["chat"]
    return {
        "embed": StageModel("embed", e.param_count(), e.d_model,
                            "batchable", item_tokens=128),
        "rerank": StageModel("rerank", r.param_count(), r.d_model,
                             "batchable", item_tokens=160),
        "vsearch": StageModel("vsearch", 0, e.d_model, "search"),
        "rewrite_prefill": StageModel("rewrite_prefill", s.param_count(),
                                      s.d_model, "stream_prefill"),
        "rewrite_decode": StageModel("rewrite_decode", s.param_count(),
                                     s.d_model, "stream_decode"),
        "plan_prefill": StageModel("plan_prefill", s.param_count(),
                                   s.d_model, "stream_prefill"),
        "plan_decode": StageModel("plan_decode", s.param_count(),
                                  s.d_model, "stream_decode"),
        "refine_prefill": StageModel("refine_prefill", c.param_count(),
                                     c.d_model, "stream_prefill"),
        "refine_decode": StageModel("refine_decode", c.param_count(),
                                    c.d_model, "stream_decode"),
        "chat_prefill": StageModel("chat_prefill", c.param_count(),
                                   c.d_model, "stream_prefill"),
        "chat_decode": StageModel("chat_decode", c.param_count(),
                                  c.d_model, "stream_decode"),
        "web": StageModel("web", 0, 0, "io"),
    }


STAGE_ROLES: Dict[str, str] = {
    "embed": "embed", "rerank": "rerank", "vsearch": "search",
    "rewrite_prefill": "search_llm", "rewrite_decode": "search_llm",
    "plan_prefill": "search_llm", "plan_decode": "search_llm",
    "refine_prefill": "chat", "refine_decode": "chat",
    "chat_prefill": "chat", "chat_decode": "chat", "web": "io",
}
