"""Stage catalogs: map a model family (configs.get_family) to the perf-model
StageModel dict + the role map used by baseline static mappings."""
from __future__ import annotations

from typing import Dict, Optional

from repro.configs import ModelConfig
from repro.configs.qwen1p5_0p5b import CONFIG as _QWEN1P5_0P5B
from repro.core.perf_model import StageModel
from repro.core.spec_decode import DEFAULT_DRAFT_MODEL, draft_stage_of

# registry of in-tree draft-model configs SessionOptions.draft_model
# validates against (small enough to propose tokens the target verifies
# in one sweep; the only sub-1B config shipped today)
DRAFT_MODELS: Dict[str, ModelConfig] = {
    "qwen1p5_0p5b": _QWEN1P5_0P5B,
}


def _kv_bytes_token(cfg: ModelConfig, bytes_per_param: float = 1.0) -> float:
    """K+V cache bytes per context token (GQA): 2 · layers · kv_heads ·
    head_dim · bytes — what KV-residency tracking and the migration-cost
    model charge per resident token."""
    return (2.0 * cfg.num_layers * cfg.num_kv_heads * cfg.resolved_head_dim
            * bytes_per_param)


def kv_page_bytes(cfg: ModelConfig, page_tokens: int = 64,
                  bytes_per_param: float = 1.0) -> float:
    """Bytes of one paged-KV page for this model's cache shape — the unit
    the tiered page store allocates, demotes and fetches in
    (``SchedulerConfig.kv_page_tokens`` × the GQA bytes/token above)."""
    return page_tokens * _kv_bytes_token(cfg, bytes_per_param)


def build_stages(family: Dict[str, ModelConfig],
                 draft_model: Optional[str] = DEFAULT_DRAFT_MODEL
                 ) -> Dict[str, StageModel]:
    e, r = family["embed"], family["rerank"]
    s, c = family["search"], family["chat"]
    kv_s, kv_c = _kv_bytes_token(s), _kv_bytes_token(c)
    stages = {
        "embed": StageModel("embed", e.param_count(), e.d_model,
                            "batchable", item_tokens=128),
        "rerank": StageModel("rerank", r.param_count(), r.d_model,
                             "batchable", item_tokens=160),
        "vsearch": StageModel("vsearch", 0, e.d_model, "search"),
        "rewrite_prefill": StageModel("rewrite_prefill", s.param_count(),
                                      s.d_model, "stream_prefill"),
        "rewrite_decode": StageModel("rewrite_decode", s.param_count(),
                                     s.d_model, "stream_decode",
                                     kv_bytes_token=kv_s),
        "plan_prefill": StageModel("plan_prefill", s.param_count(),
                                   s.d_model, "stream_prefill"),
        "plan_decode": StageModel("plan_decode", s.param_count(),
                                  s.d_model, "stream_decode",
                                  kv_bytes_token=kv_s),
        "refine_prefill": StageModel("refine_prefill", c.param_count(),
                                     c.d_model, "stream_prefill"),
        "refine_decode": StageModel("refine_decode", c.param_count(),
                                    c.d_model, "stream_decode",
                                    kv_bytes_token=kv_c),
        "chat_prefill": StageModel("chat_prefill", c.param_count(),
                                   c.d_model, "stream_prefill"),
        "chat_decode": StageModel("chat_decode", c.param_count(),
                                  c.d_model, "stream_decode",
                                  kv_bytes_token=kv_c),
        "web": StageModel("web", 0, 0, "io"),
    }
    # draft companions LAST: one small-model stream_decode stage per
    # verify (``*_decode``) stage, named by the spec_decode convention.
    # Appending after every existing entry keeps the perf-model fit's rng
    # stream byte-identical for the pre-spec stages (fit iterates in
    # insertion order), so spec_decode=False sessions stay bit-exact.
    if draft_model is not None:
        d = DRAFT_MODELS[draft_model]
        kv_d = _kv_bytes_token(d)
        for vname in [n for n, st in stages.items()
                      if st.kind == "stream_decode"]:
            dname = draft_stage_of(vname)
            stages[dname] = StageModel(dname, d.param_count(), d.d_model,
                                       "stream_decode", kv_bytes_token=kv_d)
    return stages


STAGE_ROLES: Dict[str, str] = {
    "embed": "embed", "rerank": "rerank", "vsearch": "search",
    "rewrite_prefill": "search_llm", "rewrite_decode": "search_llm",
    "plan_prefill": "search_llm", "plan_decode": "search_llm",
    "refine_prefill": "chat", "refine_decode": "chat",
    "chat_prefill": "chat", "chat_decode": "chat", "web": "io",
    # draft companions inherit their verify stage's role (static
    # strategies place them alongside the target they propose for)
    "rewrite_draft": "search_llm", "plan_draft": "search_llm",
    "refine_draft": "chat", "chat_draft": "chat",
}
