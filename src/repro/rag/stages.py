"""Stage catalogs: map a model family (configs.get_family) to the perf-model
StageModel dict + the role map used by baseline static mappings."""
from __future__ import annotations

from typing import Dict

from repro.configs import ModelConfig
from repro.core.perf_model import StageModel


def _kv_bytes_token(cfg: ModelConfig, bytes_per_param: float = 1.0) -> float:
    """K+V cache bytes per context token (GQA): 2 · layers · kv_heads ·
    head_dim · bytes — what KV-residency tracking and the migration-cost
    model charge per resident token."""
    return (2.0 * cfg.num_layers * cfg.num_kv_heads * cfg.resolved_head_dim
            * bytes_per_param)


def kv_page_bytes(cfg: ModelConfig, page_tokens: int = 64,
                  bytes_per_param: float = 1.0) -> float:
    """Bytes of one paged-KV page for this model's cache shape — the unit
    the tiered page store allocates, demotes and fetches in
    (``SchedulerConfig.kv_page_tokens`` × the GQA bytes/token above)."""
    return page_tokens * _kv_bytes_token(cfg, bytes_per_param)


def build_stages(family: Dict[str, ModelConfig]) -> Dict[str, StageModel]:
    e, r = family["embed"], family["rerank"]
    s, c = family["search"], family["chat"]
    kv_s, kv_c = _kv_bytes_token(s), _kv_bytes_token(c)
    return {
        "embed": StageModel("embed", e.param_count(), e.d_model,
                            "batchable", item_tokens=128),
        "rerank": StageModel("rerank", r.param_count(), r.d_model,
                             "batchable", item_tokens=160),
        "vsearch": StageModel("vsearch", 0, e.d_model, "search"),
        "rewrite_prefill": StageModel("rewrite_prefill", s.param_count(),
                                      s.d_model, "stream_prefill"),
        "rewrite_decode": StageModel("rewrite_decode", s.param_count(),
                                     s.d_model, "stream_decode",
                                     kv_bytes_token=kv_s),
        "plan_prefill": StageModel("plan_prefill", s.param_count(),
                                   s.d_model, "stream_prefill"),
        "plan_decode": StageModel("plan_decode", s.param_count(),
                                  s.d_model, "stream_decode",
                                  kv_bytes_token=kv_s),
        "refine_prefill": StageModel("refine_prefill", c.param_count(),
                                     c.d_model, "stream_prefill"),
        "refine_decode": StageModel("refine_decode", c.param_count(),
                                    c.d_model, "stream_decode",
                                    kv_bytes_token=kv_c),
        "chat_prefill": StageModel("chat_prefill", c.param_count(),
                                   c.d_model, "stream_prefill"),
        "chat_decode": StageModel("chat_decode", c.param_count(),
                                  c.d_model, "stream_decode",
                                  kv_bytes_token=kv_c),
        "web": StageModel("web", 0, 0, "io"),
    }


STAGE_ROLES: Dict[str, str] = {
    "embed": "embed", "rerank": "rerank", "vsearch": "search",
    "rewrite_prefill": "search_llm", "rewrite_decode": "search_llm",
    "plan_prefill": "search_llm", "plan_decode": "search_llm",
    "refine_prefill": "chat", "refine_decode": "chat",
    "chat_prefill": "chat", "chat_decode": "chat", "web": "io",
}
