"""Document chunking — paper §6.1 defaults: chunk size 128 tokens,
overlap 10."""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Sequence

from repro.rag.tokenizer import HashTokenizer


@dataclass
class Chunk:
    doc_id: int
    chunk_id: int
    token_ids: List[int]
    text: str


def chunk_documents(docs: Sequence[str], tokenizer: HashTokenizer, *,
                    chunk_size: int = 128, overlap: int = 10) -> List[Chunk]:
    assert 0 <= overlap < chunk_size
    chunks: List[Chunk] = []
    step = chunk_size - overlap
    for di, doc in enumerate(docs):
        ids = tokenizer.encode(doc)
        words = doc.split()
        if not ids:
            continue
        for ci, start in enumerate(range(0, max(len(ids) - overlap, 1), step)):
            piece = ids[start:start + chunk_size]
            if not piece:
                break
            # approximate text span (hash tokenizer is word-aligned)
            text = " ".join(words[start:start + chunk_size])
            chunks.append(Chunk(di, len(chunks), piece, text))
            if start + chunk_size >= len(ids):
                break
    return chunks
