"""Embedding + reranking stages backed by the model zoo.

Embedder: mean-pooled final hidden states, L2-normalized (bge / qwen3-
embedding style).  Reranker: cross-encoder — scores [query SEP chunk]
pairs via a scalar head on the first position's hidden state.
Both batch over items, which is exactly the batchable workload HeRo's
partitioner (Eq. 3) optimizes.
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import lm
from repro.rag.tokenizer import SEP


def _pad_batch(token_lists: Sequence[Sequence[int]], pad_to: int,
               vocab: int) -> jnp.ndarray:
    out = np.zeros((len(token_lists), pad_to), np.int32)
    for i, ids in enumerate(token_lists):
        ids = list(ids)[:pad_to]
        out[i, : len(ids)] = np.clip(ids, 0, vocab - 1)
    return jnp.asarray(out)


def hidden_states(params, cfg: ModelConfig, tokens) -> jax.Array:
    """Final-layer hidden states (pre-logits).  Dense-family models only
    (the paper's embed/rerank models are all dense)."""
    if cfg.family != "dense":
        raise NotImplementedError(cfg.family)
    x = jnp.take(params["embed"], tokens, axis=0)
    positions = jnp.arange(tokens.shape[1])
    x, _ = lm._run_dense_stack(params["blocks"], cfg, x, positions,
                               None, None, "eval")
    return L.rmsnorm(params["final_norm"], x, cfg.norm_eps)


class Embedder:
    def __init__(self, cfg: ModelConfig, params, max_tokens: int = 128):
        self.cfg = cfg
        self.params = params
        self.max_tokens = max_tokens

        @jax.jit
        def _embed(params, tokens, mask):
            h = hidden_states(params, cfg, tokens)
            s = jnp.sum(h * mask[..., None], axis=1)
            emb = s / jnp.maximum(mask.sum(-1, keepdims=True), 1.0)
            return emb / jnp.maximum(
                jnp.linalg.norm(emb, axis=-1, keepdims=True), 1e-6)

        self._fn = _embed

    def embed(self, token_lists: Sequence[Sequence[int]]) -> jax.Array:
        tokens = _pad_batch(token_lists, self.max_tokens, self.cfg.vocab_size)
        mask = (tokens != 0).astype(jnp.float32)
        return self._fn(self.params, tokens, mask)


class Reranker:
    def __init__(self, cfg: ModelConfig, params, max_tokens: int = 192):
        self.cfg = cfg
        self.params = params
        self.max_tokens = max_tokens

        @jax.jit
        def _score(params, tokens):
            h = hidden_states(params, cfg, tokens)
            w = params["embed"][SEP]          # reuse a row as the head
            return jnp.einsum("bd,d->b", h[:, 0], w)

        self._fn = _score

    def score(self, query_ids: Sequence[int],
              chunk_ids_list: Sequence[Sequence[int]]) -> np.ndarray:
        pairs = [list(query_ids) + [SEP] + list(c) for c in chunk_ids_list]
        tokens = _pad_batch(pairs, self.max_tokens, self.cfg.vocab_size)
        return np.asarray(self._fn(self.params, tokens))
