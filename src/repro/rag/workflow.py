"""The paper's three agentic RAG workflows (§6.1) as dynamic task graphs.

W1  Fast Document Finder   : chunk→embed→index→retrieve→rerank→generate
W2  Advanced Document QA   : + LLM query rewriting (N sub-queries, each
                             spawning retrieve+rerank branches at runtime)
                             + per-branch context refinement (RECOMP-style
                             compression of each retrieved set, paper [27])
W3  Deep Researcher        : + search planner issuing web requests

Dynamic inter-stage dependencies (§3.1) are real here: the rewriter's and
planner's branches only materialize when (part of) their decode finishes —
via node expanders and per-token-group ``on_progress`` callbacks, so the
first sub-query's retrieval starts before the rewriter finishes decoding
(the paper's motivating example).

``fine_grained`` mirrors the scheduler's sub-stage partition (§4.2): it
refines stage-level dependencies into per-piece ones — chunked chat prefill
consumes each branch's refined context as soon as that branch finishes,
instead of waiting for all of them.  Baselines schedule the coarse graph.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, List, Optional

from repro.core.dag import DynamicDAG, Node, WorkflowTemplate
from repro.rag.datasets import QueryTrace


def _add(dag: DynamicDAG, nid, stage, kind, workload, deps=(), template=None,
         expander=None, payload=None) -> Node:
    return dag.add(Node(id=nid, stage=stage, kind=kind,
                        workload=max(int(workload), 1), deps=set(deps),
                        template=template or nid, expander=expander,
                        payload=payload or {}))


def build_w1(trace: QueryTrace, fine_grained: bool = True,
             prefix: str = "", dag: DynamicDAG = None) -> DynamicDAG:
    dag = dag if dag is not None else DynamicDAG()
    N = lambda s: prefix + s  # noqa: E731 — namespacing for multi-query DAGs
    _add(dag, N("embed_chunks"), "embed", "batchable", trace.n_chunks)
    _add(dag, N("embed_query"), "embed", "batchable", 1)
    _add(dag, N("vsearch"), "vsearch", "search", trace.n_chunks * 8,
         deps=[N("embed_chunks"), N("embed_query")])
    _add(dag, N("rerank"), "rerank", "batchable", trace.rerank_candidates,
         deps=[N("vsearch")])
    _add(dag, N("chat_prefill"), "chat_prefill", "stream_prefill",
         trace.context_tokens + trace.query_tokens, deps=[N("rerank")])
    _add(dag, N("chat_decode"), "chat_decode", "stream_decode",
         trace.answer_tokens, deps=[N("chat_prefill")])
    return dag


def build_w2(trace: QueryTrace, fine_grained: bool = True,
             prefix: str = "", dag: DynamicDAG = None) -> DynamicDAG:
    return _build_agentic(trace, planner=False, fine_grained=fine_grained,
                          prefix=prefix, dag=dag)


def build_w3(trace: QueryTrace, fine_grained: bool = True,
             prefix: str = "", dag: DynamicDAG = None) -> DynamicDAG:
    return _build_agentic(trace, planner=True, fine_grained=fine_grained,
                          prefix=prefix, dag=dag)


def _build_agentic(trace: QueryTrace, planner: bool, fine_grained: bool,
                   prefix: str = "", dag: DynamicDAG = None) -> DynamicDAG:
    """W2/W3: base retrieval + rewriter branches (+ planner/web), each branch
    refined independently (RECOMP-style), feeding a (chunked) chat prefill."""
    dag = dag if dag is not None else DynamicDAG()
    N = lambda s: prefix + s  # noqa: E731
    n_sources = 1 + trace.n_subqueries + (trace.n_web_searches if planner
                                          else 0)
    ctx_piece = max(trace.context_tokens // n_sources, 32)
    refine_piece = max(trace.refine_tokens // n_sources, 8)

    _add(dag, N("embed_chunks"), "embed", "batchable", trace.n_chunks)
    _add(dag, N("embed_query"), "embed", "batchable", 1)
    _add(dag, N("vsearch_base"), "vsearch", "search", trace.n_chunks * 8,
         deps=[N("embed_chunks"), N("embed_query")], template="vsearch")
    _add(dag, N("rerank_base"), "rerank", "batchable", trace.rerank_candidates,
         deps=[N("vsearch_base")], template="rerank")
    # base branch refine
    _add(dag, N("refine_prefill_base"), "refine_prefill", "stream_prefill",
         ctx_piece, deps=[N("rerank_base")], template="refine_prefill")
    _add(dag, N("refine_decode_base"), "refine_decode", "stream_decode",
         refine_piece, deps=[N("refine_prefill_base")],
         template="refine_decode")

    # chat: chunked prefill (fine) or monolithic (coarse)
    refine_tails: List[str] = [N("refine_decode_base")]
    if fine_grained:
        _add(dag, N("chat_prefill_0"), "chat_prefill", "stream_prefill",
             ctx_piece + trace.query_tokens, deps=[N("refine_decode_base")],
             template="chat_prefill")
        chat_state = {"last": N("chat_prefill_0"), "pieces": 1}
    else:
        chat_state = {"last": None, "pieces": 0}

    def add_chat_piece(d: DynamicDAG, dep: str):
        if not fine_grained:
            return
        prev = chat_state["last"]
        nid = N(f"chat_prefill_{chat_state['pieces']}")
        _add(d, nid, "chat_prefill", "stream_prefill", ctx_piece,
             deps=[dep, prev], template="chat_prefill")
        chat_state["last"] = nid
        chat_state["pieces"] += 1
        if N("chat_decode") in d.nodes:
            d.retarget_dep(N("chat_decode"), prev, nid)

    def add_branch_refine(d: DynamicDAG, i: str, dep: str):
        rp = _add(d, N(f"refine_prefill_{i}"), "refine_prefill",
                  "stream_prefill", ctx_piece, deps=[dep],
                  template="refine_prefill")
        rd = _add(d, N(f"refine_decode_{i}"), "refine_decode", "stream_decode",
                  refine_piece, deps=[rp.id], template="refine_decode")
        refine_tails.append(rd.id)
        if fine_grained:
            add_chat_piece(d, rd.id)
        elif N("chat_prefill") in d.nodes:
            d.add_edge(rd.id, N("chat_prefill"))
        return rd

    # rewriter: dynamic sub-query branches with early (token-group) release
    n_sub = trace.n_subqueries
    per_sub = max(trace.rewrite_tokens // max(n_sub, 1), 1)
    rw = {"done": 0, "spawned": 0}

    def spawn_subquery(d: DynamicDAG, i: int, dep_id: str):
        sq = _add(d, N(f"embed_sq{i}"), "embed", "batchable", 1, deps=[dep_id],
                  template="embed_sq")
        vs = _add(d, N(f"vsearch_sq{i}"), "vsearch", "search",
                  trace.n_chunks * 8, deps=[sq.id, N("embed_chunks")],
                  template="vsearch_sq")
        rr = _add(d, N(f"rerank_sq{i}"), "rerank", "batchable",
                  max(trace.rerank_candidates // 2, 4), deps=[vs.id],
                  template="rerank_sq")
        add_branch_refine(d, f"sq{i}", rr.id)

    def rw_progress(d: DynamicDAG, piece: Node, tokens_done: int):
        rw["done"] += tokens_done
        while rw["spawned"] < n_sub and rw["done"] >= (rw["spawned"] + 1) * per_sub:
            spawn_subquery(d, rw["spawned"], piece.id)
            rw["spawned"] += 1

    def rw_expander(d: DynamicDAG, node: Node):
        while rw["spawned"] < n_sub:
            spawn_subquery(d, rw["spawned"], node.id)
            rw["spawned"] += 1

    _add(dag, N("rewrite_prefill"), "rewrite_prefill", "stream_prefill",
         trace.query_tokens)
    _add(dag, N("rewrite_decode"), "rewrite_decode", "stream_decode",
         trace.rewrite_tokens, deps=[N("rewrite_prefill")],
         expander=rw_expander, payload={"on_progress": rw_progress})

    # planner (W3): web searches, each embedded + refined
    if planner:
        n_web = trace.n_web_searches
        pl = {"spawned": 0}

        def spawn_web(d: DynamicDAG, i: int, dep_id: str):
            w = _add(d, N(f"web{i}"), "web", "io", 1, deps=[dep_id],
                     template="web")
            e = _add(d, N(f"embed_web{i}"), "embed", "batchable", 4,
                     deps=[w.id], template="embed_web")
            add_branch_refine(d, N(f"web{i}"), e.id)

        def pl_expander(d: DynamicDAG, node: Node):
            while pl["spawned"] < n_web:
                spawn_web(d, pl["spawned"], node.id)
                pl["spawned"] += 1

        _add(dag, N("plan_prefill"), "plan_prefill", "stream_prefill",
             trace.query_tokens)
        _add(dag, N("plan_decode"), "plan_decode", "stream_decode",
             trace.plan_tokens, deps=[N("plan_prefill")], expander=pl_expander)

    # chat tail.  Coarse: single prefill gated on every refine tail + the
    # decode tails (so dynamically-spawned branches are always observed).
    gate = [N("rewrite_decode")] + ([N("plan_decode")] if planner else [])
    if fine_grained:
        _add(dag, N("chat_decode"), "chat_decode", "stream_decode",
             trace.answer_tokens, deps=[chat_state["last"]] + gate)
        # late chat pieces hook themselves onto chat_decode via add_chat_piece
        dag.nodes[N("chat_decode")].payload["chat_state"] = chat_state
    else:
        _add(dag, N("chat_prefill"), "chat_prefill", "stream_prefill",
             trace.context_tokens + trace.query_tokens,
             deps=refine_tails + gate, template="chat_prefill")
        _add(dag, N("chat_decode"), "chat_decode", "stream_decode",
             trace.answer_tokens, deps=[N("chat_prefill")])
    return dag


BUILDERS = {1: build_w1, 2: build_w2, 3: build_w3}


def build_workflow(wf: int, trace: QueryTrace,
                   fine_grained: bool = True) -> DynamicDAG:
    return BUILDERS[wf](trace, fine_grained)


# -- workflow template (future-criticality prior, Eq. 4) ---------------------

def make_template(wf: int, mean: Dict[str, float]) -> WorkflowTemplate:
    """mean: historical means over traces (see default_means)."""
    t = WorkflowTemplate()
    n_sources = 1 + (mean["n_subqueries"] if wf >= 2 else 0) + (
        mean["n_web"] if wf >= 3 else 0)
    ctx_piece = max(mean["context_tokens"] / n_sources, 32)
    ref_piece = max(mean["refine_tokens"] / n_sources, 8)
    t.add_stage("embed_chunks", "embed", "batchable", mean["n_chunks"], 1.0)
    t.add_stage("embed_query", "embed", "batchable", 1, 1.0)
    t.add_stage("vsearch", "vsearch", "search", mean["n_chunks"] * 8, 1.0,
                deps=["embed_chunks", "embed_query"])
    t.add_stage("rerank", "rerank", "batchable", mean["rerank"], 1.0,
                deps=["vsearch"])
    prev = "rerank"
    if wf >= 2:
        t.add_stage("rewrite_prefill", "rewrite_prefill", "stream_prefill",
                    mean["query_tokens"], 1.0)
        t.add_stage("rewrite_decode", "rewrite_decode", "stream_decode",
                    mean["rewrite_tokens"], 1.0, deps=["rewrite_prefill"])
        t.add_stage("embed_sq", "embed", "batchable", 1,
                    mean["n_subqueries"], deps=["rewrite_decode"])
        t.add_stage("vsearch_sq", "vsearch", "search", mean["n_chunks"] * 8,
                    mean["n_subqueries"], deps=["embed_sq"])
        t.add_stage("rerank_sq", "rerank", "batchable", mean["rerank"] / 2,
                    mean["n_subqueries"], deps=["vsearch_sq"])
        t.add_stage("refine_prefill", "refine_prefill", "stream_prefill",
                    ctx_piece, n_sources, deps=["rerank", "rerank_sq"])
        t.add_stage("refine_decode", "refine_decode", "stream_decode",
                    ref_piece, n_sources, deps=["refine_prefill"])
        prev = "refine_decode"
    if wf >= 3:
        t.add_stage("plan_prefill", "plan_prefill", "stream_prefill",
                    mean["query_tokens"], 1.0)
        t.add_stage("plan_decode", "plan_decode", "stream_decode",
                    mean["plan_tokens"], 1.0, deps=["plan_prefill"])
        t.add_stage("web", "web", "io", 1, mean["n_web"],
                    deps=["plan_decode"])
        t.add_stage("embed_web", "embed", "batchable", 4, mean["n_web"],
                    deps=["web"])
        t.stages["refine_prefill"].deps.add("embed_web")
    t.add_stage("chat_prefill", "chat_prefill", "stream_prefill",
                (ctx_piece if wf >= 2 else mean["context_tokens"])
                + mean["query_tokens"],
                n_sources if wf >= 2 else 1.0, deps=[prev])
    t.add_stage("chat_decode", "chat_decode", "stream_decode",
                mean["answer_tokens"], 1.0, deps=["chat_prefill"])
    return t


def default_means(dataset_traces) -> Dict[str, float]:
    import numpy as np
    tr = dataset_traces
    return {
        "n_chunks": float(np.mean([t.n_chunks for t in tr])),
        "rerank": float(np.mean([t.rerank_candidates for t in tr])),
        "query_tokens": float(np.mean([t.query_tokens for t in tr])),
        "rewrite_tokens": float(np.mean([t.rewrite_tokens for t in tr])),
        "n_subqueries": float(np.mean([t.n_subqueries for t in tr])),
        "context_tokens": float(np.mean([t.context_tokens for t in tr])),
        "refine_tokens": float(np.mean([t.refine_tokens for t in tr])),
        "plan_tokens": float(np.mean([t.plan_tokens for t in tr])),
        "n_web": float(np.mean([t.n_web_searches for t in tr])),
        "answer_tokens": float(np.mean([t.answer_tokens for t in tr])),
    }
