"""The paper's three agentic RAG workflows (§6.1) as dynamic task graphs.

W1  Fast Document Finder   : chunk→embed→index→retrieve→rerank→generate
W2  Advanced Document QA   : + LLM query rewriting (N sub-queries, each
                             spawning retrieve+rerank branches at runtime)
                             + per-branch context refinement (RECOMP-style
                             compression of each retrieved set, paper [27])
W3  Deep Researcher        : + search planner issuing web requests

The canonical workflow definitions now live in ``repro.api.spec`` as
declarative :class:`~repro.api.spec.WorkflowSpec` objects, from which both
the runtime :class:`DynamicDAG` (with its §3.1 dynamic branch expanders
and per-token-group early release) and the Eq. 4
:class:`WorkflowTemplate` prior are derived — one description, two
artifacts.  Define new workflows there (or pass a custom spec to
``HeroSession.submit``); the functions below are thin compatibility
wrappers over ``builtin_spec(1..3)`` kept for the figure benchmarks.
"""
from __future__ import annotations

from typing import Dict

from repro.core.dag import DynamicDAG, WorkflowTemplate
from repro.rag.datasets import QueryTrace


def build_w1(trace: QueryTrace, fine_grained: bool = True,
             prefix: str = "", dag: DynamicDAG = None) -> DynamicDAG:
    from repro.api.spec import builtin_spec
    return builtin_spec(1).build_dag(trace, fine_grained=fine_grained,
                                     prefix=prefix, dag=dag)


def build_w2(trace: QueryTrace, fine_grained: bool = True,
             prefix: str = "", dag: DynamicDAG = None) -> DynamicDAG:
    from repro.api.spec import builtin_spec
    return builtin_spec(2).build_dag(trace, fine_grained=fine_grained,
                                     prefix=prefix, dag=dag)


def build_w3(trace: QueryTrace, fine_grained: bool = True,
             prefix: str = "", dag: DynamicDAG = None) -> DynamicDAG:
    from repro.api.spec import builtin_spec
    return builtin_spec(3).build_dag(trace, fine_grained=fine_grained,
                                     prefix=prefix, dag=dag)


BUILDERS = {1: build_w1, 2: build_w2, 3: build_w3}


def build_workflow(wf: int, trace: QueryTrace,
                   fine_grained: bool = True) -> DynamicDAG:
    return BUILDERS[wf](trace, fine_grained)


def shared_corpus_traces(dataset: str, k: int, seed: int = 0,
                         n_docs: int = 4, context_tokens: int = 768,
                         chunks_per_doc: int = 4):
    """``k`` traces over ONE shared ``n_docs``-document corpus: every
    query retrieves the same ranked chunk list (identical ``chunk_ids``)
    under the same context budget — the dominant serving pattern the
    cross-query prefix cache exists for (many users asking about the same
    few documents).  Query/answer lengths still vary per trace, so only
    the retrieved-context prefix is shareable, exactly as in a real
    deployment."""
    import dataclasses

    from repro.rag.datasets import sample_traces
    traces = sample_traces(dataset, k, seed=seed)
    chunk_ids = tuple(f"d{seed}.{i // chunks_per_doc}.c{i % chunks_per_doc}"
                      for i in range(n_docs * chunks_per_doc))
    return [dataclasses.replace(t, n_docs=n_docs, chunk_ids=chunk_ids,
                                context_tokens=context_tokens)
            for t in traces]


# -- workflow template (future-criticality prior, Eq. 4) ---------------------

def make_template(wf: int, mean: Dict[str, float]) -> WorkflowTemplate:
    """mean: historical means over traces (see default_means).  Derived
    from the same ``WorkflowSpec`` as the runtime DAG."""
    from repro.api.spec import builtin_spec
    return builtin_spec(wf).build_template(mean)


def default_means(dataset_traces) -> Dict[str, float]:
    import numpy as np
    tr = dataset_traces
    return {
        "n_chunks": float(np.mean([t.n_chunks for t in tr])),
        "rerank": float(np.mean([t.rerank_candidates for t in tr])),
        "query_tokens": float(np.mean([t.query_tokens for t in tr])),
        "rewrite_tokens": float(np.mean([t.rewrite_tokens for t in tr])),
        "n_subqueries": float(np.mean([t.n_subqueries for t in tr])),
        "context_tokens": float(np.mean([t.context_tokens for t in tr])),
        "refine_tokens": float(np.mean([t.refine_tokens for t in tr])),
        "plan_tokens": float(np.mean([t.plan_tokens for t in tr])),
        "n_web": float(np.mean([t.n_web_searches for t in tr])),
        "answer_tokens": float(np.mean([t.answer_tokens for t in tr])),
    }
