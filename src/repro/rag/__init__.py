from repro.rag.chunker import Chunk, chunk_documents  # noqa: F401
from repro.rag.datasets import (  # noqa: F401
    DATASETS, QueryTrace, sample_traces, synth_documents, synth_query)
from repro.rag.embedder import Embedder, Reranker  # noqa: F401
from repro.rag.stages import STAGE_ROLES, build_stages  # noqa: F401
from repro.rag.tokenizer import HashTokenizer  # noqa: F401
from repro.rag.vectordb import VectorDB  # noqa: F401
from repro.rag.workflow import (  # noqa: F401
    build_workflow, default_means, make_template, shared_corpus_traces)
