"""Sharded, asynchronous checkpointing with restart-from-latest.

Pytrees are flattened to leaf arrays and written as one .npz per save (per
host at scale: each host writes its addressable shards; this container has
one host).  Writes happen on a background thread (training never blocks on
IO); a manifest records the latest *complete* step, so a crash mid-write
can never corrupt restore — the previous complete checkpoint wins.
Retention keeps the newest ``keep`` checkpoints.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
import threading
from typing import Any, List, Optional, Tuple

import jax
import numpy as np


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # -- save ------------------------------------------------------------------
    def save(self, tree: Any, step: int, block: bool = False):
        """Asynchronous save: snapshots to host memory synchronously (cheap),
        writes to disk on a background thread."""
        self.wait()
        leaves, treedef = jax.tree.flatten(tree)
        host = [np.asarray(x) for x in leaves]

        def _write():
            try:
                tmp = tempfile.mkdtemp(dir=self.dir)
                np.savez(os.path.join(tmp, "shards.npz"),
                         **{f"leaf{i}": a for i, a in enumerate(host)})
                final = os.path.join(self.dir, f"step_{step:08d}")
                os.replace(os.path.join(tmp, "shards.npz"),
                           final + ".npz.tmp")
                os.replace(final + ".npz.tmp", final + ".npz")
                shutil.rmtree(tmp, ignore_errors=True)
                self._write_manifest(step)
                self._gc()
            except BaseException as e:   # surfaced by wait()
                self._error = e

        self._thread = threading.Thread(target=_write, daemon=True)
        self._thread.start()
        if block:
            self.wait()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _write_manifest(self, step: int):
        tmp = os.path.join(self.dir, "manifest.json.tmp")
        with open(tmp, "w") as f:
            json.dump({"latest_step": step}, f)
        os.replace(tmp, os.path.join(self.dir, "manifest.json"))

    def _gc(self):
        steps = self.available_steps()
        for s in steps[:-self.keep]:
            try:
                os.remove(os.path.join(self.dir, f"step_{s:08d}.npz"))
            except OSError:
                pass

    # -- restore -----------------------------------------------------------------
    def available_steps(self) -> List[int]:
        out = []
        for fn in os.listdir(self.dir):
            if fn.startswith("step_") and fn.endswith(".npz"):
                out.append(int(fn[5:-4]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        mf = os.path.join(self.dir, "manifest.json")
        if not os.path.exists(mf):
            return None
        with open(mf) as f:
            return json.load(f)["latest_step"]

    def restore(self, template: Any, step: int) -> Any:
        """Restore into the structure (and shardings) of ``template``."""
        path = os.path.join(self.dir, f"step_{step:08d}.npz")
        data = np.load(path)
        leaves, treedef = jax.tree.flatten(template)
        restored = []
        for i, leaf in enumerate(leaves):
            a = data[f"leaf{i}"]
            dev = jax.device_put(a, getattr(leaf, "sharding", None)) \
                if hasattr(leaf, "sharding") else a
            restored.append(dev)
        return jax.tree.unflatten(treedef, restored)

    def restore_latest(self, template: Any
                       ) -> Optional[Tuple[Any, int]]:
        step = self.latest_step()
        if step is None:
            return None
        return self.restore(template, step), step
