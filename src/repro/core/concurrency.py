"""Bandwidth-aware concurrency control (paper §4.2, Eq. 5).

Admitting ready node v with config c while the critical-path node v* runs
costs:  W_B = φ_{v*}(B(t) + b_v(c)) · (t − S_{v*}) · p_{v*}(c_{v*}).
A soft budget B_soft prunes configs outright.  The mapper's final score is
F_v(c) + α · W_B  (Alg. 1 line 13) — parallelism is admitted only when it
does not significantly impede critical-path progress.
"""
from __future__ import annotations

from typing import Optional

from repro.core.dag import RUNNING, Node
from repro.core.perf_model import LinearPerfModel


def contention_penalty(perf: LinearPerfModel, v_star: Optional[Node],
                       b_cand: float, B_now: float, now: float) -> float:
    """W_B (Eq. 5).  0 when there is no running critical node."""
    if v_star is None or v_star.status != RUNNING or v_star.config is None:
        return 0.0
    pu, batch = v_star.config
    if pu == "io":                 # external calls consume no bandwidth
        return 0.0
    p_star = perf.p0(v_star.stage, pu, batch)
    phi = perf.phi(v_star.stage, B_now + b_cand)
    active = max(now - v_star.start, 0.0)
    return phi * active * p_star


def violates_budget(B_now: float, b_cand: float, b_soft: float) -> bool:
    """Soft bandwidth constraint (Alg. 1 line 11)."""
    return B_now + b_cand > b_soft
