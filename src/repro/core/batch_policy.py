"""Adaptive batching policy — coalesce/decode caps derived online (§4.2).

HeRo's thesis is that profiling-based performance models should *drive*
the scheduler: Eq. 3 derives the partition count n* online from the
fitted model instead of from constants.  This module applies the same
move to the batching layer, replacing the three hand-picked knobs
(``coalesce_cap``, ``coalesce_window``, ``decode_batch_cap``) with
derivations from :class:`LinearPerfModel`'s profiled grids:

- **decode width cap** — enumerate the profiled ``(width, group)`` decode
  grid the way Eq. 3 enumerates n* and keep widening the resident batch
  while the marginal per-member latency gain of one more resident exceeds
  the queueing delay of waiting for that member to arrive (an EWMA of
  ready-pool inter-arrivals tracked by the scheduler).  Under saturating
  arrivals the delay term vanishes and the cap sits at the argmin of the
  per-member curve; under sparse arrivals it backs off toward narrow
  batches — no single constant is right for both, which is exactly why
  the fixed ``decode_batch_cap`` had to go (Agent.xpu makes the same
  argument for heterogeneous-SoC agentic serving).

- **coalesce cap** — the dual for batchable stages: the knee of the
  profiled per-item latency curve p0(n)/n (Fig. 2's "larger batches do
  not always yield better per-item efficiency").

- **coalesce window** — from the fitted per-dispatch overhead versus the
  observed inter-arrival rate: a fused dispatch may occupy its PU for a
  few inter-arrival periods (absorbing work saves one invocation overhead
  per member), but never so long that latecomers starve behind it; as
  arrivals saturate (τ → 0) the queue is service-bound and the window
  opens to the profiled ladder top.

- **per-round token group** (the ROADMAP horizon policy) — each decode
  round sorts residents by remaining tokens and enumerates grid groups
  aligned to the *member remainder distribution* instead of padding
  ragged tails to a fixed group; the scheduler scores candidates by mean
  member completion (Σ⌈rᵢ/g⌉·p0 / w), so a short straggler's early leave
  is weighed against the per-round overhead of extra boundaries.

``FixedBatchPolicy`` preserves the PR 3 constants bit-exactly (pinned
against committed goldens); ``AdaptiveBatchPolicy`` is selected with
``SchedulerConfig.batch_policy = "adaptive"`` /
``HeroSession(batch_policy="adaptive")``.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.dag import Node
from repro.core.partitioner import ceil_passes
from repro.core.perf_model import LinearPerfModel


class ArrivalTracker:
    """Burst-aware EWMA of ready-pool inter-arrival times, per
    (stage, kind) key.

    The scheduler observes every node the moment it first enters the
    ready pool (decode residents re-entering at a token-group boundary
    count too: a rejoining stream IS the next member a forming batch
    would wait for).  ``tau`` is the policy's queueing-delay estimate for
    "one more member".

    *Fresh* arrivals landing at the same scheduling instant are a
    *burst* — a W2 rewriter releasing 4 sub-queries at once spawns 4
    streams whose first ready-pool entries share one timestamp.  A plain
    gap EWMA aliases such a burst as one arrival — one inter-arrival gap
    for b members — so the width-beyond-ready decision mis-estimates the
    per-member arrival rate by ~b×.  Two estimates are therefore kept:

    - :meth:`tau` — burst-corrected *per-member* inter-arrival:
      ``EWMA(gap) / EWMA(batch size)`` over fresh-burst-deduplicated
      arrival events.  What the decode width cap compares marginal
      per-member gains against — one arrival event repays the wait with
      the whole burst's worth of members.
    - :meth:`tau_event` — the PR 4 raw gap EWMA (every observation, zero
      gaps included).  What the coalesce *window* consumes: it bounds
      how long a fused dispatch may hold a PU before the next
      *newcomer* starves behind it, and a burst's latecomers starve
      together, not b× faster.

    Singleton arrivals make both estimates identical to the PR 4 one
    (batch EWMA pinned at 1).  Decode residents *re-entering* at a
    token-group boundary count as individual arrivals (``fresh=False``)
    exactly as before — a re-fusing batch's own boundary is not
    evidence about how fast new members show up.
    """

    def __init__(self, alpha: float = 0.3):
        self.alpha = alpha
        self._last: Dict[Tuple[str, str], float] = {}
        # fresh members that arrived at _last's instant, not yet flushed
        # into the batch EWMA (a burst closes when a later observation
        # lands)
        self._pending: Dict[Tuple[str, str], int] = {}
        self._gap: Dict[Tuple[str, str], float] = {}
        self._batch: Dict[Tuple[str, str], float] = {}
        self._tau_event: Dict[Tuple[str, str], float] = {}

    def observe(self, key: Tuple[str, str], now: float,
                fresh: bool = True) -> None:
        last = self._last.get(key)
        if last is None:
            self._last[key] = now
            self._pending[key] = 1
            return
        a = self.alpha
        # event estimate: every observation, zero gaps included (the
        # PR 4 estimator, bit-for-bit)
        gap = max(now - last, 0.0)
        prev_e = self._tau_event.get(key)
        self._tau_event[key] = (gap if prev_e is None
                                else (1 - a) * prev_e + a * gap)
        if fresh and now <= last:
            # same scheduling instant, new stream: the burst grows; the
            # per-member estimate records no gap yet
            self._pending[key] = self._pending.get(key, 1) + 1
            self._last[key] = now
            return
        batch = float(self._pending.get(key, 1))
        prev_g = self._gap.get(key)
        self._gap[key] = gap if prev_g is None else (1 - a) * prev_g + a * gap
        prev_b = self._batch.get(key)
        self._batch[key] = (batch if prev_b is None
                            else (1 - a) * prev_b + a * batch)
        self._last[key] = now
        self._pending[key] = 1

    def tau(self, key: Tuple[str, str]) -> Optional[float]:
        """Burst-corrected EWMA mean *per-member* inter-arrival for
        ``key`` (None until 2 distinct arrival instants)."""
        gap = self._gap.get(key)
        if gap is None:
            return None
        return gap / max(self._batch.get(key, 1.0), 1.0)

    def tau_event(self, key: Tuple[str, str]) -> Optional[float]:
        """Raw per-observation gap EWMA (None until 2 observations) —
        the PR 4 estimator, kept for the coalesce-window fairness
        bound."""
        return self._tau_event.get(key)


class FixedBatchPolicy:
    """The PR 3 behavior: the three SchedulerConfig constants, the fixed
    token-group ladder, and horizon-amortized round scoring — bit-exact
    (pinned against ``tests/goldens/``)."""

    name = "fixed"

    def __init__(self, cfg, perf: LinearPerfModel, kv=None):
        self.cfg = cfg
        self.perf = perf
        # KV-residency tracker (core/kv_residency.py) when the scheduler
        # runs with it: lets the adaptive width cap price residency from
        # the batch's measured state instead of a fixed-width probe
        self.kv = kv

    # -- caps / windows ----------------------------------------------------
    def decode_width_cap(self, stage: str, prefer_pu: Optional[str],
                         tau: Optional[float],
                         remainders: Optional[Sequence[int]] = None) -> int:
        return self.cfg.decode_batch_cap

    def coalesce_cap(self, stage: str, pu: Optional[str] = None) -> int:
        return self.cfg.coalesce_cap

    def coalesce_window(self, stage: str, tau: Optional[float]) -> int:
        return self.cfg.coalesce_window

    # -- decode rounds -----------------------------------------------------
    def round_group_candidates(self, node: Node) -> Optional[Sequence[int]]:
        """None = the scheduler's fixed token-group ladder."""
        return None

    def round_passes(self, node: Node, batch: int) -> float:
        """Eq. 3 amortization over the batch's remaining horizon — the
        PR 3 scoring (the dispatch itself still serves one group)."""
        return ceil_passes(node.workload, batch)

    # -- speculative decoding ----------------------------------------------
    def spec_width_candidates(self, draft_stage: str, verify_stage: str,
                              draft_pu: str, verify_pu: str,
                              alpha: float) -> Sequence[int]:
        """Draft widths the scheduler's speculative plan enumerates for a
        (draft, verify) PU pair.  Fixed policy: the single configured
        width, snapped to the profiled grid (nearest below, else the
        grid floor) so the pair lookup is exact."""
        w = max(int(self.cfg.spec_draft_width), 1)
        grid = self.perf.spec_width_grid(draft_stage, verify_stage,
                                         draft_pu, verify_pu)
        if not grid:
            return (w,)
        below = [g for g in grid if g <= w]
        return (below[-1] if below else grid[0],)


class AdaptiveBatchPolicy(FixedBatchPolicy):
    """Caps/windows/groups derived online from the profiled grids."""

    name = "adaptive"

    def __init__(self, cfg, perf: LinearPerfModel, kv=None):
        super().__init__(cfg, perf, kv)
        self._pus: List[str] = sorted({pu for (_s, pu) in perf.coef})
        self._cap_cache: Dict[Tuple[str, str], int] = {}
        self._anchor_cache: Dict[str, Optional[str]] = {}
        # (stage, pu) -> (knee, gains, residency-per-round): the profiled
        # tables are static, so everything except the tau comparison is
        # derived once — decode_width_cap runs in the scheduler hot loop
        self._width_cache: Dict[Tuple[str, str], tuple] = {}
        # (pair, alpha-bucket) -> ranked draft widths (hot-loop cache)
        self._spec_cache: Dict[tuple, Sequence[int]] = {}

    # -- anchors -----------------------------------------------------------
    def _anchor_pu(self, stage: str, probe_batch: int = 16) -> Optional[str]:
        """The PU Eq. 3 will most likely map ``stage`` to: fastest
        profiled per-item latency at a mid-grid probe shape."""
        if stage in self._anchor_cache:
            return self._anchor_cache[stage]
        best, best_t = None, float("inf")
        for pu in self._pus:
            if not self.perf.supported(stage, pu):
                continue
            t = self.perf.per_item(stage, pu, probe_batch)
            if t < best_t:
                best, best_t = pu, t
        self._anchor_cache[stage] = best
        return best

    # -- decode width cap --------------------------------------------------
    def decode_width_cap(self, stage: str, prefer_pu: Optional[str],
                         tau: Optional[float],
                         remainders: Optional[Sequence[int]] = None) -> int:
        """Widen while the marginal per-member gain of one more resident
        beats the queueing delay of waiting for it.

        The gain of width w over the previous grid width repeats at every
        round the stream stays resident, so it is compared against the
        arrival gap amortized over those rounds (estimated from the
        candidates' own remaining tokens when known); ``tau=None`` (no
        arrival history yet) and saturating arrivals both degrade to the
        pure argmin-knee of the profiled per-member curve.
        """
        pu = prefer_pu if prefer_pu is not None else self._anchor_pu(stage)
        if pu is None:
            return self.cfg.decode_batch_cap
        group = self.cfg.token_group
        cached = self._width_cache.get((stage, pu))
        if cached is None:
            gains = self.perf.decode_marginal_gains(stage, pu, group)
            knee = 1
            for w, gain in gains:
                if gain <= 0:
                    break
                knee = w
            p_round = (self.perf.p0_decode(stage, pu, 2, group)
                       if gains else 0.0)
            groups = self.perf.decode_group_grid(stage, pu)
            mid = groups[len(groups) // 2] * 2 if groups else 4 * group
            cached = (knee, tuple(gains), p_round, mid)
            self._width_cache[(stage, pu)] = cached
        knee, gains, p_round, default_horizon = cached
        if not gains:
            return self.cfg.decode_batch_cap
        # Two different decisions hide in one cap.  (1) Truncation of the
        # ALREADY-READY candidate set: those members ride along for free
        # (they are queued either way), so cutting them can only be right
        # past the spill knee of the profiled per-member curve — the pure
        # Eq. 3 argmin over the width axis of the decode grid.
        # (2) Width reserved BEYOND the ready set: a member who has not
        # arrived yet joins a boundary for free only if the arrival gap
        # fits inside the stream's resident lifetime; past that, widening
        # implies a real wait of the excess gap, repaid once over every
        # resident round — so the marginal per-member gain must beat
        # (tau − residency)/rounds for the extra width to be worth
        # holding open.  Under saturating or bursty arrivals the wait
        # term vanishes and both parts agree on the knee.
        horizon = (sum(remainders) / len(remainders) if remainders
                   else default_horizon)
        rounds = max(float(ceil_passes(int(horizon), group)), 1.0)
        if self.kv is not None and remainders:
            # KV residency tracked: price the wait against the batch's
            # *measured* residency — a round at the candidates' actual
            # width, not the width-2 probe (the footprint the tracker
            # holds is exactly this width's worth of resident caches)
            p_round = self.perf.p0_decode(stage, pu,
                                          max(len(remainders), 2), group)
        threshold = 0.0
        if tau is not None:
            threshold = max(tau - rounds * p_round, 0.0) / rounds
        waitable = 1
        for w, gain in gains:
            if gain <= threshold:
                break
            waitable = w
        ready = len(remainders) if remainders else 0
        cap = max(waitable, min(ready, knee))
        return max(cap, 2)    # a batch needs two members to exist at all

    # -- coalesce cap (batchable stages) -----------------------------------
    def coalesce_cap(self, stage: str, pu: Optional[str] = None) -> int:
        """Knee of the profiled per-item curve — merged dispatches stay on
        measured sweet-spot shapes instead of running out to an arbitrary
        constant.  ``pu`` pins the curve when the mapper already knows the
        target; otherwise the stage's anchor (fastest) PU is used."""
        if pu is None or not self.perf.supported(stage, pu):
            pu = self._anchor_pu(stage)
        if pu is None:
            return self.cfg.coalesce_cap
        key = (stage, pu)
        if key in self._cap_cache:
            return self._cap_cache[key]
        cap, best = None, float("inf")
        for n, _gain in self.perf.batch_marginal_gains(stage, pu):
            t = self.perf.per_item(stage, pu, n)
            if t < best:
                cap, best = n, t
        cap = cap if cap is not None else self.cfg.coalesce_cap
        self._cap_cache[key] = cap
        return cap

    # -- coalesce window ---------------------------------------------------
    WINDOW_FAIRNESS = 4.0      # inter-arrival periods one dispatch may hold
    WINDOW_MAX_PASSES = 8      # τ → 0 ladder top (saturation)

    def coalesce_window(self, stage: str, tau: Optional[float]) -> int:
        """Total workload one fused dispatch may absorb, from the fitted
        per-dispatch overhead versus the observed inter-arrival rate.

        Absorbing a member saves one invocation overhead ``o`` but
        extends the dispatch's PU occupancy; the window therefore admits
        as many cap-sized passes as fit in ``WINDOW_FAIRNESS`` arrival
        periods — under sparse arrivals the fused dispatch must not hold
        the PU past the point where a newly-arrived query would starve
        behind it, while under saturation (τ → 0, or τ below the pass
        time + amortized overhead) the queue is service-bound and the
        window opens to the ladder top.
        """
        cap = self.coalesce_cap(stage)
        pu = self._anchor_pu(stage)
        if pu is None or tau is None:
            return cap * self.WINDOW_MAX_PASSES
        p_pass = self.perf.p0(stage, pu, cap)
        o = self.perf.dispatch_overhead(stage, pu)
        budget = self.WINDOW_FAIRNESS * (p_pass + o)
        passes = int(budget / max(tau, 1e-9))
        return cap * min(max(passes, 1), self.WINDOW_MAX_PASSES)

    # -- decode rounds: per-round group (horizon policy) -------------------
    def round_group_candidates(self, node: Node) -> Optional[Sequence[int]]:
        """Grid groups aligned to the sorted member remainders.

        Instead of padding ragged tails to a fixed ladder, the candidates
        are the profiled groups nearest the shortest member's remaining
        tokens, the median remainder, and the full horizon — the
        scheduler's Eq. 3 pass then scores them by mean member completion
        (see :meth:`round_passes`), trading a short straggler's early
        leave against the per-round overhead of extra boundaries.
        """
        rem = self._remainders(node)
        if rem is None:
            return None
        pu = (node.payload.get("prefer_pu")
              or self._anchor_pu(node.stage, self.cfg.token_group))
        grid = self.perf.decode_group_grid(node.stage, pu) if pu else ()
        if not grid:
            grid = (self.cfg.token_group, self.cfg.token_group * 2,
                    self.cfg.token_group * 4)
        anchors = (rem[0], rem[len(rem) // 2], rem[-1])
        cands = set()
        for r in anchors:
            below = [g for g in grid if g <= r]
            cands.add(below[-1] if below else grid[0])
        return sorted(min(g, max(node.workload, 1)) for g in cands)

    # completion quantile the "quantile" round scoring charges (p99-aware:
    # with ≤ 8 residents this is the slowest member, the tail the mixed
    # sparse-arrival regime loses on)
    ROUND_QUANTILE = 0.9

    def round_passes(self, node: Node, batch: int) -> float:
        """Member completion in rounds at group ``batch``.

        ``round_score="mean"`` (default): Σ⌈rᵢ/g⌉/w — the fixed policy
        charges the *longest* member's horizon to every candidate, which
        pads ragged tails; weighting by each resident's own remainder
        makes a group that releases short members at the next boundary
        score exactly as much better as the latency it reclaims.

        ``round_score="quantile"``: a high quantile
        (:data:`ROUND_QUANTILE`) of the member completions instead — the
        p99-aware variant: optimizing the mean trades the slowest
        member's finish for early leaves, exactly the mixed@2.0 p99 gap;
        scoring the tail keeps groups aligned to the members that define
        it."""
        rem = self._remainders(node)
        if rem is None:
            return ceil_passes(node.workload, batch)
        passes = sorted(ceil_passes(r, batch) for r in rem)
        if getattr(self.cfg, "round_score", "mean") == "quantile":
            k = min(int(self.ROUND_QUANTILE * len(passes)), len(passes) - 1)
            return float(passes[k])
        return sum(passes) / len(passes)

    # -- speculative decoding ----------------------------------------------
    # widths tried per (pair, alpha-bucket): the top of the accept-rate-
    # aware effective-throughput ranking over the profiled grid
    SPEC_TOP_WIDTHS = 2
    # alpha is bucketed for the cache key: the ranking is a step function
    # of alpha, so a coarse quantization keeps the hot loop table-driven
    SPEC_ALPHA_BUCKETS = 20

    def spec_width_candidates(self, draft_stage: str, verify_stage: str,
                              draft_pu: str, verify_pu: str,
                              alpha: float) -> Sequence[int]:
        """The (draft_width, verify_group) dual of the adaptive width
        cap: rank the profiled draft-width grid by accept-rate-aware
        effective throughput ``(1 + alpha·w) / cost(w)`` — cost pipelined
        (max) cross-PU, serialized (sum) on a shared PU — and enumerate
        the top few, letting Eq. 3's scoring pick between them per token
        group.  Falls back to the fixed policy's single width when the
        pair was never profiled."""
        a = max(min(float(alpha), 1.0), 0.0)
        bucket = int(a * self.SPEC_ALPHA_BUCKETS)
        key = (draft_stage, verify_stage, draft_pu, verify_pu, bucket)
        cached = self._spec_cache.get(key)
        if cached is not None:
            return cached
        grid = self.perf.spec_width_grid(draft_stage, verify_stage,
                                         draft_pu, verify_pu)
        if not grid:
            out = FixedBatchPolicy.spec_width_candidates(
                self, draft_stage, verify_stage, draft_pu, verify_pu, a)
            self._spec_cache[key] = out
            return out
        a_mid = (bucket + 0.5) / self.SPEC_ALPHA_BUCKETS
        ranked = sorted(
            grid, key=lambda w: -(self.perf.spec_throughput(
                draft_stage, verify_stage, draft_pu, verify_pu, w, a_mid)
                or 0.0))
        out = tuple(sorted(ranked[:self.SPEC_TOP_WIDTHS]))
        self._spec_cache[key] = out
        return out

    @staticmethod
    def _remainders(node: Node) -> Optional[List[int]]:
        """Sorted member remainders of a decode round: the ``remaining``
        snapshot ``fuse_decode`` records (refreshed by the scheduler when
        a cancelled round re-enters the pool), falling back to the live
        member workloads for rounds built outside the normal path."""
        rem = node.payload.get("remaining")
        if rem:
            return list(rem)
        members = node.payload.get("members")
        if not members:
            return None
        return sorted(m.workload for m in members)


def make_policy(cfg, perf: LinearPerfModel, kv=None):
    """Resolve ``SchedulerConfig.batch_policy`` to a policy object
    (``kv``: the scheduler's KV-residency tracker, when enabled)."""
    kinds = {"fixed": FixedBatchPolicy, "adaptive": AdaptiveBatchPolicy}
    name = getattr(cfg, "batch_policy", "fixed")
    if name not in kinds:
        raise KeyError(f"batch_policy {name!r}; pick from {sorted(kinds)}")
    score = getattr(cfg, "round_score", "mean")
    if score not in ("mean", "quantile"):
        raise KeyError(f"round_score {score!r}; pick from "
                       f"['mean', 'quantile']")
    return kinds[name](cfg, perf, kv)
