"""Shape-aware sub-stage partition (paper §4.2, Eq. 3).

For batchable stages: pick n* = argmin_{n ∈ N_{m,k}} ⌈L/n⌉ · p⁰(n,k) over the
offline-profiled candidate batch set, then split the node into ⌈L/n*⌉
sub-stages of ≤ n* items each (downstream nodes can start as soon as the
sub-stages they actually depend on finish).

For streaming stages: token-group granularity — decode nodes split into
groups of g tokens so downstream stages trigger once their data dependency
(a prefix of the stream) is satisfied.
"""
from __future__ import annotations

from typing import List, Optional, Sequence, Tuple

from repro.core.dag import DONE, READY, DynamicDAG, Node
from repro.core.perf_model import LinearPerfModel

DEFAULT_BATCH_CANDIDATES = (1, 2, 4, 8, 16, 32, 64, 128, 256)
DEFAULT_TOKEN_GROUPS = (4, 8, 16, 32)


def ceil_passes(workload: int, batch: int) -> int:
    """⌈L/n⌉ passes of a dispatch at batch n, with the ≥1 floors every
    dispatch site needs — THE shared definition: scheduler ETAs, the
    simulator, and the live runtime must agree on it or their queue
    estimates silently diverge."""
    return -(-max(workload, 1) // max(batch, 1))


def dispatch_passes(node: "Node", batch: int) -> int:
    """Passes ONE dispatch of ``node`` actually executes — the quantity
    straggler ETAs and busy-PU estimates must use.

    A continuous-batching decode round serves exactly one token-group
    boundary per dispatch — one pass, never ⌈horizon/n⌉.  (The round's
    workload normally arrives pre-trimmed to the group, but a round
    re-entering the pool after a live-mode straggler cancellation carries
    a stale trim while its partially-decoded residents have advanced —
    ⌈L/n⌉ over that horizon overestimated the drain and made cancelled
    rounds look slow enough to defer or migrate for no reason.)"""
    if node.payload.get("decode_round"):
        return 1
    return ceil_passes(node.workload, batch)


def fused_boundary_index(workloads: Sequence[int], done_frac: float) -> int:
    """Members to KEEP when splitting a fused dispatch at its next member
    boundary, given the fraction of its total work already executed.

    Members execute in stored order, so the boundary nearest the true
    progress point is the first index whose cumulative workload reaches
    ``done_frac`` of the total — the in-progress member finishes (its
    partial work is never discarded), everything after it is releasable.
    Always keeps at least one member; ``done_frac ≥ 1`` keeps all (the
    dispatch is effectively finished — nothing left to release)."""
    total = sum(max(w, 1) for w in workloads)
    target = min(max(done_frac, 0.0), 1.0) * total
    cum = 0
    for i, w in enumerate(workloads):
        cum += max(w, 1)
        if cum >= target:
            return max(i + 1, 1)
    return max(len(workloads), 1)


def best_batch(perf: LinearPerfModel, stage: str, pu: str, L: int,
               candidates: Sequence[int] = DEFAULT_BATCH_CANDIDATES
               ) -> Tuple[int, float]:
    """Eq. 3: argmin_n ⌈L/n⌉ · p⁰_v((n, k))."""
    best_n, best_t = 1, float("inf")
    for n in candidates:
        if n > L:
            n = L
        t = -(-L // n) * perf.p0(stage, pu, n)
        if t < best_t:
            best_n, best_t = n, t
    return best_n, best_t


def shape_aware_configs(perf: LinearPerfModel, node: Node, pu: str,
                        candidates: Sequence[int] = DEFAULT_BATCH_CANDIDATES,
                        token_groups: Sequence[int] = DEFAULT_TOKEN_GROUPS,
                        cap: Optional[int] = None) -> List[int]:
    """The small candidate config set Alg. 1 enumerates for (v, k).

    ``cap`` bounds the largest batch config enumerated — fused
    (cross-query coalesced) nodes cap at the top of the profiled grid so
    merged dispatches stay on measured shapes."""
    if not perf.supported(node.stage, pu):
        return []
    L = node.workload
    if cap is not None:
        candidates = [c for c in candidates if c <= cap] or [cap]
    if node.kind == "batchable":
        n, _ = best_batch(perf, node.stage, pu, L, candidates)
        # n* plus neighbours lets the mapper trade shape vs contention
        cands = {min(n, L), min(2 * n, L), max(1, n // 2)}
        if cap is not None:
            cands = {min(c, cap) for c in cands}
        return sorted(cands)
    if node.kind == "stream_decode":
        return [min(g, L) for g in token_groups if g <= max(L, 4)][:3] or [L]
    return [L]  # prefill / search / io run whole


def partition_node(dag: DynamicDAG, node: Node, perf: LinearPerfModel,
                   pu: str, candidates: Sequence[int] = DEFAULT_BATCH_CANDIDATES,
                   ) -> List[Node]:
    """Split a batchable node into ⌈L/n*⌉ sub-stages (Eq. 3) for PU ``pu``.

    Successor edges are preserved conservatively (every successor depends on
    every sub-stage) unless the successor is itself partitionable per item —
    the workflow builders create per-item edges where semantics allow
    (e.g. first search need not wait for later rewrites, §3.1)."""
    if node.kind != "batchable" or node.status != READY:
        return [node]
    n_star, _ = best_batch(perf, node.stage, pu, node.workload, candidates)
    if n_star >= node.workload:
        return [node]
    subs: List[Node] = []
    remaining = node.workload
    succ = list(dag.successors(node.id))
    i = 0
    while remaining > 0:
        take = min(n_star, remaining)
        sub = Node(id=dag.fresh_id(f"{node.id}.p"), stage=node.stage,
                   kind=node.kind, workload=take, deps=set(node.deps),
                   template=node.template, group=node.group or node.id)
        dag.add(sub)
        for s in succ:
            dag.add_edge(sub.id, s.id)
        subs.append(sub)
        remaining -= take
        i += 1
    # retire the original node (it was never dispatched)
    node.workload = 0
    node.status = DONE
    node.finish = node.start = 0.0
    for s in succ:
        s.deps.discard(node.id)
        dag._refresh_status(s)
    return subs
