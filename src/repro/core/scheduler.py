"""HeRo online heterogeneous scheduler — paper Alg. 1.

Node-centric dispatch: at every scheduling point (a completion event, or
new work arriving), the scheduler walks the ready set in criticality order
(Eq. 4), enumerates shape-aware configs per capable idle PU (Eq. 3), prunes
those violating the soft bandwidth budget, scores the rest with the
contention penalty (Eq. 5), and dispatches the argmin.  If the most
critical node has no feasible config it is deferred and the next one tried.

The techniques toggle independently (``SchedulerConfig``) which is
exactly what Table 3 ablates:
  - enable_partition    → Eq. 3 sub-stage partitioning
  - enable_criticality  → Eq. 4 priority (off = FIFO + earliest-finish)
  - enable_concurrency  → Eq. 5 penalty + B_soft gate (off = always admit)
  - coalesce            → cross-query batch coalescing (the dual of Eq. 3:
    READY batchable nodes of *different* queries sharing a (stage, kind)
    key merge into one fused dispatch — weight sweeps and per-invocation
    overheads are paid once for the whole group, the way Agent.xpu /
    RAGDoll batch concurrent requests on a shared accelerator)
``static_map`` pins stages to PUs (the llama.cpp-GPU / Powerserve-NPU /
Ayo-like baselines).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core import concurrency as cc
from repro.core import criticality as crit
from repro.core.batch_policy import ArrivalTracker, make_policy
from repro.core.dag import (READY, RUNNING, DynamicDAG, Node,
                            WorkflowTemplate, resolve_prefer_pu)
from repro.core.kv_pages import PagedKVCache
from repro.core.kv_residency import KVResidency, _kv_members
from repro.core.partitioner import (ceil_passes, dispatch_passes,
                                    shape_aware_configs)
from repro.core.perf_model import LinearPerfModel
from repro.core.spec_decode import SpecTracker, draft_stage_of, spec_passes


# Boolean SchedulerConfig knobs that legitimately default ON: the paper's
# baseline HeRo strategy (partition + criticality + concurrency control)
# and continuous decode batching, which is a no-op until ``coalesce``
# gates it.  Every OTHER boolean knob is a feature gate and must default
# off so a default config stays bit-identical to the PR 2/3 goldens —
# repro.analysis.lint rule CFG001 enforces exactly this list.
BASELINE_ON_KNOBS = frozenset({
    "enable_partition", "enable_criticality", "enable_concurrency",
    "decode_batch",
})


@dataclass
class SchedulerConfig:
    alpha: float = 0.35            # contention-penalty weight (grid-searched)
    beta: float = 0.6              # future-criticality weight (grid-searched)
    b_soft_frac: float = 0.90      # B_soft = frac · B0
    enable_partition: bool = True
    enable_criticality: bool = True
    enable_concurrency: bool = True
    static_map: Optional[Dict[str, str]] = None    # stage -> pu name
    token_group: int = 16
    # fault tolerance: re-dispatch a node when its runtime exceeds
    # straggler_factor × predicted latency (speculative execution)
    straggler_factor: float = 3.0
    # cross-query batch coalescing (multi-query serving; off for the
    # paper's single-query latency protocol)
    coalesce: bool = False
    # largest merged batch config enumerated — the top of the profiled
    # grid; within it Eq. 3 and the spill term pick the PU's sweet spot
    coalesce_cap: int = 256
    # max total workload absorbed into one fused dispatch: bounds how long
    # a single dispatch can occupy a PU (tail-latency fairness)
    coalesce_window: int = 512
    # continuous decode batching (vLLM/RAGDoll-style): stream_decode nodes
    # of different admitted queries share a resident per-(stage, PU) batch
    # at token-group granularity, with join/leave at group boundaries.
    # Effective only under ``coalesce`` (the multi-query serving mode).
    decode_batch: bool = True
    # max resident sequences per decode batch (profiled width grid top)
    decode_batch_cap: int = 8
    # seconds charged when a resident batch's next round moves PU (KV-cache
    # migration); keeps batches sticky per (stage, PU) unless moving wins.
    # The legacy constant — superseded by the modeled cost when
    # ``kv_residency`` is on, and the fallback when a loaded profile
    # predates the migration grid
    decode_migrate_cost: float = 0.01
    # per-stream KV-residency tracking (core/kv_residency.py): decode-round
    # PU moves are priced by the modeled migration cost (resident footprint
    # ÷ profiled PU-pair link bandwidth, φ-scaled) instead of the constant
    # above, and both backends register/charge the migrations
    # (kv_migrations / kv_bytes_moved in results).  Off = the legacy
    # constant and free migration physics, bit-identical to the
    # PR 2/3/4 goldens.
    kv_residency: bool = False
    # paged KV cache (core/kv_pages.py): supersedes the monolithic
    # kv_residency tracker with a page table — fixed-size pages in a
    # tiered store (PU arenas → DRAM pool → disk, LRU-with-pin eviction),
    # page-granular migration pricing, and a content-hash prefix cache
    # that lets prefill nodes skip resident shared-context pages
    # (cross-query reuse).  Implies residency tracking; off = bit-identical
    # to the PR 2/3/5 goldens (kv_residency decides the tracker as before)
    kv_pages: bool = False
    # tokens per KV page (page bytes = this × the stage's profiled GQA
    # cache bytes/token)
    kv_page_tokens: int = 64
    # predictive prefetch on the paged tiers (PerCache staging / RAGDoll
    # fetch-compute overlap): after each dispatch pass commits compute,
    # the scheduler pre-stages the spill-resident pages of admitted
    # prefill hits and ready-but-deferred decode streams up to their
    # anchor PU, crediting the fitted fetch time against the committed
    # compute window instead of paying it on the dispatch critical path;
    # eviction becomes hit-frequency-weighted.  Requires ``kv_pages``;
    # off = bit-identical to the PR 6 paging behaviour.
    kv_prefetch: bool = False
    # migration pricing under kv_residency: "modeled" (footprint ÷ link
    # bandwidth) or "constant" (keep the legacy constant while still
    # tracking and charging real transfers — the mischarging baseline the
    # migration-heavy bench regime pits the model against)
    migrate_pricing: str = "modeled"
    # decode-round scoring under the adaptive policy: "mean" member
    # completion (PR 4) or "quantile" (p99-aware: score by a high quantile
    # of member completion, targeting the mixed sparse-arrival tail)
    round_score: str = "mean"
    # batching-cap policy: "fixed" uses the three constants above verbatim
    # (bit-identical to the pre-adaptive scheduler, pinned against
    # committed goldens); "adaptive" derives coalesce/decode caps, the
    # coalesce window, and per-round token groups online from the
    # profiled (width, group) / batch grids (core/batch_policy.py)
    batch_policy: str = "fixed"
    # preemptible fused dispatches: when a higher-SLO-class node is left
    # READY after a pass, an in-flight fused batchable dispatch of lower
    # class may be split at its next member boundary (members past the
    # boundary return READY with their state in place) instead of the
    # cancel-and-redispatch path, which discards completed work and pays
    # a modeled migration.  Off = fused dispatches run whole
    # (bit-identical to the PR 2-7 goldens).
    preempt: bool = False
    # SLO-class, tail-aware admission: nodes carry a query class
    # ("interactive" | "batch"); interactive candidates pierce the Eq. 5
    # gate's batched-mode stand-down, and batch candidates defer while
    # interactive work waits — bounded by the throughput floor below.
    # Off = class-blind admission (bit-identical goldens).
    slo_admission: bool = False
    # throughput floor for batch deferral, in units of the batch class's
    # tracked inter-arrival tau: a deferred batch node that has waited
    # longer than slo_floor_mult × tau dispatches regardless, so batch
    # throughput degrades boundedly under interactive pressure
    slo_floor_mult: float = 4.0
    # speculative decoding (core/spec_decode.py): every decode round may
    # dispatch as a coupled (draft, verify) pair — a small draft model
    # streams ``w`` candidate tokens per verify pass on a possibly
    # *different* PU while the target scores the previous group in one
    # weight sweep, compressing a ``g``-token round into
    # ceil(g / (1 + alpha·w)) passes at the stream's observed accept
    # rate.  Rounds only (requires ``coalesce`` + ``decode_batch``);
    # off = bit-identical to the PR 8 goldens.
    spec_decode: bool = False
    # draft-model registry key (rag.stages.DRAFT_MODELS); None keeps the
    # catalog default the stage set was built with
    draft_model: Optional[str] = None
    # draft width w under the fixed batching policy (candidates proposed
    # per verify pass); the adaptive policy enumerates the profiled
    # (draft_width, verify_group) grid instead
    spec_draft_width: int = 4
    # accept-rate EWMA: prior for never-observed streams (the profiled
    # pair prior wins when the perf model carries one) and the per-round
    # fold-in weight
    spec_accept_init: float = 0.6
    spec_accept_alpha: float = 0.3


@dataclass
class Dispatch:
    node: Node
    pu: str
    batch: int
    predicted_p0: float
    bandwidth: float
    # modeled one-off KV-migration seconds this dispatch pays before its
    # passes start (0 without a residency tracker): both backends add it
    # to the dispatch ETA, and busy-PU candidates see it in busy_until —
    # a queued placement no longer looks cheaper than it is
    migrate_s: float = 0.0


class HeroScheduler:
    def __init__(self, perf: LinearPerfModel, pus: Sequence[str], b0: float,
                 cfg: Optional[SchedulerConfig] = None,
                 template: Optional[WorkflowTemplate] = None):
        self.perf = perf
        self.pus: List[str] = list(pus)      # elastic: may grow/shrink
        self.b0 = b0
        # a fresh config per scheduler: a shared default instance would leak
        # static_map (and any toggle mutation) across schedulers
        self.cfg = cfg if cfg is not None else SchedulerConfig()
        self.template = template
        self._fifo_seq: Dict[str, int] = {}
        self._seq = 0
        if self.cfg.migrate_pricing not in ("modeled", "constant"):
            raise KeyError(f"migrate_pricing {self.cfg.migrate_pricing!r}; "
                           f"pick from ['modeled', 'constant']")
        # KV tracker: per-stream cache placement + footprints, shared with
        # the DAG (boundary events) and the batching policy.  kv_pages
        # selects the page-table tracker (tiered store + prefix cache);
        # kv_residency the monolithic one; neither = the legacy constant
        if self.cfg.kv_pages:
            self.kv = PagedKVCache(perf,
                                   page_tokens=self.cfg.kv_page_tokens,
                                   prefetch=self.cfg.kv_prefetch)
        elif self.cfg.kv_residency:
            self.kv = KVResidency(perf)
        else:
            self.kv = None
        # batching policy (fixed constants vs online derivation from the
        # profiled grids) + the ready-pool inter-arrival EWMA it consults
        self.policy = make_policy(self.cfg, perf, kv=self.kv)
        self.arrivals = ArrivalTracker()
        # last-seen decode_rounds per resident id: detects boundary
        # re-entries (same node id, another ready-pool arrival)
        self._seen_rounds: Dict[str, int] = {}
        # SLO classes per admitted-query namespace (HeroSession fills this
        # from submit(slo=...)); nodes may also carry payload["slo"]
        self.slo_classes: Dict[str, str] = {}
        # first time each node entered the ready pool (slo_admission only:
        # feeds the batch-deferral throughput floor)
        self._ready_since: Dict[str, float] = {}
        # chosen-shape telemetry per dispatch (benchmarks report these):
        # histograms of resident decode widths, per-round token groups,
        # and fused batchable dispatch sizes
        self.policy_log: Dict[str, Dict[int, int]] = {
            "decode_width": {}, "decode_group": {}, "fused_batch": {}}
        # speculative decoding: online accept-rate tracker — per-stream
        # EWMA the round pricing consults, plus the run totals both
        # backends surface (the ``preemptions`` counter-protocol
        # contract).  The telemetry key is added only when the mode is
        # on so spec-off bench output stays bit-identical.
        if self.cfg.spec_decode:
            self.spec: Optional[SpecTracker] = SpecTracker(
                init=self.cfg.spec_accept_init,
                weight=self.cfg.spec_accept_alpha)
            self.policy_log["spec_width"] = {}
        else:
            self.spec = None

    # -- elastic PU membership (fault tolerance / scale up-down) -----------
    def add_pu(self, pu: str):
        if pu not in self.pus:
            self.pus.append(pu)

    def remove_pu(self, pu: str):
        if pu in self.pus:
            self.pus.remove(pu)

    # -- Alg. 1 -------------------------------------------------------------
    def dispatch_pass(self, dag: DynamicDAG, now: float,
                      idle_pus: Sequence[str], B_now: float,
                      busy_until: Optional[Dict[str, float]] = None,
                      ) -> List[Dispatch]:
        """One scheduling step.  ``busy_until``: estimated release time per
        busy PU — predicted completion F_v(c) is queue-aware, so a critical
        node *defers* for a fast busy PU instead of grabbing a slow idle one
        (the paper's "each stage executes on a single PU" default emerges
        from this, with migration only when genuinely beneficial)."""
        cfgn = self.cfg
        if self.kv is not None and dag.kv is not self.kv:
            # let decode-round boundaries and fuse_decode reach the tracker
            dag.kv = self.kv
        if self.spec is not None and dag.spec is not self.spec:
            # boundary accept counts (_finish_decode_round) feed the EWMA
            # the next round's speculative pricing reads
            dag.spec = self.spec
        crit.update_criticality(dag, self.perf, self.template, now,
                                beta=cfgn.beta if cfgn.enable_criticality
                                else 0.0)                       # line 4
        for n in dag.ready():
            if n.id not in self._fifo_seq:
                self._fifo_seq[n.id] = self._seq
                self._seq += 1
                self._seen_rounds[n.id] = n.payload.get("decode_rounds", 0)
                # ready-pool arrival: feeds the adaptive policy's
                # queueing-delay estimate
                if n.kind != "io":
                    self.arrivals.observe((n.stage, n.kind), now)
                if cfgn.slo_admission:
                    self._ready_since[n.id] = now
                    if n.kind != "io":
                        # per-class arrival rate: the batch class's tau
                        # bounds how long the deferral floor may hold a
                        # batch candidate back
                        self.arrivals.observe(("slo", self._slo_class(n)),
                                              now)
                if (n.kind == "stream_prefill"
                        and getattr(self.kv, "paged", False)):
                    # prefix cache: trim the prefill by its longest
                    # resident page-aligned prefix before any config is
                    # enumerated for it (first-seen = exactly once)
                    self.kv.apply_prefix_hits(n)
            elif (n.payload.get("decode_round")
                  and n.payload.get("members")):
                # a round back in the pool (live-mode straggler
                # cancellation): its workload still carries the previous
                # dispatch's group trim while the residents have advanced
                # — refresh the horizon (and the remainder snapshot the
                # group policy reads) from their true remaining tokens so
                # ETA and group choice see remaining work, not stale
                # padding
                n.payload["remaining"] = sorted(
                    m.workload for m in n.payload["members"])
                n.workload = n.payload["remaining"][-1]
            elif (n.payload.get("decode_rounds", 0)
                  != self._seen_rounds.get(n.id)):
                # a resident re-entering READY at a token-group boundary
                # keeps its node id but IS a fresh ready-pool arrival —
                # the next member a forming batch would wait for; without
                # this, tau freezes after initial admissions in
                # continuous serving
                self._seen_rounds[n.id] = n.payload.get("decode_rounds", 0)
                if n.kind != "io":
                    # boundary re-entry: a real arrival (tau must not
                    # freeze) but NOT a fresh-burst member — the batch's
                    # own boundary says nothing about new-stream rate
                    self.arrivals.observe((n.stage, n.kind), now,
                                          fresh=False)
        fused_new = self._coalesce(dag) if cfgn.coalesce else []
        # Eq. 5 protects a single query's critical path — the right
        # objective in the paper's one-query-at-a-time regime.  A fused
        # node in the graph (ready or in flight) means the scheduler is in
        # batched-serving mode (multiple admitted queries, saturating
        # arrivals): there throughput lives on overlapping work across
        # PUs, so the per-query contention terms stand down and only the
        # absolute B_soft budget (line 11) throttles admission — notably,
        # the gate must not defer the fused dispatch itself.
        batched_mode = False
        for n in dag.ready() + dag.running():
            if "members" in n.payload:
                batched_mode = True
                # a fused node has no successors of its own: its urgency
                # (dispatch order among ready candidates) is its most
                # critical member's, refreshed every pass
                n.criticality = max(m.criticality
                                    for m in n.payload["members"])
        idle = [p for p in idle_pus if p in self.pus or p == "io"]
        busy_until = dict(busy_until or {})
        r_tmp = list(dag.ready())                               # line 5
        decisions: List[Dispatch] = []
        b_soft = cfgn.b_soft_frac * self.b0

        while idle and r_tmp:                                   # line 6
            # absorbed members of an in-flight fused dispatch are RUNNING
            # with config=None — only the fused node (which carries their
            # max criticality and the real config) represents that work
            # here, so members are excluded from the running pool
            running = [n for n in dag.running() if n.config is not None
                       or "fused_into" not in n.payload]
            pool = dag.ready() + running
            v_star = max(pool, key=lambda n: n.criticality,
                         default=None) if pool else None        # line 7
            running_star = (v_star if v_star is not None
                            and v_star.status == RUNNING else
                            next(iter(sorted(running,
                                             key=lambda n: -n.criticality)),
                                 None))
            gate_star = None if batched_mode else running_star
            if cfgn.enable_criticality:
                v_cand = max(r_tmp, key=lambda n: n.criticality)  # line 8
            else:
                v_cand = min(r_tmp, key=lambda n: self._fifo_seq.get(n.id, 0))

            if v_cand.kind == "io":
                # external calls bypass the PU perf model entirely; a node
                # carrying an absolute ``arrival`` payload is an admission
                # timer (HeroSession multi-query) whose remaining delay is
                # its predicted latency
                if "io" in idle:
                    arr = v_cand.payload.get("arrival")
                    p_io = max(arr - now, 0.0) if arr is not None else 0.35
                    dag.mark_running(v_cand.id, now, ("io", 1))
                    decisions.append(Dispatch(v_cand, "io", 1, p_io, 0.0))
                    idle.remove("io")
                r_tmp.remove(v_cand)
                continue

            if (cfgn.slo_admission and self._slo_rank(v_cand) == 0
                    and self._defer_batch(v_cand, r_tmp, idle, now)):
                # batch class stands aside while interactive work waits
                # for a PU it could use — until the throughput floor
                # (slo_floor_mult × batch-class tau) says it has waited
                # long enough
                r_tmp.remove(v_cand)
                continue
            gate_v = self._gate_for(v_cand, gate_star, running_star,
                                    batched_mode) \
                if cfgn.slo_admission else gate_star

            best: Optional[Tuple[float, Dispatch, bool, Optional[Dict]]] \
                = None
            capable = self._capable_pus(v_cand, idle + list(busy_until))
            # speculative decoding precondition for this candidate: a
            # decode round whose stage has a profiled draft companion.
            # alpha is the mean tracker estimate over the member streams
            # (profiled pair prior for never-observed streams).
            spec_ds: Optional[str] = None
            spec_alpha = 0.0
            spec_wpin: Optional[int] = None
            if self.spec is not None and v_cand.payload.get("decode_round"):
                ds0 = draft_stage_of(v_cand.stage)
                mems = v_cand.payload.get("members") or [v_cand]
                # typed per-stage pins (StageSpec.decode = DecodeSpec):
                # a stage pinned to a different draft family than the
                # session's opts out of speculation rather than run under
                # the wrong draft; a width pin bypasses the policy search
                dspec = next((m.payload.get("decode_spec") for m in mems
                              if m.payload.get("decode_spec") is not None),
                             None)
                dm = getattr(dspec, "draft_model", None)
                if dm is not None and self.cfg.draft_model not in (None, dm):
                    ds0 = None
                spec_wpin = getattr(dspec, "draft_width", None)
                prior = (self.perf.spec_accept_init(ds0, v_cand.stage)
                         if ds0 is not None else None)
                if prior is not None:
                    spec_ds = ds0
                    spec_alpha = sum(
                        self.spec.alpha(m.group or m.id, prior)
                        for m in mems) / len(mems)
            # resident decode batch: Eq. 3 enumerates configs at the batch's
            # *current* width, and moving PU pays the KV-migration cost
            width = (v_cand.payload.get("decode_width", 1)
                     if v_cand.payload.get("decode_round") else 1)
            prefer_pu = v_cand.payload.get("prefer_pu")
            for pu in capable:                                  # line 9
                is_idle = pu in idle
                start = now if is_idle else max(now, busy_until[pu])
                for batch in self._configs(v_cand, pu):         # line 10
                    if width > 1:
                        b = self.perf.bandwidth_decode(v_cand.stage, pu,
                                                       width, batch)
                    else:
                        b = self.perf.bandwidth(v_cand.stage, pu, batch)
                    b_active = B_now + sum(x.bandwidth for x in decisions)
                    if is_idle and cfgn.enable_concurrency and \
                            b_active > 0 and cc.violates_budget(
                                b_active, b, b_soft):           # line 11
                        # (gate only actual *concurrency*: a lone stage may
                        # exceed B_soft — waiting cannot help it)
                        continue
                    if width > 1:
                        p0 = self.perf.p0_decode(v_cand.stage, pu, width,
                                                 batch)
                    else:
                        p0 = self.perf.p0(v_cand.stage, pu, batch)
                    phi = self.perf.phi(v_cand.stage, B_now + b)
                    if v_cand.payload.get("decode_round"):
                        # rounds amortize over the residents' remaining
                        # horizon: fixed charges the longest member to
                        # every candidate, adaptive weighs each member's
                        # own remainder (mean completion — the horizon
                        # policy's scoring)
                        passes = self.policy.round_passes(v_cand, batch)
                    else:
                        passes = ceil_passes(v_cand.workload, batch)
                    f_cand = start + passes * p0 * phi          # line 12 (Eq. 2)
                    w_b = cc.contention_penalty(
                        self.perf, gate_v, b, B_now, now
                    ) if (cfgn.enable_concurrency and is_idle) else 0.0
                    score = f_cand + cfgn.alpha * w_b           # line 13 (Eq. 5)
                    mig_s = 0.0
                    if self.kv is not None:
                        # migration priced per stream from tracked
                        # residency — rounds AND solo token-group chains
                        # (which the legacy constant never priced and
                        # which hop PUs freely without it).  f_cand
                        # already amortizes over the remaining horizon,
                        # so the one-off transfer is weighed against the
                        # whole stay: work migrates exactly when the
                        # destination's latency win repays the copy.
                        # The charge rides the Dispatch (migrate_s) so
                        # backend ETAs and busy_until see it too — a
                        # busy-PU candidate queues behind the pending
                        # migration, not just the compute passes.
                        if v_cand.kind == "stream_decode":
                            mig_s = self._migrate_score(v_cand, pu,
                                                        B_now + b)
                            score += mig_s
                    elif (width > 1 and prefer_pu is not None
                          and pu != prefer_pu):
                        # legacy constant: a pure score nudge, never an
                        # ETA term (bit-exact with the kv-off goldens)
                        score += cfgn.decode_migrate_cost
                    if (cfgn.preempt and "members" not in v_cand.payload
                            and v_cand.payload.get("preempt_prefer_pu")
                            is not None
                            and not (self.kv is not None
                                     and v_cand.kind == "stream_decode")):
                        # residency-aware re-placement of a preempted
                        # member: its state stayed put, so anchor to the
                        # KV-resident PU when the tracker knows one, else
                        # the PU it was split off.  A score nudge only
                        # (no ETA term) — stream_decode under a tracker
                        # is excluded because mig_s already prices the
                        # move from true residency.
                        anchor = v_cand.payload["preempt_prefer_pu"]
                        if self.kv is not None:
                            rp = self.kv.resident_pu(v_cand)
                            if rp is not None:
                                anchor = rp
                        if pu != anchor:
                            score += cfgn.decode_migrate_cost
                    d = Dispatch(v_cand, pu, batch, p0, b, mig_s)
                    if best is None or score < best[0]:
                        best = (score, d, is_idle, None)
                    if spec_ds is not None and is_idle:
                        sp = self._spec_plan(v_cand, spec_ds, spec_alpha,
                                             pu, batch, width, idle, start,
                                             B_now, b_active, b_soft,
                                             gate_v, mig_s, now,
                                             wpin=spec_wpin)
                        if sp is not None and (best is None
                                               or sp[0] < best[0]):
                            best = (sp[0], sp[1], True, sp[2])
            if best is None or not best[2]:                     # line 15
                # infeasible now, or better to queue for a busy PU: defer
                r_tmp.remove(v_cand)
                continue
            _, d, _, spec_meta = best
            if (cfgn.enable_concurrency and gate_v is not None
                    and gate_v.id != d.node.id
                    and gate_v.config
                    and gate_v.config[0] != "io"):
                # Eq. 5 admission gate: parallelism is admitted only when it
                # does not significantly impede critical-path progress —
                # defer when the contention damage to v* exceeds the overlap
                # benefit (the candidate's own runtime).
                phi0 = self.perf.phi(gate_v.stage, B_now)
                phi1 = self.perf.phi(gate_v.stage,
                                     B_now + d.bandwidth)
                sp, sb = gate_v.config
                p_star = (self.perf.p0(gate_v.stage, sp, sb)
                          * ceil_passes(gate_v.workload, sb))
                damage = (phi1 - phi0) * p_star
                # dispatch_passes: a decode round's overlap benefit is
                # one token-group pass, not the residents' whole horizon
                # (which is served across later rounds)
                benefit = d.predicted_p0 * dispatch_passes(d.node, d.batch)
                if cfgn.alpha * damage > benefit:
                    r_tmp.remove(v_cand)
                    continue
            piece = self._take_substage(dag, d.node, d.batch)   # Eq. 3 split
            d = dataclasses.replace(d, node=piece)
            if spec_meta is not None:
                self._stamp_spec(piece, spec_meta)
            dag.mark_running(piece.id, now, (d.pu, d.batch))    # line 17
            self._log_choice(piece, d.batch)
            decisions.append(d)
            idle.remove(d.pu)                                   # line 18-19
            passes = ceil_passes(piece.workload, d.batch)
            busy_until[d.pu] = now + passes * d.predicted_p0 + d.migrate_s
            if spec_meta is not None and spec_meta["dp"] != d.pu:
                # cross-PU plan: materialize the draft half as its own
                # dispatch occupying the draft PU for the round
                dd = self._spawn_draft(dag, piece, spec_meta, now)
                decisions.append(dd)
                if dd.pu in idle:
                    idle.remove(dd.pu)
                busy_until[dd.pu] = now + spec_meta["n"] * dd.predicted_p0
            r_tmp = [n for n in dag.ready() if n not in
                     [x.node for x in decisions]]
        if (cfgn.kv_prefetch and decisions
                and getattr(self.kv, "prefetch_on", False)):
            # lookahead hook: the pass just committed compute — overlap
            # the next dispatches' page staging with it
            self._prefetch_pass(dag, decisions, busy_until, now)
        for f in fused_new:
            if f.status == READY:       # never dispatched: dissolve so
                dag.unfuse(f)             # members stay schedulable
                self._fifo_seq.pop(f.id, None)
            elif f.payload.get("decode_round"):
                # dispatched rounds never consult the FIFO again, and one
                # fresh id is minted per token-group boundary — keeping
                # them would leak an entry per boundary in long-lived
                # continuous serving
                self._fifo_seq.pop(f.id, None)
        if cfgn.preempt:
            # `idle` has had every committed dispatch removed, so it is
            # exactly the capacity left over after this pass
            self._preempt_pass(dag, decisions, now, idle)
        return decisions

    # -- SLO classes & preemption ------------------------------------------
    def _slo_class(self, node: Node) -> str:
        """A node's SLO class: its own payload stamp, else its admitted
        query's class (submit(slo=...)), else interactive — unclassified
        work keeps the latency-optimal treatment it always had."""
        cls = node.payload.get("slo")
        if cls is None:
            cls = self.slo_classes.get(self._query_key(node.id),
                                       "interactive")
        return cls

    def _slo_rank(self, node: Node) -> int:
        """Class priority (higher = more latency-sensitive).  A fused
        dispatch ranks as its most sensitive member — a fusion with any
        interactive member is never treated as preemptible batch work."""
        members = node.payload.get("members")
        if members:
            return max(self._slo_rank(m) for m in members)
        return 1 if self._slo_class(node) == "interactive" else 0

    def _defer_batch(self, v: Node, r_tmp: Sequence[Node],
                     idle: Sequence[str], now: float) -> bool:
        """Should batch-class candidate ``v`` stand aside this pass?
        Only while some interactive node is waiting for an idle PU that
        could actually serve it (deferring for unservable work is pure
        starvation), and only until ``v`` has waited past the throughput
        floor: ``slo_floor_mult`` × the batch class's inter-arrival tau.
        With no tau yet (fewer than two batch arrivals) the floor cannot
        be priced and interactive keeps priority."""
        waiting = [n for n in r_tmp
                   if n is not v and n.kind != "io"
                   and self._slo_rank(n) >= 1
                   and self._capable_pus(n, idle)]
        if not waiting:
            return False
        tau_b = self.arrivals.tau(("slo", "batch"))
        members = v.payload.get("members") or [v]
        # a preemption release restarts the member's deferral clock
        # (payload["preempt_t"]): the floor prices a full waiting window
        # from the split, not from the original arrival — otherwise a
        # released member's window is already spent and it re-dispatches
        # straight back into the contention it was split to relieve
        since = min(max(self._ready_since.get(m.id, now),
                        m.payload.get("preempt_t", 0.0))
                    for m in members)
        if tau_b is not None and (now - since) > \
                self.cfg.slo_floor_mult * tau_b:
            return False
        return True

    def _gate_for(self, v: Node, gate_star: Optional[Node],
                  running_star: Optional[Node],
                  batched_mode: bool) -> Optional[Node]:
        """Class-aware Eq. 5 gate: the candidate faces the contention
        gate only against running work of equal-or-higher class.  An
        interactive candidate pierces the gate a batch v* would impose;
        a batch candidate in batched mode loses the stand-down and faces
        the gate the running critical node imposes (batched_mode exists
        to protect cross-query throughput — batch-class work is exactly
        the traffic that may be throttled for it)."""
        rank = self._slo_rank(v)
        if gate_star is not None and rank > self._slo_rank(gate_star):
            return None
        if (gate_star is None and batched_mode and running_star is not None
                and running_star.config
                and running_star.config[0] != "io"
                and rank < self._slo_rank(running_star)):
            return running_star
        return gate_star

    def preempt_price(self, node: Node, now: float) -> float:
        """Modeled cost of splitting ``node`` at its next member
        boundary: zero — no completed member work is discarded, the
        in-progress member finishes, and released members' KV/state
        stays put (re-placement anchors to it)."""
        return 0.0

    def cancel_price(self, node: Node, now: float) -> float:
        """Modeled cost of the legacy cancel-and-redispatch: every
        second of completed work since dispatch is discarded, and each
        member pays a re-placement migration (the constant — cancel
        drops placement state, so the modeled per-stream price is not
        even available).  Strictly positive for any running dispatch,
        so preemption is always priced cheaper."""
        members = node.payload.get("members") or [node]
        lost = max(now - node.start, 0.0) if node.start >= 0 else 0.0
        return lost + self.cfg.decode_migrate_cost * len(members)

    def _preempt_pass(self, dag: DynamicDAG, decisions: List[Dispatch],
                      now: float, idle_left: Sequence[str]) -> None:
        """Flag in-flight fused batchable dispatches for a boundary
        split: if a higher-class node is still READY after this pass
        AND genuinely starved — no idle PU left that could serve it, so
        a running fusion on one of its capable PUs is what blocks it —
        that fusion gets ``payload["preempt_split"]`` whenever the split
        is priced cheaper than cancellation; the backend performs the
        split at the member boundary nearest its true progress (decode
        rounds already yield at token-group boundaries and are left
        alone).  A ready node that merely *deferred* for a busy fast PU
        while capable capacity sat idle is not starved — splitting for
        it would release members into pure contention churn."""
        dispatched = {d.node.id for d in decisions}
        blocked = [b for b in dag.ready()
                   if b.kind != "io" and b.id not in dispatched
                   and self._slo_rank(b) > 0
                   and not self._capable_pus(b, idle_left)]
        if not blocked:
            return
        for n in dag.running():
            if ("members" not in n.payload
                    or n.payload.get("decode_round")
                    or n.config is None or n.config[0] == "io"
                    or n.payload.get("preempt_split")
                    # bounded preemption: a member is released at most
                    # once — re-splitting a fusion of already-released
                    # members trades no new capacity for another round
                    # of re-dispatch churn (and its bandwidth contention
                    # is exactly what slows the interactive work the
                    # split is meant to protect)
                    or any(m.payload.get("preemptions", 0)
                           for m in n.payload["members"])):
                continue
            rank = self._slo_rank(n)
            for b in blocked:
                if (self._slo_rank(b) > rank
                        and n.config[0] in self._capable_pus(b, self.pus)
                        and self.preempt_price(n, now)
                        < self.cancel_price(n, now)):
                    n.payload["preempt_split"] = True
                    break

    # -- predictive prefetch ---------------------------------------------------
    def _prefetch_pass(self, dag: DynamicDAG, decisions: List[Dispatch],
                       busy_until: Dict[str, float], now: float) -> None:
        """Lookahead staging after a committed dispatch pass: the compute
        just dispatched opens an overlap window (the latest non-io
        ``busy_until`` minus ``now``, in modeled seconds); spend it
        pre-staging the spill-resident pages the *next* dispatches will
        gather — (a) admitted prefills whose prefix hit demoted pages
        stage those hits onto their own PU (the decode that adopts them
        anchors there), then (b) ready-but-deferred decode streams stage
        toward their anchor.  The transfer queue is serial, so one
        budget is debited sequentially across all stagings; dispatched
        decode rounds are NOT prefetched — their gather runs now, with
        no compute ahead of it to hide behind."""
        window = max((t for p, t in busy_until.items() if p != "io"),
                     default=now)
        budget = window - now
        if budget <= 0.0:
            return
        dispatched = {d.node.id for d in decisions}
        for d in decisions:
            if budget <= 0.0:
                return
            pids = d.node.payload.get("kv_hit_pages")
            if pids and d.pu != "io":
                budget -= self.kv.prefetch(d.node, d.pu, budget, pids=pids)
        for n in dag.ready():
            if n.kind != "stream_decode" or n.id in dispatched:
                continue
            for m in _kv_members(n):
                if budget <= 0.0:
                    return
                st = self.kv.tracked(m)
                if st is None:
                    continue
                dst = st.pu or m.payload.get("batch_pu")
                if dst is not None:
                    budget -= self.kv.prefetch(m, dst, budget)

    # -- cross-query coalescing ----------------------------------------------
    @staticmethod
    def _query_key(nid: str) -> str:
        """Admitted-query namespace of a node id (HeroSession prefixes
        shared-DAG nodes with ``q<i>/``; un-prefixed ids share one key)."""
        return nid.split("/", 1)[0] if "/" in nid else ""

    def _coalesce(self, dag: DynamicDAG) -> List[Node]:
        """Group READY batchable nodes that share a (stage, kind) key
        across different admitted queries and fuse each group into one
        dispatch unit.  The fused node then flows through the normal
        Alg. 1 machinery: ``shape_aware_configs`` enumerates tile-aligned
        merged configs (capped at ``coalesce_cap``) and the Eq. 5 gate
        prunes them like any other candidate.  Fusions that do not
        dispatch this pass are dissolved before returning.

        With ``decode_batch``, READY ``stream_decode`` nodes group the same
        way into *decode rounds* (continuous batching): each round serves
        one token group per resident stream, so membership is re-derived at
        every boundary — unfinished members return READY and re-fuse here,
        newly READY streams join, finished ones have already left."""
        cfgn = self.cfg
        groups: Dict[Tuple[str, str], List[Node]] = {}
        for n in dag.ready():
            if ("members" in n.payload or n.payload.get("no_coalesce")):
                continue
            if n.kind == "batchable" or (n.kind == "stream_decode"
                                         and cfgn.decode_batch):
                groups.setdefault((n.stage, n.kind), []).append(n)
        created: List[Node] = []
        for (_, kind), nodes in groups.items():
            if len({self._query_key(n.id) for n in nodes}) < 2:
                continue                   # cross-query only
            # most critical members first; the window bounds PU occupancy.
            # Oversized nodes are skipped (they dispatch solo) rather than
            # blocking fusion of the smaller nodes behind them.
            nodes.sort(key=lambda n: -n.criticality)
            stage = nodes[0].stage
            if kind == "stream_decode":
                # width-beyond-ready compares per-member marginal gains,
                # so it needs the burst-corrected per-member rate
                tau = self.arrivals.tau((stage, kind))
                # KV residency: the cap is derived at the PU the forming
                # round will anchor to (same resolution fuse_decode
                # stamps: agreement, or the largest tracked footprint
                # under conflicting history)
                prefer = resolve_prefer_pu(self.kv, nodes)
                cap = self.policy.decode_width_cap(
                    stage, prefer, tau, [n.workload for n in nodes])
                if self.policy.name == "adaptive":
                    # horizon policy: when the cap binds, prefer residents
                    # closest to leaving (shortest remaining first) so
                    # boundaries release members instead of padding them
                    nodes.sort(key=lambda n: n.workload)
                take = nodes[:cap]
                if len({self._query_key(n.id) for n in take}) < 2:
                    continue
                fused = dag.fuse_decode(take)
            else:
                # the window bounds occupancy until the next arrival
                # *event* (a burst's latecomers starve together, not b×
                # faster), so it keeps the raw gap estimate
                tau = self.arrivals.tau_event((stage, kind))
                window = self.policy.coalesce_window(stage, tau)
                take = []
                total = 0
                for n in nodes:
                    if total + n.workload > window:
                        continue
                    take.append(n)
                    total += n.workload
                if len({self._query_key(n.id) for n in take}) < 2:
                    continue
                fused = dag.fuse_ready(take)
            self._fifo_seq[fused.id] = min(
                self._fifo_seq.get(n.id, self._seq) for n in take)
            created.append(fused)
        return created

    # -- helpers -------------------------------------------------------------
    def _migrate_score(self, node: Node, pu: str, B: float) -> float:
        """Eq. 5 addend for serving round ``node`` on ``pu`` given tracked
        KV residency: the modeled transfer cost of every member whose
        cache lives elsewhere (φ-scaled — the copy rides the shared bus),
        or the legacy constant under ``migrate_pricing="constant"`` / a
        profile without the migration grid."""
        pen = self.kv.migrate_penalty(node, pu, B)
        if pen is None:                  # pre-residency profile: no grid
            prefer = node.payload.get("prefer_pu")
            return (self.cfg.decode_migrate_cost
                    if prefer is not None and pu != prefer else 0.0)
        moving, cost = pen
        if moving == 0:
            return 0.0
        if self.cfg.migrate_pricing == "constant":
            return self.cfg.decode_migrate_cost
        return cost

    # -- speculative decoding ----------------------------------------------
    def _spec_plan(self, node: Node, ds: str, alpha: float, pu: str,
                   batch: int, width: int, idle: Sequence[str],
                   start: float, B_now: float, b_active: float,
                   b_soft: float, gate_v: Optional[Node], mig_s: float,
                   now: float, wpin: Optional[int] = None
                   ) -> Optional[Tuple[float, Dispatch, Dict]]:
        """Best speculative plan for serving round ``node`` on verify PU
        ``pu`` at token group ``batch``: enumerate (draft PU, draft
        width) over the profiled pair grid — the draft may pipeline on
        any other *idle* PU (per-pass cost max(t_d, t_v)) or run
        serially on the verify PU itself (t_d + t_v) — and gate the
        coupled pair's *combined* bandwidth through the same Eq. 5
        budget, so draft traffic can never starve the verify star.
        Returns (score, verify Dispatch, meta) or None when the grid
        offers nothing feasible; the caller compares the score against
        the plain (non-speculative) round candidate."""
        cfgn = self.cfg
        vs = node.stage
        pin = (cfgn.static_map or {}).get(ds)
        best: Optional[Tuple[float, Dispatch, Dict]] = None
        for dp in [pu] + [q for q in idle if q != pu]:
            if dp == "io" or not self.perf.supported(ds, dp):
                continue
            if pin is not None and dp != pin:
                continue
            if wpin:
                # typed DecodeSpec.draft_width pin: snap to the profiled
                # grid (largest fitted width not above the pin) instead
                # of searching the policy's candidate set
                grid = self.perf.spec_width_grid(ds, vs, dp, pu)
                below = [g for g in grid if g <= wpin]
                cands: Sequence[int] = ((max(below) if below
                                         else min(grid),) if grid else ())
            else:
                cands = self.policy.spec_width_candidates(ds, vs, dp, pu,
                                                          alpha)
            for w in cands:
                pair = self.perf.spec_pair_time(ds, vs, dp, pu, w, width)
                bv = self.perf.spec_bandwidth(vs, pu, w, width)
                if pair is None or bv is None:
                    continue
                td, tv = pair
                bd = self.perf.bandwidth_decode(ds, dp, width, w)
                b_pair = bv + bd
                if cfgn.enable_concurrency and b_active > 0 and \
                        cc.violates_budget(b_active, b_pair, b_soft):
                    continue
                n_p = spec_passes(batch, w, alpha)
                cost = max(td, tv) if dp != pu else td + tv
                phi = self.perf.phi(vs, B_now + b_pair)
                horizon = self.policy.round_passes(node, batch)
                f_cand = start + horizon * n_p * cost * phi
                w_b = cc.contention_penalty(self.perf, gate_v, b_pair,
                                            B_now, now) \
                    if cfgn.enable_concurrency else 0.0
                score = f_cand + cfgn.alpha * w_b + mig_s
                if best is None or score < best[0]:
                    # the verify dispatch's ETA is the whole round
                    # (n passes of the pipelined pair); same-PU plans
                    # fold the draft's bandwidth into it, cross-PU
                    # plans give the draft its own dispatch
                    d = Dispatch(node, pu, batch, n_p * cost,
                                 b_pair if dp == pu else bv, mig_s)
                    best = (score, d, {"ds": ds, "dp": dp, "w": w,
                                       "n": n_p, "td": td, "bd": bd,
                                       "alpha": alpha})
        return best

    @staticmethod
    def _stamp_spec(piece: Node, meta: Dict) -> None:
        """Commit the chosen speculative plan onto the round's payload —
        what the backends (ground-truth pass count, draft placement),
        the boundary bookkeeping (accept counters, draft-KV sync) and
        the telemetry read."""
        p = piece.payload
        p["spec_width"] = meta["w"]
        p["spec_draft_stage"] = meta["ds"]
        p["spec_draft_pu"] = meta["dp"]
        p["spec_passes"] = meta["n"]
        p["spec_alpha"] = meta["alpha"]

    def _spawn_draft(self, dag: DynamicDAG, piece: Node, meta: Dict,
                     now: float) -> Dispatch:
        """Materialize the draft half of a cross-PU speculative round:
        its own RUNNING node + Dispatch streaming ``n × w`` candidate
        tokens of the small model on the draft PU while the verify
        dispatch scores them.  The node is terminal — no successors and
        no KV-stream registration (draft-cache residency is synced at
        the verify boundary instead) — and is deleted on completion."""
        n_p, w = meta["n"], meta["w"]
        dn = Node(id=dag.fresh_id(f"{piece.id}.draft"), stage=meta["ds"],
                  kind="stream_decode", workload=n_p * w,
                  payload={"draft_round": True, "draft_for": piece.id,
                           "no_coalesce": True,
                           "decode_width": piece.payload.get(
                               "decode_width", 1),
                           "spec_width": w})
        dag.add(dn)
        dag.mark_running(dn.id, now, (meta["dp"], w))
        return Dispatch(dn, meta["dp"], w, meta["td"], meta["bd"])

    def _log_choice(self, node: Node, batch: int) -> None:
        """Chosen-shape telemetry: resident width + token group per decode
        round, merged batch per fused dispatch (what the serving benchmark
        reports per regime — the observable output of the batching policy)."""
        if node.payload.get("decode_round"):
            w = node.payload.get("decode_width", 1)
            wh = self.policy_log["decode_width"]
            wh[w] = wh.get(w, 0) + 1
            gh = self.policy_log["decode_group"]
            gh[batch] = gh.get(batch, 0) + 1
            sw = node.payload.get("spec_width")
            if sw is not None and "spec_width" in self.policy_log:
                sh = self.policy_log["spec_width"]
                sh[sw] = sh.get(sw, 0) + 1
        elif "members" in node.payload:
            fh = self.policy_log["fused_batch"]
            fh[batch] = fh.get(batch, 0) + 1

    def _capable_pus(self, node: Node, idle: Sequence[str]) -> List[str]:
        if node.kind == "io":
            return ["io"] if "io" in idle else []
        if self.cfg.static_map is not None:
            pinned = self.cfg.static_map.get(node.stage)
            if pinned is not None:
                return [pinned] if pinned in idle else []
        return [p for p in idle
                if p != "io" and self.perf.supported(node.stage, p)]

    def _configs(self, node: Node, pu: str) -> List[int]:
        if node.kind == "io":
            return [max(node.workload, 1)]
        if node.payload.get("decode_round"):
            # one boundary per dispatch: token-group candidates, clipped to
            # the batch's remaining horizon (the dispatch trims to the
            # chosen group; unfinished members re-enter at the boundary).
            # The adaptive policy aligns candidates to the sorted member
            # remainders (per-round group selection — no ragged-tail
            # padding); fixed keeps the static ladder.
            groups = self.policy.round_group_candidates(node)
            if groups is None:
                groups = (self.cfg.token_group, self.cfg.token_group * 2,
                          self.cfg.token_group * 4)
            return shape_aware_configs(self.perf, node, pu,
                                       token_groups=tuple(groups))
        if "members" in node.payload:
            # fused dispatch: coalescing IS a batching decision, so merged
            # shape configs are enumerated even with partitioning ablated
            return shape_aware_configs(self.perf, node, pu,
                                       cap=self.policy.coalesce_cap(
                                           node.stage, pu))
        if not self.cfg.enable_partition:
            return [max(node.workload, 1)]
        return shape_aware_configs(self.perf, node, pu,
                                   token_groups=(self.cfg.token_group,
                                                 self.cfg.token_group * 2,
                                                 self.cfg.token_group * 4))

    def _take_substage(self, dag: DynamicDAG, node: Node, n: int) -> Node:
        """Dispatch an n-sized bite of ``node``; leave the remainder as a
        ready sibling (batchable: parallel; streaming: sequential chain).
        Partitioning is recomputed on the remaining workload at the next
        dispatch (paper §4.2)."""
        L = node.workload
        if node.payload.get("decode_round"):
            # decode rounds serve exactly one token group per member; the
            # remainder stays IN the member streams, which rejoin the pool
            # at the boundary (continuous batching — no rest sibling)
            node.workload = min(L, n)
            return node
        if node.payload.get("draft_round"):
            # a draft half re-pooled by a live straggler cancel runs
            # whole: it is terminal and garbage-collected on completion,
            # so a rest sibling would dangle in the successor map
            return node
        if "members" in node.payload:
            return node    # fused dispatches run whole (membership is fixed)
        if not self.cfg.enable_partition or n >= L or node.kind in (
                "io", "search", "stream_prefill"):
            return node
        rest = Node(id=dag.fresh_id(f"{node.id}.r"), stage=node.stage,
                    kind=node.kind, workload=L - n,
                    deps=set(node.deps), template=node.template,
                    group=node.group or node.id, payload=dict(node.payload))
        for k in ("pu_busy_acc", "decode_served", "decode_total",
                  "decode_rounds", "last_slice", "coalesced", "batch_pu",
                  "round_final", "kv_migrations", "kv_bytes_moved",
                  "spec_drafted", "spec_accepted"):
            rest.payload.pop(k, None)   # batch accounting is per-node
        node.workload = n
        node.group = node.group or node.id
        succ = list(dag.successors(node.id))
        if node.kind == "stream_decode":
            # sequential: remainder continues the stream; downstream triggers
            # and expansion move to the final piece
            rest.deps = {node.id}
            rest.expander, node.expander = node.expander, None
            rest.payload["on_progress"] = node.payload.get("on_progress")
        dag.add(rest)
        if node.kind == "stream_decode":
            for s in succ:
                s.deps.discard(node.id)
                s.deps.add(rest.id)
                dag._succ[node.id].discard(s.id)
                dag._succ[rest.id].add(s.id)
                dag._refresh_status(s)
        else:
            for s in succ:
                dag.add_edge(rest.id, s.id)
        return node


# ---------------------------------------------------------------------------
# baseline strategy factories (paper §6.1)
# ---------------------------------------------------------------------------

def strategy_config(name: str, stages: Dict[str, str]) -> SchedulerConfig:
    """stages: stage-name -> role ('embed'|'rerank'|'search_llm'|'chat'|
    'search'|'io'...) used to build the Ayo-like manual map."""
    def all_to(pu: str) -> Dict[str, str]:
        # FAISS-style vector search stays on CPU in every baseline (§6.1)
        return {s: ("cpu" if r == "search" else pu)
                for s, r in stages.items()}

    if name == "llamacpp_gpu":
        return SchedulerConfig(enable_partition=False,
                               enable_criticality=False,
                               enable_concurrency=False,
                               static_map=all_to("gpu"))
    if name == "powerserve_npu":
        return SchedulerConfig(enable_partition=False,
                               enable_criticality=False,
                               enable_concurrency=False,
                               static_map=all_to("npu"))
    if name == "ayo_like":
        m = {}
        for s, role in stages.items():
            m[s] = {"embed": "npu", "rerank": "npu", "search": "cpu",
                    "search_llm": "npu", "chat": "gpu", "refine": "gpu",
                    "rewrite": "npu", "io": "io"}.get(role, "gpu")
        return SchedulerConfig(enable_partition=False,
                               enable_criticality=False,
                               enable_concurrency=False, static_map=m)
    if name == "hero":
        return SchedulerConfig()
    raise KeyError(name)
