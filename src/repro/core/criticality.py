"""Criticality estimation (paper §4.2, Eq. 4):  CS(v) = CS_L(v) + β·CS_F(v).

CS_L — *observed* term: longest remaining path from v on G_obs(t).  PU
assignment during the path simulation uses a dependency-agnostic SJF-like
heuristic (each node costed at its fastest supported PU), recomputed
whenever G_obs evolves.

CS_F — *future* term: expected downstream work on the predefined workflow
template, weighted by historical activation likelihood.  Agents that tend
to trigger more computation (search planner) get higher expected future
criticality than lightweight post-processing.
"""
from __future__ import annotations

from typing import Dict, Optional

from repro.core.dag import (DONE, RUNNING, DynamicDAG, Node,
                            WorkflowTemplate)
from repro.core.partitioner import best_batch
from repro.core.perf_model import LinearPerfModel


def _sjf_latency(perf: LinearPerfModel, node: Node,
                 cache: Dict[str, float]) -> float:
    """Dependency-agnostic latency prior: fastest PU, shape-optimal (SJF)."""
    key = f"{node.stage}|{node.kind}|{node.workload}"
    if key in cache:
        return cache[key]
    best = float("inf")
    for (stage, pu) in perf.coef:
        if stage != node.stage:
            continue
        if node.kind == "batchable":
            _, t = best_batch(perf, stage, pu, max(node.workload, 1))
        elif node.kind == "stream_decode":
            t = perf.p0(stage, pu, max(node.workload, 1))
        else:
            t = perf.p0(stage, pu, max(node.workload, 1))
        best = min(best, t)
    if best == float("inf"):
        best = 0.35 if node.kind == "io" else 0.0
    cache[key] = best
    return best


def observed_scores(dag: DynamicDAG, perf: LinearPerfModel,
                    now: float) -> Dict[str, float]:
    """CS_L for every unfinished node: longest remaining path on G_obs."""
    cache: Dict[str, float] = {}
    scores: Dict[str, float] = {}
    for node in reversed(dag.topo_order()):
        if node.status == DONE:
            scores[node.id] = 0.0
            continue
        succ_max = max((scores.get(s.id, 0.0)
                        for s in dag.successors(node.id)), default=0.0)
        own = _sjf_latency(perf, node, cache)
        if node.status == RUNNING and node.start >= 0:
            own = max(0.0, own - (now - node.start))
        scores[node.id] = own + succ_max
    return scores


def future_scores(dag: DynamicDAG, template: Optional[WorkflowTemplate],
                  perf: LinearPerfModel) -> Dict[str, float]:
    """CS_F: expected (probability-weighted) downstream template work."""
    if template is None:
        return {}
    cache: Dict[str, float] = {}
    tcost: Dict[str, float] = {}
    for ts in template.stages.values():
        probe = Node(id="probe", stage=ts.stage, kind=ts.kind,
                     workload=max(int(ts.mean_workload), 1))
        tcost[ts.id] = ts.prob * _sjf_latency(perf, probe, cache)
    out: Dict[str, float] = {}
    for node in dag.unfinished():
        if node.template is None or node.template not in template.stages:
            out[node.id] = 0.0
            continue
        # expected work of descendants NOT yet materialized in G_obs
        materialized = {n.template for n in dag.nodes.values()
                        if n.template is not None and n.id != node.id}
        out[node.id] = sum(
            tcost[d.id] for d in template.descendants(node.template)
            if d.id not in materialized)
    return out


def update_criticality(dag: DynamicDAG, perf: LinearPerfModel,
                       template: Optional[WorkflowTemplate], now: float,
                       beta: float = 1.0) -> None:
    """Eq. 4 over R(t) ∪ A(t) (and pending nodes, used for path scores)."""
    cs_l = observed_scores(dag, perf, now)
    cs_f = future_scores(dag, template, perf)
    for node in dag.unfinished():
        node.criticality = cs_l.get(node.id, 0.0) + beta * cs_f.get(node.id,
                                                                    0.0)
