"""Central registry of timeline-event names.

Both execution substrates (``core/simulator.py`` and
``serving/executor.py``) record the run as ``(t, event, node_id)``
triples, and a long tail of consumers — ``BackendRun`` counter
derivation, per-query attribution in ``api/results.py``, the session's
streaming observer, benchmark metrics — dispatch on the *string value*
of ``event``.  A typo'd emit therefore fails silently: the event lands
on the timeline, every ``e[1] == "..."`` filter misses it, and a
counter quietly under-reports (exactly the bug class the soft-overflow
accounting leak in PR 7 was).

This module is the single source of truth.  Emit sites and comparison
sites use the ``EV_*`` constants; ``repro.analysis.lint`` rejects raw
event-string literals in the event-handling modules, and
``repro.analysis.tracecheck`` rejects recorded events whose name is not
in :data:`ALL_EVENTS`.

The constant *values* are the historical strings, so recorded
timelines, goldens, and bench baselines are bit-identical across the
migration.
"""
from __future__ import annotations

# -- node lifecycle ----------------------------------------------------------
EV_START = "start"            # dispatch began on a PU
EV_DONE = "done"              # node (or fused dispatch) completed
EV_TOKENS = "tokens"          # resident decode-round member advanced one
#                               token group at a boundary without finishing
EV_CANCELLED = "cancelled"    # user-requested cancel finalized the node

# -- re-serve (the first attempt did not complete) ---------------------------
EV_REDISPATCH = "redispatch"  # simulator: speculative straggler re-dispatch
EV_STRAGGLER = "straggler"    # live runtime: heartbeat-detected straggler
EV_RETRY = "retry"            # live runtime: stage fn raised; retrying
EV_PREEMPT = "preempt"        # member released from a preempted fused
#                               dispatch at a boundary split (returns READY)

# -- KV-cache subsystem ------------------------------------------------------
EV_KV_MIGRATE = "kv_migrate"            # resident cache moved PU -> PU
EV_KV_FETCH = "kv_fetch"                # cache gathered from a spill tier
EV_KV_PAGE_HIT = "kv_page_hit"          # prefix-cache hit on a prefill
EV_KV_HIT_DECLINED = "kv_hit_declined"  # hit-or-recompute rule declined
EV_KV_EVICT = "kv_evict"                # page demoted/dropped for room
EV_KV_PREFETCH = "kv_prefetch"          # pages staged ahead of a dispatch
EV_KV_SOFT_OVERFLOW = "kv_soft_overflow"  # all-pinned capacity breach

ALL_EVENTS = frozenset({
    EV_START, EV_DONE, EV_TOKENS, EV_CANCELLED,
    EV_REDISPATCH, EV_STRAGGLER, EV_RETRY, EV_PREEMPT,
    EV_KV_MIGRATE, EV_KV_FETCH, EV_KV_PAGE_HIT, EV_KV_HIT_DECLINED,
    EV_KV_EVICT, EV_KV_PREFETCH, EV_KV_SOFT_OVERFLOW,
})

# the three "this dispatch did not complete; a re-serve follows" events —
# BackendRun.redispatches and QueryResult.redispatches count exactly these
REDISPATCH_EVENTS = (EV_REDISPATCH, EV_STRAGGLER, EV_RETRY)

# spill tiers of the paged KV store ("dram"/"disk", vs. PU-name tiers);
# a gather sourced from one of these is a fetch, not a migration
SPILL_TIERS = ("dram", "disk")
