"""Dynamic RAG task graph with partial observability (paper §3.1).

Nodes are *sub-stages*.  The graph evolves at runtime: when a decision
stage finishes, its ``expander`` callback may add new nodes/edges
(G_obs(t) ⊆ G) — e.g. a query rewriter emitting N search sub-queries, or a
search planner spawning web-search + refine branches.  The scheduler only
ever sees the observed graph.

Fused nodes: the dual of sub-stage partitioning.  ``fuse_ready`` merges
several READY same-(stage, kind) nodes — typically the same stage of
*different* admitted queries — into one dispatch unit whose completion
fans back out to every member (``mark_done``), releasing each member's own
successors.  Members leave the ready pool while fused; ``unfuse`` reverses
an un-dispatched fusion.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Set

PENDING, READY, RUNNING, DONE = "pending", "ready", "running", "done"


def resolve_prefer_pu(kv, members: Sequence["Node"]) -> Optional[str]:
    """The PU a forming decode round should anchor to, from its members'
    ``batch_pu`` history — THE shared resolution: ``fuse_decode`` stamps
    it on the round and the scheduler derives the width cap at it, so
    the two must agree.  Agreement short-circuits (the legacy path);
    conflicting history is resolved by the KV-residency tracker (largest
    resident footprint, deterministic tie-breaks) when one is attached,
    with a smallest-name guard should the tracker ever abstain; without
    a tracker a conflict yields no preference, exactly as before."""
    prev = {m.payload.get("batch_pu") for m in members} - {None}
    if len(prev) == 1:
        return next(iter(prev))
    if prev and kv is not None:
        return kv.prefer_pu(members) or min(prev)
    return None


@dataclass
class Node:
    id: str
    stage: str                       # perf-model key (StageModel name)
    kind: str                        # batchable | stream_prefill | stream_decode | search | io
    workload: int                    # L: items (batchable) / tokens (stream)
    deps: Set[str] = field(default_factory=set)
    # template stage id for the future-criticality prior
    template: Optional[str] = None
    # called on completion; may mutate the DAG (dynamic dependencies)
    expander: Optional[Callable[["DynamicDAG", "Node"], None]] = None
    # partitioning: sub-stages created from this node share its group
    group: Optional[str] = None
    # --- runtime state ---
    status: str = PENDING
    config: Optional[Any] = None     # chosen (pu, batch)
    start: float = -1.0
    finish: float = -1.0
    remaining: float = 0.0           # simulator bookkeeping
    criticality: float = 0.0
    payload: Dict[str, Any] = field(default_factory=dict)


class DynamicDAG:
    def __init__(self):
        self.nodes: Dict[str, Node] = {}
        self._succ: Dict[str, Set[str]] = {}
        self._ids = itertools.count()
        # KV-residency tracker (core/kv_residency.py), attached by the
        # scheduler when SchedulerConfig.kv_residency is on: decode-round
        # boundaries report served tokens / leaves to it, and fuse_decode
        # consults it to anchor rounds with conflicting batch_pu history
        self.kv = None
        # speculative-decoding accept tracker (core/spec_decode.py),
        # attached by the scheduler when SchedulerConfig.spec_decode is
        # on: round boundaries report per-member drafted/accepted counts
        # so the next round's pricing sees each stream's observed alpha
        self.spec = None
        # count of cancel-requested, not-yet-finalized nodes: backends
        # skip the reap scan entirely while it is zero (the hot-path
        # guard that keeps cancellation free when unused)
        self._cancel_pending = 0

    # -- construction -------------------------------------------------------
    def add(self, node: Node) -> Node:
        assert node.id not in self.nodes, node.id
        self.nodes[node.id] = node
        self._succ.setdefault(node.id, set())
        for d in node.deps:
            assert d in self.nodes, f"dep {d} of {node.id} not materialized"
            self._succ.setdefault(d, set()).add(node.id)
        self._refresh_status(node)
        return node

    def fresh_id(self, prefix: str) -> str:
        return f"{prefix}#{next(self._ids)}"

    def add_edge(self, src: str, dst: str):
        self.nodes[dst].deps.add(src)
        self._succ.setdefault(src, set()).add(dst)
        self._refresh_status(self.nodes[dst])

    def retarget_dep(self, node_id: str, old_dep: str, new_dep: str):
        """Replace one dependency of ``node_id`` (chunked-prefill chains)."""
        n = self.nodes[node_id]
        n.deps.discard(old_dep)
        self._succ.get(old_dep, set()).discard(node_id)
        self.add_edge(new_dep, node_id)

    # -- state --------------------------------------------------------------
    def _refresh_status(self, node: Node):
        if node.status in (RUNNING, DONE):
            return
        if all(self.nodes[d].status == DONE for d in node.deps):
            node.status = READY
        else:
            node.status = PENDING

    def ready(self) -> List[Node]:
        return [n for n in self.nodes.values() if n.status == READY]

    def running(self) -> List[Node]:
        return [n for n in self.nodes.values() if n.status == RUNNING]

    def unfinished(self) -> List[Node]:
        return [n for n in self.nodes.values() if n.status != DONE]

    def successors(self, nid: str) -> List[Node]:
        return [self.nodes[s] for s in self._succ.get(nid, ())]

    def mark_running(self, nid: str, t: float, config):
        n = self.nodes[nid]
        n.status, n.start, n.config = RUNNING, t, config

    def mark_done(self, nid: str, t: float):
        n = self.nodes[nid]
        n.status, n.finish = DONE, t
        members = n.payload.get("members")
        if n.payload.get("decode_round"):
            # continuous decode batching: one token-group boundary
            self._finish_decode_round(n, t)
        elif members:
            # coalesced dispatch: completion fans out to every member query
            total = max(n.workload, 1)
            for m in members:
                m.start, m.config = n.start, n.config
                m.payload.pop("fused_into", None)
                m.payload["coalesced"] = n.id
                m.payload["fused_share"] = m.workload / total
                self.mark_done(m.id, t)
        if (getattr(self.kv, "paged", False)
                and n.kind == "stream_prefill"):
            # paged KV: a finished prefill materializes its prefix pages on
            # the PU that ran it (reusing resident hashed pages — the
            # cross-query hit) and links them to its decode stream
            self.kv.on_prefill_done(
                n, n.config[0] if n.config is not None else None)
        # dynamic dependencies: expansion happens *before* dependents are
        # released, so newly-created upstream work is observed atomically
        if n.expander is not None:
            n.expander(self, n)
            n.expander = None
        if (self.kv is not None and n.kind == "stream_decode"
                and not n.payload.get("decode_round")
                and not n.payload.get("draft_round")
                and "members" not in n.payload):
            # a finished decode piece with no continuation (no rest
            # sibling of the same stream) ends its stream: free the KV
            # footprint so long-lived serving does not accumulate ghosts
            skey = n.group or n.id
            if not any(s.kind == "stream_decode"
                       and (s.group or s.id) == skey
                       for s in self.successors(nid)):
                self.kv.on_boundary(n, "", 0, left=True)
        for s in self._succ.get(nid, ()):
            self._refresh_status(self.nodes[s])
        if ((n.payload.get("decode_round")
             or n.payload.get("draft_round"))
                and not self._succ.get(nid)):
            # a completed round nobody depends on (progressive spawns may
            # anchor on it) would otherwise accumulate one node per
            # token-group boundary, making every scheduler pass scan an
            # ever-growing graph in long-lived continuous serving
            del self.nodes[nid]
            self._succ.pop(nid, None)

    # -- continuous decode batching ------------------------------------------
    def fuse_decode(self, members: Sequence[Node]) -> Node:
        """Fuse ≥ 2 READY ``stream_decode`` nodes into one *decode round* —
        one token-group boundary of a resident continuous batch.  Unlike
        ``fuse_ready``, the round does not consume its members whole: its
        workload is the batch's remaining horizon (the scheduler trims it to
        the chosen token group at dispatch) and ``mark_done`` advances every
        member by its slice, releasing finished members immediately (leave)
        while unfinished members rejoin the ready pool to re-fuse at the
        next boundary — where newly READY decode streams join."""
        assert len(members) >= 2
        stage = members[0].stage
        for m in members:
            assert m.status == READY, (m.id, m.status)
            assert m.kind == "stream_decode", m.id
            assert m.stage == stage, m.id
        fused = Node(id=self.fresh_id(f"dround:{stage}"), stage=stage,
                     kind="stream_decode",
                     workload=max(m.workload for m in members),
                     payload={"members": list(members), "decode_round": True,
                              "decode_width": len(members),
                              # sorted member remainders: the horizon
                              # policy picks the round's token group from
                              # this distribution (ragged tails leave at a
                              # boundary instead of being padded to one)
                              "remaining": sorted(m.workload
                                                  for m in members)})
        # KV caches of a resident batch live on the PU that served the
        # previous round; the scheduler charges migration when moving
        prefer = resolve_prefer_pu(self.kv, members)
        if prefer is not None:
            fused.payload["prefer_pu"] = prefer
        for m in members:
            m.status = RUNNING
            m.payload["fused_into"] = fused.id
            m.payload.setdefault(
                "decode_total", m.payload.get("decode_served", 0) + m.workload)
        self.add(fused)
        fused.criticality = max(m.criticality for m in members)
        return fused

    def _finish_decode_round(self, n: Node, t: float):
        """Boundary-quantized fan-out: each member advances by
        ``min(round group, remaining)`` tokens.  Finished members *leave*
        (marked done — successors release, expanders run — the per-member
        early release); the rest return to READY with the served tokens
        subtracted, carrying their progressive-release callbacks."""
        g = max(n.workload, 1)
        members = n.payload["members"]
        dur = (t - n.start) if n.start >= 0 else 0.0
        total = sum(min(g, m.workload) for m in members)
        # speculative round: the same boundary served the same tokens, but
        # in spec_passes verify sweeps of drafted groups.  Per member the
        # round drafted passes × width candidates; accepted counts come
        # from the backend's scoreboard (payload["spec_accepts"], live
        # stage fns) or fall back to the pass arithmetic — s tokens in
        # spec_passes sweeps means s − passes drafts were accepted.
        spec_w = n.payload.get("spec_width", 0)
        spec_n = max(int(n.payload.get("spec_passes", 1)), 1)
        acc_map = n.payload.get("spec_accepts") or {}
        for m in members:
            s = min(g, m.workload)
            m.payload.pop("fused_into", None)
            m.payload["coalesced"] = n.id
            m.payload["last_slice"] = s
            m.payload["decode_rounds"] = m.payload.get("decode_rounds", 0) + 1
            m.payload["decode_served"] = m.payload.get("decode_served", 0) + s
            if spec_w:
                drafted = spec_n * spec_w
                acc = acc_map.get(m.id)
                if acc is None:
                    acc = s - spec_n
                acc = max(0, min(int(acc), drafted))
                m.payload["spec_drafted"] = (
                    m.payload.get("spec_drafted", 0) + drafted)
                m.payload["spec_accepted"] = (
                    m.payload.get("spec_accepted", 0) + acc)
                if self.spec is not None:
                    self.spec.observe(m.group or m.id, drafted, acc)
            if self.kv is not None:
                if n.config is not None:
                    # residency boundary event: the member's cache grew by
                    # the served slice on the round's PU; leavers free theirs
                    self.kv.on_boundary(m, n.config[0], s,
                                        left=(s >= m.workload))
                    if (spec_w and s < m.workload
                            and getattr(self.kv, "paged", False)):
                        # draft KV: a staying member's draft-model cache
                        # mirrors its (just-grown) verify context —
                        # growing forward or trimming the rejected
                        # speculative tail back to it, never below, so
                        # rollback cannot cross a served-page boundary.
                        # Leavers skip: release() frees both footprints.
                        self.kv.spec_draft_sync(
                            m, n.payload.get("spec_draft_stage"),
                            n.payload.get("spec_draft_pu") or n.config[0])
                elif s >= m.workload:
                    # a leaver of an un-configured round (e.g. drained
                    # without a dispatch) must still release its stream, or
                    # its footprint stays registered until session end
                    self.kv.release(m)
            if n.config is not None:
                # PU occupancy charged by live membership: workload share of
                # this round's residency
                acc = m.payload.setdefault("pu_busy_acc", {})
                acc[n.config[0]] = (acc.get(n.config[0], 0.0)
                                    + dur * (s / max(total, 1)))
                m.payload["batch_pu"] = n.config[0]
            if m.start < 0:
                m.start = n.start       # joined the resident batch here
            prog = m.payload.get("on_progress")
            if s >= m.workload:
                m.config = m.config if m.config is not None else n.config
                m.payload["round_final"] = True
                self.mark_done(m.id, t)
                if prog is not None:
                    prog(self, m, s)
            else:
                m.workload -= s
                m.status = READY
                if prog is not None:
                    # spawned work may depend on the (done) round node
                    prog(self, n, s)

    # -- cross-query coalescing ----------------------------------------------
    def fuse_ready(self, members: Sequence[Node]) -> Node:
        """Merge ≥ 2 READY nodes sharing (stage, kind) into one fused
        dispatch unit.  Members are absorbed (status RUNNING, no config)
        until the fused node completes; its ``mark_done`` fans completion
        back out, so each member's successors release normally."""
        assert len(members) >= 2
        stage, kind = members[0].stage, members[0].kind
        for m in members:
            assert m.status == READY, (m.id, m.status)
            assert (m.stage, m.kind) == (stage, kind), m.id
        fused = Node(id=self.fresh_id(f"fused:{stage}"), stage=stage,
                     kind=kind, workload=sum(m.workload for m in members),
                     payload={"members": list(members)})
        for m in members:
            m.status = RUNNING
            m.payload["fused_into"] = fused.id
        self.add(fused)
        fused.criticality = max(m.criticality for m in members)
        return fused

    def preempt_fused(self, fused: Node, keep: int,
                      prefer_pu: Optional[str] = None,
                      t: float = 0.0) -> List[Node]:
        """Split a RUNNING fused batchable dispatch at a member boundary:
        the first ``keep`` members stay in the (truncated) dispatch and
        complete with it; the rest are *released* — back to READY with
        their state in place, stamped ``preemptions`` (+1),
        ``preempt_prefer_pu`` (the PU they were split off, which
        re-placement anchors to unless the KV tracker knows better) and
        ``preempt_t`` (release time ``t`` — the SLO deferral floor's
        clock restarts here, so a released batch member queues a full
        deferral window again instead of re-dispatching into the very
        contention it was split to relieve).
        Nothing is discarded: the in-progress member finishes inside the
        kept slice, so preemption costs only the released members' wait.
        Returns the released members (empty when the boundary falls past
        the last member — the dispatch simply runs out)."""
        assert fused.status == RUNNING, fused.status
        members = fused.payload["members"]
        keep = max(1, min(keep, len(members)))
        if keep >= len(members):
            return []
        kept, released = members[:keep], members[keep:]
        fused.payload["members"] = kept
        fused.workload = sum(m.workload for m in kept)
        for m in released:
            m.status = READY
            m.payload.pop("fused_into", None)
            m.payload["preemptions"] = m.payload.get("preemptions", 0) + 1
            m.payload["preempt_t"] = t
            if prefer_pu is not None:
                m.payload["preempt_prefer_pu"] = prefer_pu
        return released

    # -- user-requested cancellation -------------------------------------------
    def request_cancel(self, prefix: str) -> int:
        """Flag every unfinished node of an admitted query (id prefix)
        for cancellation.  Finalization is deferred to the backend's
        next scheduling point (``reap_cancelled`` + in-flight abort) so
        both substrates observe cancellation at the same granularity.
        Returns the number of nodes flagged."""
        flagged = 0
        for n in self.nodes.values():
            if (n.status != DONE and n.id.startswith(prefix)
                    and not n.payload.get("cancel_requested")):
                n.payload["cancel_requested"] = True
                flagged += 1
        self._cancel_pending += flagged
        return flagged

    def reap_cancelled(self, t: float) -> List[Node]:
        """Finalize cancel-requested PENDING/READY nodes: marked DONE at
        ``t`` with ``payload["cancelled"]`` and their expanders dropped
        (a cancelled query must not spawn new work), decode streams
        release their KV footprint, and successors refresh — so a
        cancelled query's whole remaining chain collapses in one
        fixpoint sweep.  RUNNING nodes are the backend's job (abort the
        in-flight task, then finalize); members absorbed into a live
        fused dispatch ride it to completion first (best-effort — the
        fused work is shared with other queries)."""
        reaped: List[Node] = []
        progress = True
        while progress:
            progress = False
            for n in list(self.nodes.values()):
                if (n.status not in (PENDING, READY)
                        or not n.payload.get("cancel_requested")
                        or "fused_into" in n.payload):
                    continue
                n.status, n.finish = DONE, t
                n.expander = None
                n.payload["cancelled"] = True
                if self.kv is not None and n.kind == "stream_decode":
                    self.kv.release(n)
                for s in self._succ.get(n.id, ()):
                    self._refresh_status(self.nodes[s])
                reaped.append(n)
                progress = True
        self._cancel_pending = sum(
            1 for n in self.nodes.values()
            if n.payload.get("cancel_requested") and n.status != DONE)
        return reaped

    def unfuse(self, fused: Node) -> List[Node]:
        """Dissolve an un-dispatched fused node; members rejoin the ready
        pool."""
        assert fused.status == READY, fused.status
        members = fused.payload["members"]
        for m in members:
            m.status = READY
            m.payload.pop("fused_into", None)
        del self.nodes[fused.id]
        self._succ.pop(fused.id, None)
        return members

    # -- analysis ------------------------------------------------------------
    def topo_order(self) -> List[Node]:
        indeg = {nid: len(n.deps) for nid, n in self.nodes.items()}
        queue = [nid for nid, d in indeg.items() if d == 0]
        out = []
        while queue:
            nid = queue.pop()
            out.append(self.nodes[nid])
            for s in self._succ.get(nid, ()):
                indeg[s] -= 1
                if indeg[s] == 0:
                    queue.append(s)
        assert len(out) == len(self.nodes), "cycle in DAG"
        return out

    def makespan(self) -> float:
        return max((n.finish for n in self.nodes.values()
                    if n.status == DONE), default=0.0)


@dataclass
class WorkflowTemplate:
    """The predefined workflow graph used for the future-criticality term
    CS_F (paper Eq. 4): template stages with activation likelihoods and
    expected downstream workloads, updated from history."""

    stages: Dict[str, "TemplateStage"] = field(default_factory=dict)

    def add_stage(self, sid: str, stage: str, kind: str, mean_workload: float,
                  prob: float, deps: Sequence[str] = ()):
        self.stages[sid] = TemplateStage(sid, stage, kind, mean_workload,
                                         prob, set(deps))

    def descendants(self, sid: str) -> List["TemplateStage"]:
        out, seen = [], set()
        frontier = [sid]
        while frontier:
            cur = frontier.pop()
            for s in self.stages.values():
                if cur in s.deps and s.id not in seen:
                    seen.add(s.id)
                    out.append(s)
                    frontier.append(s.id)
        return out

    def update_history(self, template_id: str, activated: bool,
                       workload: float = 0.0, ema: float = 0.1):
        """Online prior update (historical averages, §4.2)."""
        s = self.stages.get(template_id)
        if s is None:
            return
        s.prob = (1 - ema) * s.prob + ema * (1.0 if activated else 0.0)
        if activated and workload > 0:
            s.mean_workload = (1 - ema) * s.mean_workload + ema * workload


@dataclass
class TemplateStage:
    id: str
    stage: str                 # perf-model key
    kind: str
    mean_workload: float
    prob: float                # historical activation likelihood
    deps: Set[str]
