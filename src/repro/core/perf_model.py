"""Heterogeneous performance models (paper §3.2).

Three layers, faithful to the paper's methodology:

1. **Ground-truth hardware model** — an analytic roofline cost per
   (stage, PU, shape) built from the PU specs (Table 2 SoCs, or TPU-v5e
   slices) plus per-PU efficiency curves and per-invocation overheads.
   This is what the *simulator* executes (it plays the role of the phone).

2. **Profiled estimates** — the paper profiles sampled measurements and
   fits a multi-feature linear regression (§5, following Band/CoDL).  We do
   exactly that: sample the ground truth on a grid of (workload size, batch
   shape, background bandwidth) and fit ``p^0_v(c)``, ``b_v(c)`` and
   ``φ_v(B)``.  The *scheduler* only ever sees these fitted estimates, so
   modeling error is part of the evaluation, as on real hardware.

3. **Contention model** — ``φ_v(B)``: monotone slowdown in aggregate
   bandwidth demand ``B(t)``; per-stage sensitivity (Eq. 1).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np


# ---------------------------------------------------------------------------
# processing units
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class PU:
    """One processing unit (mobile accelerator or TPU mesh slice)."""

    name: str
    kind: str                  # cpu | gpu | npu | tpu_slice | io
    peak_flops: float          # effective FLOP/s at the stage dtype
    # fraction of DRAM bandwidth this PU can pull when alone
    mem_bw: float              # bytes/s
    # per-invocation overhead (s): graph launch / shape switch
    overhead: float = 1e-4
    # extra overhead per *token step* for streaming decode (NPU pays shape
    # switches per step; this is what makes generation GPU-affine, Fig. 2)
    step_overhead: float = 0.0
    # compute efficiency by workload kind
    eff_batch: float = 0.5     # batchable fixed-shape stages
    eff_stream: float = 0.5    # autoregressive decode
    # effective DRAM-bandwidth utilization for token-by-token streaming
    # (NPU runtimes pay per-step graph swaps + dequant pipeline stalls —
    # this is what makes LLM *generation* GPU-affine, Fig. 2, and why
    # mllm.npu-style systems decode off-NPU)
    mem_eff_stream: float = 0.85
    # native tile size: batch shapes off the tile grid lose efficiency
    # (shape sensitivity, Fig. 2) — sawtooth efficiency curve
    tile: int = 8
    tile_penalty: float = 0.35
    # batch sweet spot: beyond it, per-item efficiency *degrades* (compiled-
    # graph pipelining breaks, activations spill on-chip memory) — Fig. 2's
    # "larger batches do not always yield better per-item efficiency".
    batch_sweet: int = 64
    spill: float = 0.5
    # bytes of PU-local KV arena the runtime pins for resident caches
    # (paged-KV tier 0); 0 = unbounded (tiering effectively off for this PU)
    kv_arena: float = 0.0


@dataclass(frozen=True)
class SoCSpec:
    name: str
    pus: Tuple[PU, ...]
    dram_bw: float             # shared B0, bytes/s
    # φ shape parameters: φ(B) = 1 + gamma * max(0, B/B0 - knee)^2
    phi_knee: float = 0.20
    phi_gamma: float = 3.0
    # paged-KV spill tiers: shared-DRAM pool bytes reserved for evicted KV
    # pages (tier 1) and the storage read bandwidth behind the disk tier
    # (tier 2, UFS-class).  0 = unbounded pool / a conservative fraction of
    # DRAM bandwidth for the disk path.
    kv_dram_pool: float = 0.0
    disk_bw: float = 0.0

    def pu(self, name: str) -> PU:
        for p in self.pus:
            if p.name == name:
                return p
        raise KeyError(name)


def snapdragon_8gen3() -> SoCSpec:
    """Redmi K80 (Table 2).  FLOPs are INT8-effective (models are INT8)."""
    bw = 76.8e9
    return SoCSpec(
        name="sd8gen3",
        pus=(
            PU("cpu", "cpu", peak_flops=140e9, mem_bw=0.55 * bw,
               overhead=3e-5, step_overhead=1e-5, eff_batch=0.55,
               eff_stream=0.60, mem_eff_stream=0.70, tile=4,
               tile_penalty=0.15, batch_sweet=128, spill=0.15,
               kv_arena=384e6),
            PU("gpu", "gpu", peak_flops=2.8e12, mem_bw=0.80 * bw,
               overhead=8e-4, step_overhead=2e-4, eff_batch=0.15,
               eff_stream=0.50, mem_eff_stream=0.35, tile=16,
               tile_penalty=0.30, batch_sweet=48, spill=0.55,
               kv_arena=512e6),
            PU("npu", "npu", peak_flops=34e12, mem_bw=0.85 * bw,
               overhead=4e-3, step_overhead=3e-3, eff_batch=0.52,
               eff_stream=0.30, mem_eff_stream=0.30, tile=32,
               tile_penalty=0.45, batch_sweet=32, spill=0.85,
               kv_arena=256e6),
        ),
        dram_bw=bw, kv_dram_pool=2e9, disk_bw=3.5e9)


def snapdragon_8gen4() -> SoCSpec:
    """OnePlus 13 / 8 Elite (Table 2)."""
    bw = 84.8e9
    return SoCSpec(
        name="sd8gen4",
        pus=(
            PU("cpu", "cpu", peak_flops=210e9, mem_bw=0.55 * bw,
               overhead=2.5e-5, step_overhead=8e-6, eff_batch=0.58,
               eff_stream=0.62, mem_eff_stream=0.75, tile=4,
               tile_penalty=0.15, batch_sweet=128, spill=0.15,
               kv_arena=384e6),
            PU("gpu", "gpu", peak_flops=3.4e12, mem_bw=0.80 * bw,
               overhead=7e-4, step_overhead=1.6e-4, eff_batch=0.22,
               eff_stream=0.52, mem_eff_stream=0.50, tile=16,
               tile_penalty=0.30, batch_sweet=48, spill=0.55,
               kv_arena=512e6),
            PU("npu", "npu", peak_flops=50e12, mem_bw=0.85 * bw,
               overhead=3.5e-3, step_overhead=2.5e-3, eff_batch=0.55,
               eff_stream=0.32, mem_eff_stream=0.30, tile=32,
               tile_penalty=0.45, batch_sweet=32, spill=0.85,
               kv_arena=256e6),
        ),
        dram_bw=bw, kv_dram_pool=2e9, disk_bw=3.5e9)


def tpu_v5e_slices(slices: Dict[str, int]) -> SoCSpec:
    """TPU deployment: PU groups = disjoint mesh slices of a v5e pod.

    slices: {"slice_name": n_chips}.  The shared domain here is the pod's
    host-DMA/ICI fabric for inter-stage tensor handoff; per-chip HBM scales
    with the slice, so mem_bw = chips * 819 GB/s.
    """
    pus = []
    for name, chips in slices.items():
        pus.append(PU(
            name, "tpu_slice",
            peak_flops=chips * 394e12,     # int8 ~= 2x bf16 197 TFLOP/s
            mem_bw=chips * 819e9,
            overhead=2e-5 + 3e-6 * chips,  # dispatch + sync grows with slice
            step_overhead=6e-6,
            eff_batch=0.55, eff_stream=0.45, tile=8 * chips,
            tile_penalty=0.30))
    # inter-slice fabric ~ 50 GB/s/link * bisection links of smallest slice
    fabric = 50e9 * max(4, min(slices.values()))
    return SoCSpec(name="tpu_v5e_pod", pus=tuple(pus), dram_bw=fabric,
                   phi_knee=0.7, phi_gamma=4.0)


# ---------------------------------------------------------------------------
# stage workload characterization
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class StageModel:
    """Static description of one RAG stage's compute (from its ModelConfig)."""

    name: str                   # e.g. "qwen3-embedding-0.6b"
    params: int                 # parameter count
    d_model: int
    kind: str                   # "batchable" | "stream_prefill" | "stream_decode" | "search" | "io"
    bytes_per_param: float = 1.0   # INT8
    # batchable: per-item token count; streaming: tokens handled elsewhere
    item_tokens: int = 128
    # KV-cache bytes appended per token (2 · layers · kv_heads · head_dim ·
    # bytes for a GQA transformer); 0 selects the d_model fallback below —
    # what KV-residency tracking and the migration-cost model charge
    kv_bytes_token: float = 0.0

    def kv_bytes_per_token(self) -> float:
        """Bytes of K+V cache one context token occupies on its PU."""
        return self.kv_bytes_token or 2.0 * self.d_model * self.bytes_per_param

    def flops(self, n_items: int, tokens: Optional[int] = None) -> float:
        t = tokens if tokens is not None else n_items * self.item_tokens
        if self.kind == "search":
            # vector search: 2*N*d per query (n_items = corpus size)
            return 2.0 * n_items * self.d_model
        return 2.0 * self.params * t

    def bytes_moved(self, n_items: int, tokens: Optional[int] = None) -> float:
        w = self.params * self.bytes_per_param
        if self.kind == "search":
            return n_items * self.d_model * 1.0  # int8 corpus scan
        if self.kind == "stream_decode":
            t = tokens if tokens is not None else n_items
            return w * t               # weights re-read per token step
        return w + (tokens or n_items * self.item_tokens) * self.d_model


# ---------------------------------------------------------------------------
# ground-truth cost model
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class Config:
    """One scheduling configuration c ∈ C_v: target PU + workload shape."""
    pu: str
    batch: int                  # items (batchable) or token-group size (stream)
    # decode only: number of sequences resident in the dispatch (continuous
    # cross-query batching).  1 = the paper's single-stream decode.
    width: int = 1


def _shape_eff(pu: PU, batch: int) -> float:
    """Sawtooth tiling efficiency + large-batch spill (Fig. 2)."""
    if batch <= 0:
        return 1.0
    rem = batch % pu.tile
    eff = 1.0 if rem == 0 else 1.0 - pu.tile_penalty * (1.0 - rem / pu.tile)
    if batch > pu.batch_sweet:
        eff *= (pu.batch_sweet / batch) ** pu.spill
    return eff


class GroundTruthPerf:
    """Analytic p0 / bandwidth per (stage, PU, shape) — simulator substrate."""

    def __init__(self, soc: SoCSpec, stages: Dict[str, StageModel]):
        self.soc = soc
        self.stages = stages

    def supported(self, stage: StageModel, pu: PU) -> bool:
        if stage.kind == "io":
            return pu.kind == "io"
        if pu.kind == "io":
            return False
        if stage.kind == "search" and pu.kind == "npu":
            return False           # FAISS-style scan not NPU-supported (§6.1)
        return True

    def p0(self, stage: StageModel, pu: PU, c: Config,
           tokens: Optional[int] = None) -> float:
        """Base latency of ONE sub-stage pass at batch c.batch."""
        if stage.kind == "io":
            return 0.35            # web search round trip (s)
        n = c.batch
        eff = _shape_eff(pu, n)
        if stage.kind == "batchable":
            fl = stage.flops(n)
            by = stage.bytes_moved(n)
            t = max(fl / (pu.peak_flops * pu.eff_batch * eff),
                    by / pu.mem_bw)
            return t + pu.overhead
        if stage.kind == "stream_prefill":
            t_tok = tokens if tokens is not None else n
            fl = stage.flops(1, t_tok)
            by = stage.params * stage.bytes_per_param
            t = max(fl / (pu.peak_flops * pu.eff_batch * eff),
                    by / pu.mem_bw)
            return t + pu.overhead
        if stage.kind == "stream_decode":
            # token-group of size n: memory-bound weight sweep per token.
            # At width w > 1 (continuous cross-query batching) the per-step
            # weight sweep is SHARED by all w resident sequences — the
            # vLLM/RAGDoll serving lever — while compute scales with w and
            # pays the width tiling efficiency.
            w = max(c.width, 1)
            by = stage.params * stage.bytes_per_param * n
            fl = stage.flops(1, n) * w
            weff = _shape_eff(pu, w) if w > 1 else 1.0
            t = max(fl / (pu.peak_flops * pu.eff_stream * weff),
                    by / (pu.mem_bw * pu.mem_eff_stream))
            return t + pu.overhead + pu.step_overhead * n
        if stage.kind == "search":
            by = stage.bytes_moved(n)
            return by / min(pu.mem_bw, self.soc.dram_bw) + pu.overhead
        raise ValueError(stage.kind)

    def bandwidth(self, stage: StageModel, pu: PU, c: Config,
                  tokens: Optional[int] = None) -> float:
        """Average demand b_v(c) on the SHARED domain, bytes/s.

        Mobile SoC: all PU traffic hits the unified DRAM -> full bytes.
        TPU slices: HBM is slice-private; only inter-stage activation
        handoff crosses the shared fabric."""
        if stage.kind == "io":
            return 0.0
        t = self.p0(stage, pu, c, tokens)
        if pu.kind == "tpu_slice":
            acts = (tokens or c.batch * max(stage.item_tokens, 1)) \
                * max(stage.d_model, 1) * 2.0
            return acts / max(t, 1e-9)
        if stage.kind in ("batchable", "stream_prefill"):
            by = stage.bytes_moved(c.batch, tokens)
        elif stage.kind == "stream_decode":
            by = stage.params * stage.bytes_per_param * c.batch
        else:
            by = stage.bytes_moved(c.batch, tokens)
        return by / max(t, 1e-9)

    def link_bandwidth(self, src: PU, dst: PU) -> float:
        """Effective KV-copy bandwidth between two PUs (bytes/s).

        On a unified-memory SoC a cache migration is a read at the source
        PU's DMA rate followed by a write at the destination's, both over
        the shared bus — the harmonic combination, never above the bus
        itself.  TPU slices pay the inter-slice fabric the same way."""
        eff = 1.0 / (1.0 / src.mem_bw + 1.0 / dst.mem_bw)
        return min(eff, self.soc.dram_bw)

    def migrate_cost(self, stage: StageModel, src: PU, dst: PU,
                     ctx_tokens: int) -> float:
        """Seconds to move ``ctx_tokens`` of ``stage``'s KV cache from
        ``src`` to ``dst`` (uncontended; the bus contention multiplier is
        applied by the caller, like every other p0)."""
        if src.name == dst.name:
            return 0.0
        by = stage.kv_bytes_per_token() * max(ctx_tokens, 0)
        return by / self.link_bandwidth(src, dst) + dst.overhead

    # -- paged-KV tier model (kv_pages subsystem) -------------------------
    # Tier names are PU names (tier 0, pinned arenas), "dram" (tier 1,
    # shared spill pool) and "disk" (tier 2, UFS-class storage).

    def kv_capacity(self, tier: str) -> float:
        """Capacity in bytes of one KV tier; ``inf`` = unbounded (specs
        that predate the tier model, e.g. TPU slices, never evict)."""
        if tier == "disk":
            return float("inf")
        if tier == "dram":
            return self.soc.kv_dram_pool or float("inf")
        return self.soc.pu(tier).kv_arena or float("inf")

    def _tier_bw(self, tier: str, pu: PU) -> float:
        """Effective copy bandwidth between a spill tier and a PU arena."""
        if tier == "disk":
            # storage reads stream at the UFS link, never above what the
            # PU side can absorb; unspecified = a conservative DRAM slice
            return min(self.soc.disk_bw or 0.05 * self.soc.dram_bw,
                       pu.mem_bw)
        # dram pool <-> PU arena: one read + one write over the shared bus
        return min(0.5 * self.soc.dram_bw, pu.mem_bw)

    def tier_transfer_cost(self, stage: StageModel, src: str, dst: str,
                           tokens: int) -> float:
        """Seconds to move ``tokens`` of ``stage``'s KV pages between two
        tiers (uncontended, like every other p0).  PU→PU pairs delegate to
        :meth:`migrate_cost` so the paged path prices link hops identically
        to the monolithic tracker."""
        if src == dst:
            return 0.0
        names = {p.name for p in self.soc.pus}
        if src in names and dst in names:
            return self.migrate_cost(stage, self.soc.pu(src),
                                     self.soc.pu(dst), tokens)
        by = stage.kv_bytes_per_token() * max(tokens, 0)
        if src in names:                       # spill: arena -> pool/disk
            return by / self._tier_bw(dst, self.soc.pu(src))
        if dst in names:                       # fetch: pool/disk -> arena
            p = self.soc.pu(dst)
            return by / self._tier_bw(src, p) + p.overhead
        # dram <-> disk (cascade demotion): storage link is the bottleneck
        bw = self.soc.disk_bw or 0.05 * self.soc.dram_bw
        return by / bw

    # -- speculative decoding (draft/verify pairs) ------------------------

    def spec_verify_p0(self, stage: StageModel, pu: PU, draft_width: int,
                       width: int = 1) -> float:
        """Base latency of ONE verify pass: the target model scores
        ``draft_width + 1`` positions per resident sequence in a single
        weight sweep — the speculative win, since a memory-bound decode
        otherwise pays one sweep *per token*.  Compute scales with the
        scored positions and the resident width; bytes do not."""
        w = max(int(draft_width), 0)
        rw = max(int(width), 1)
        by = stage.params * stage.bytes_per_param
        fl = stage.flops(1, w + 1) * rw
        weff = _shape_eff(pu, rw) if rw > 1 else 1.0
        t = max(fl / (pu.peak_flops * pu.eff_stream * weff),
                by / (pu.mem_bw * pu.mem_eff_stream))
        return t + pu.overhead + pu.step_overhead

    def spec_accept(self, draft: StageModel, verify: StageModel) -> float:
        """Ground-truth accept rate of ``draft`` proposing for ``verify``:
        a smooth deterministic proxy in the capacity ratio (a draft 1/16
        the size still agrees on most easy tokens — the quarter-power
        keeps the curve in the empirically reported 0.6–0.9 band),
        clipped away from the degenerate extremes."""
        ratio = max(draft.params, 1) / max(verify.params, 1)
        return float(min(max(ratio ** 0.25, 0.05), 0.95))

    def phi(self, stage: StageModel, B: float) -> float:
        """Contention slowdown φ_v(B) ≥ 1 (Eq. 1)."""
        soc = self.soc
        x = B / soc.dram_bw
        base = 1.0 + soc.phi_gamma * max(0.0, x - soc.phi_knee) ** 2
        # memory-bound stages feel contention harder
        sens = {"stream_decode": 1.6, "search": 1.4, "batchable": 1.0,
                "stream_prefill": 0.8, "io": 0.0}[stage.kind]
        return 1.0 + (base - 1.0) * sens


# ---------------------------------------------------------------------------
# profiled (regression) estimates — what the scheduler sees (§5)
# ---------------------------------------------------------------------------

class LinearPerfModel:
    """Profiled estimates, as in the paper (§5, after Band [13]/CoDL [14]):
    the offline-profiled candidate set N_{m,k} keeps its *measured* values
    in a lookup table; a multi-feature linear regression interpolates the
    irregular (off-grid) workload sizes."""

    def __init__(self):
        self.coef: Dict[Tuple[str, str], np.ndarray] = {}
        self.bw_coef: Dict[Tuple[str, str], np.ndarray] = {}
        self.phi_coef: Dict[str, np.ndarray] = {}
        self.table: Dict[Tuple[str, str], Dict[int, Tuple[float, float]]] = {}
        # batched-decode profile: (stage, pu) -> {(width, group): (p0, bw)}
        # plus a log-space regression for off-grid (width, group) shapes —
        # what Eq. 3 enumerates over the *current* width of a resident
        # continuous-batching decode group
        self.decode_table: Dict[Tuple[str, str],
                                Dict[Tuple[int, int],
                                     Tuple[float, float]]] = {}
        self.decode_coef: Dict[Tuple[str, str], np.ndarray] = {}
        self.decode_bw_coef: Dict[Tuple[str, str], np.ndarray] = {}
        # KV-migration profile (decode stages): (stage, src_pu, dst_pu) ->
        # (intercept, seconds-per-context-token) fitted over MIGRATE_CTX —
        # what prices a resident decode batch moving PU, replacing the
        # decode_migrate_cost constant (footprint / PU-pair link bandwidth)
        self.migrate_coef: Dict[Tuple[str, str, str], Tuple[float, float]] = {}
        # per-stage KV bytes per context token (copied exactly from the
        # profiled StageModels) — the residency tracker's footprint unit
        self.kv_bytes: Dict[str, float] = {}
        # paged-KV tier profile: (stage, src_tier, dst_tier) ->
        # (intercept, seconds-per-token) lines for spill/fetch hops that
        # involve the "dram"/"disk" tiers (PU↔PU pairs live in
        # migrate_coef), plus the profiled per-tier capacities in bytes
        # (0 = unbounded) the page table evicts against
        self.fetch_coef: Dict[Tuple[str, str, str], Tuple[float, float]] = {}
        self.kv_tiers: Dict[str, float] = {}
        # speculative-decoding profile (spec_decode subsystem):
        # - spec_table: (verify stage, pu) -> {(draft_width, width):
        #   (verify-pass p0, verify-pass bandwidth)} — one target sweep
        #   scoring draft_width+1 positions per resident
        # - spec_pair: (draft stage, verify stage, draft_pu, verify_pu) ->
        #   {(draft_width, width): (t_draft, t_verify)} — the coupled
        #   per-pass pair the effective-throughput term is built from
        # - spec_accept0: (draft stage, verify stage) -> profiled accept
        #   rate prior (the EWMA's init before any observed rounds)
        self.spec_table: Dict[Tuple[str, str],
                              Dict[Tuple[int, int],
                                   Tuple[float, float]]] = {}
        self.spec_pair: Dict[Tuple[str, str, str, str],
                             Dict[Tuple[int, int],
                                  Tuple[float, float]]] = {}
        self.spec_accept0: Dict[Tuple[str, str], float] = {}

    @staticmethod
    def _feats(n: np.ndarray, tile: int) -> np.ndarray:
        """Features for the log-space linear fit: latency curves span 4+
        orders of magnitude across batch sizes, so the regression targets
        log(p0) — positive by construction, multiplicatively accurate."""
        n = np.asarray(n, dtype=np.float64)
        frac = (n % tile) / max(tile, 1)
        ln = np.log(np.maximum(n, 1.0))
        return np.stack([np.ones_like(n), ln, ln * ln, frac], axis=-1)

    @staticmethod
    def _dfeats(w: np.ndarray, g: np.ndarray, tile: int) -> np.ndarray:
        """Features for the batched-decode fit over (width, token group)."""
        w = np.asarray(w, dtype=np.float64)
        g = np.asarray(g, dtype=np.float64)
        lw = np.log(np.maximum(w, 1.0))
        lg = np.log(np.maximum(g, 1.0))
        frac = (w % tile) / max(tile, 1)
        return np.stack([np.ones_like(w), lw, lg, lw * lg, lw * lw, frac],
                        axis=-1)

    def fit(self, gt: GroundTruthPerf,
            batch_grid: Sequence[int] = (1, 2, 4, 8, 16, 24, 32, 48, 64, 96,
                                         128, 192, 256),
            bw_grid: Optional[Sequence[float]] = None,
            noise: float = 0.0, seed: int = 0) -> "LinearPerfModel":
        rng = np.random.default_rng(seed)
        for sname, stage in gt.stages.items():
            for pu in gt.soc.pus:
                if not gt.supported(stage, pu):
                    continue
                ns = np.array(batch_grid)
                ys, bs = [], []
                tab: Dict[int, Tuple[float, float]] = {}
                for n in ns:
                    c = Config(pu.name, int(n))
                    y = gt.p0(stage, pu, c)
                    b = gt.bandwidth(stage, pu, c)
                    if noise:
                        y *= float(1 + rng.normal(0, noise))
                        b *= float(1 + rng.normal(0, noise))
                    ys.append(y)
                    bs.append(b)
                    tab[int(n)] = (y, b)
                self.table[(sname, pu.name)] = tab
                X = self._feats(ns, pu.tile)
                self.coef[(sname, pu.name)] = np.linalg.lstsq(
                    X, np.log(np.maximum(ys, 1e-9)), rcond=None)[0]
                self.bw_coef[(sname, pu.name)] = np.linalg.lstsq(
                    X, np.log(np.maximum(bs, 1e-3)), rcond=None)[0]
                if stage.kind == "stream_decode":
                    self._fit_decode(gt, sname, stage, pu, rng, noise)
            # φ: quadratic fit in B/B0 above the knee
            Bs = np.linspace(0, 1.6 * gt.soc.dram_bw, 24)
            phis = np.array([gt.phi(stage, B) for B in Bs])
            Xp = np.stack([np.ones_like(Bs), Bs / gt.soc.dram_bw,
                           (Bs / gt.soc.dram_bw) ** 2], axis=-1)
            self.phi_coef[sname] = np.linalg.lstsq(Xp, phis, rcond=None)[0]
        self._tiles = {pu.name: pu.tile for pu in gt.soc.pus}
        self._b0 = gt.soc.dram_bw
        # KV-migration grid, after every latency fit so the noise rng
        # stream is untouched: migration is a bulk copy, linear in bytes,
        # so the ctx-grid samples pin an exact (intercept, slope) line per
        # (decode stage, PU pair)
        for sname, stage in gt.stages.items():
            if stage.kind != "stream_decode":
                continue
            self.kv_bytes[sname] = stage.kv_bytes_per_token()
            pus = [p for p in gt.soc.pus if gt.supported(stage, p)]
            ctx = np.asarray(self.MIGRATE_CTX, dtype=np.float64)
            X = np.stack([np.ones_like(ctx), ctx], axis=-1)
            for src in pus:
                for dst in pus:
                    if src.name == dst.name:
                        continue
                    ys = [gt.migrate_cost(stage, src, dst, int(c))
                          for c in ctx]
                    a, b = np.linalg.lstsq(X, np.array(ys), rcond=None)[0]
                    self.migrate_coef[(sname, src.name, dst.name)] = (
                        float(a), float(b))
            # tier spill/fetch lines (paged KV): arena <-> dram/disk per
            # decode stage — sampled on the same ctx grid, after every
            # noisy fit so the rng stream stays byte-identical
            for p in pus:
                for tier in ("dram", "disk"):
                    for src, dst in ((p.name, tier), (tier, p.name)):
                        ys = [gt.tier_transfer_cost(stage, src, dst, int(c))
                              for c in ctx]
                        a, b = np.linalg.lstsq(X, np.array(ys),
                                               rcond=None)[0]
                        self.fetch_coef[(sname, src, dst)] = (float(a),
                                                              float(b))
        self.kv_tiers = {p.name: p.kv_arena for p in gt.soc.pus
                         if p.kind != "io"}
        self.kv_tiers["dram"] = gt.soc.kv_dram_pool
        self.kv_tiers["disk"] = 0.0
        # speculative-decoding grid, noiseless and LAST so the rng stream
        # of every fit above is byte-identical whether or not the stage set
        # includes draft companions: per verify stage with an in-tree
        # ``*_draft`` companion, sample one-sweep verify passes and the
        # coupled (draft, verify) per-pass pair over every supported PU
        # pair — what spec_throughput prices Eq. 3 candidates with
        from repro.core.spec_decode import draft_stage_of
        for sname, stage in gt.stages.items():
            if stage.kind != "stream_decode":
                continue
            dname = draft_stage_of(sname)
            if dname is None or dname not in gt.stages:
                continue
            draft = gt.stages[dname]
            self.spec_accept0[(dname, sname)] = gt.spec_accept(draft, stage)
            vpus = [p for p in gt.soc.pus if gt.supported(stage, p)]
            dpus = [p for p in gt.soc.pus if gt.supported(draft, p)]
            for vp in vpus:
                vtab: Dict[Tuple[int, int], Tuple[float, float]] = {}
                for w in self.SPEC_WIDTHS:
                    for rw in self.SPEC_RES_WIDTHS:
                        tv = gt.spec_verify_p0(stage, vp, w, rw)
                        bv = (stage.params * stage.bytes_per_param
                              / max(tv, 1e-9))
                        vtab[(int(w), int(rw))] = (tv, bv)
                self.spec_table[(sname, vp.name)] = vtab
            for dp in dpus:
                for vp in vpus:
                    ptab: Dict[Tuple[int, int], Tuple[float, float]] = {}
                    for w in self.SPEC_WIDTHS:
                        for rw in self.SPEC_RES_WIDTHS:
                            td = gt.p0(draft, dp,
                                       Config(dp.name, int(w),
                                              width=int(rw)))
                            tv = self.spec_table[(sname, vp.name)][
                                (int(w), int(rw))][0]
                            ptab[(int(w), int(rw))] = (td, tv)
                    self.spec_pair[(dname, sname, dp.name, vp.name)] = ptab
        return self

    # context-length grid the migration-cost line is sampled on (tokens)
    MIGRATE_CTX = (256, 1024, 4096, 16384)

    # speculative-decoding grid: draft widths (candidate tokens per verify
    # pass) × resident widths the coupled pair is sampled on
    SPEC_WIDTHS = (1, 2, 3, 4, 6, 8)
    SPEC_RES_WIDTHS = (1, 2, 4, 8)

    def migrate_cost(self, stage: str, src_pu: str, dst_pu: str,
                     ctx_tokens: int) -> Optional[float]:
        """Modeled seconds to move a ``ctx_tokens``-context KV cache of
        ``stage`` from ``src_pu`` to ``dst_pu`` (the fitted footprint ÷
        link-bandwidth line).  ``None`` when this profile predates the
        migration grid or the pair was never profiled — callers fall back
        to ``SchedulerConfig.decode_migrate_cost``."""
        if src_pu == dst_pu:
            return 0.0
        co = self.migrate_coef.get((stage, src_pu, dst_pu))
        if co is None:
            return None
        return max(co[0] + co[1] * max(ctx_tokens, 0), 0.0)

    def fetch_cost(self, stage: str, src: str, dst: str,
                   tokens: int) -> Optional[float]:
        """Modeled seconds to move ``tokens`` of ``stage``'s KV pages
        between tiers.  PU↔PU pairs resolve through the migration lines;
        hops involving "dram"/"disk" through the tier-fetch lines.
        ``None`` for profiles that predate either grid."""
        if src == dst:
            return 0.0
        co = self.fetch_coef.get((stage, src, dst))
        if co is not None:
            return max(co[0] + co[1] * max(tokens, 0), 0.0)
        return self.migrate_cost(stage, src, dst, tokens)

    def kv_capacity(self, tier: str) -> float:
        """Profiled byte capacity of one KV tier (inf = unbounded)."""
        cap = self.kv_tiers.get(tier, 0.0)
        return cap or float("inf")

    def prefill_cost(self, stage: str, tokens: int) -> Optional[float]:
        """Modeled seconds to (re-)prefill ``tokens`` of ``stage`` on its
        best profiled PU — the alternative a prefix-cache hit on a demoted
        page must beat (the hit-or-recompute rule): fetching KV up from a
        cold tier only wins when the transfer is cheaper than simply
        recomputing the prefix.  First-order estimate (one pass at
        ``batch=tokens``); ``None`` when the stage was never profiled, in
        which case callers keep the legacy always-hit behaviour."""
        best: Optional[float] = None
        for (s, pu) in sorted(self.coef):
            if s != stage:
                continue
            c = self.p0(s, pu, max(int(tokens), 1))
            if best is None or c < best:
                best = c
        return best

    # decode-batching profile grid: widths × token groups (width 1 lives in
    # the ordinary table; the scheduler's group candidates are clipped to
    # the stream's remaining horizon, so off-grid shapes hit the regression)
    DECODE_WIDTHS = (2, 3, 4, 6, 8)
    DECODE_GROUPS = (4, 8, 16, 24, 32, 48, 64)

    def _fit_decode(self, gt: GroundTruthPerf, sname: str, stage, pu,
                    rng, noise: float) -> None:
        tab: Dict[Tuple[int, int], Tuple[float, float]] = {}
        ws, gs, ys, bs = [], [], [], []
        for w in self.DECODE_WIDTHS:
            for g in self.DECODE_GROUPS:
                c = Config(pu.name, int(g), width=int(w))
                y = gt.p0(stage, pu, c)
                b = gt.bandwidth(stage, pu, c)
                if noise:
                    y *= float(1 + rng.normal(0, noise))
                    b *= float(1 + rng.normal(0, noise))
                tab[(int(w), int(g))] = (y, b)
                ws.append(w)
                gs.append(g)
                ys.append(y)
                bs.append(b)
        self.decode_table[(sname, pu.name)] = tab
        X = self._dfeats(np.array(ws), np.array(gs), pu.tile)
        self.decode_coef[(sname, pu.name)] = np.linalg.lstsq(
            X, np.log(np.maximum(ys, 1e-9)), rcond=None)[0]
        self.decode_bw_coef[(sname, pu.name)] = np.linalg.lstsq(
            X, np.log(np.maximum(bs, 1e-3)), rcond=None)[0]

    def supported(self, stage: str, pu: str) -> bool:
        return (stage, pu) in self.coef

    # -- persistence (ship profiles with a deployment, paper §5) ----------
    def save(self, path: str) -> None:
        import json
        blob = {
            "coef": {f"{s}|{p}": c.tolist() for (s, p), c in
                     self.coef.items()},
            "bw_coef": {f"{s}|{p}": c.tolist() for (s, p), c in
                        self.bw_coef.items()},
            "phi_coef": {s: c.tolist() for s, c in self.phi_coef.items()},
            "table": {f"{s}|{p}": {str(n): v for n, v in tab.items()}
                      for (s, p), tab in self.table.items()},
            "decode_coef": {f"{s}|{p}": c.tolist() for (s, p), c in
                            self.decode_coef.items()},
            "decode_bw_coef": {f"{s}|{p}": c.tolist() for (s, p), c in
                               self.decode_bw_coef.items()},
            "decode_table": {f"{s}|{p}": {f"{w},{g}": v
                                          for (w, g), v in tab.items()}
                             for (s, p), tab in self.decode_table.items()},
            "migrate_coef": {f"{s}|{a}|{b}": list(v) for (s, a, b), v in
                             self.migrate_coef.items()},
            "kv_bytes": dict(self.kv_bytes),
            "fetch_coef": {f"{s}|{a}|{b}": list(v) for (s, a, b), v in
                           self.fetch_coef.items()},
            "kv_tiers": dict(self.kv_tiers),
            "spec_table": {f"{s}|{p}": {f"{w},{rw}": list(v)
                                        for (w, rw), v in tab.items()}
                           for (s, p), tab in self.spec_table.items()},
            "spec_pair": {f"{d}|{s}|{a}|{b}": {f"{w},{rw}": list(v)
                                               for (w, rw), v in
                                               tab.items()}
                          for (d, s, a, b), tab in self.spec_pair.items()},
            "spec_accept0": {f"{d}|{s}": v for (d, s), v in
                             self.spec_accept0.items()},
            "tiles": self._tiles, "b0": self._b0,
        }
        with open(path, "w") as f:
            json.dump(blob, f)

    @classmethod
    def load(cls, path: str) -> "LinearPerfModel":
        import json
        with open(path) as f:
            blob = json.load(f)
        m = cls()
        m.coef = {tuple(k.split("|")): np.array(v)
                  for k, v in blob["coef"].items()}
        m.bw_coef = {tuple(k.split("|")): np.array(v)
                     for k, v in blob["bw_coef"].items()}
        m.phi_coef = {k: np.array(v) for k, v in blob["phi_coef"].items()}
        m.table = {tuple(k.split("|")): {int(n): tuple(v)
                                         for n, v in tab.items()}
                   for k, tab in blob["table"].items()}
        # decode-batching profile (absent in pre-serving profile files)
        m.decode_coef = {tuple(k.split("|")): np.array(v)
                         for k, v in blob.get("decode_coef", {}).items()}
        m.decode_bw_coef = {tuple(k.split("|")): np.array(v)
                            for k, v in blob.get("decode_bw_coef",
                                                 {}).items()}
        m.decode_table = {
            tuple(k.split("|")): {tuple(int(x) for x in wg.split(",")):
                                  tuple(v) for wg, v in tab.items()}
            for k, tab in blob.get("decode_table", {}).items()}
        # KV-migration profile (absent in pre-residency profile files:
        # migrate_cost then returns None and callers keep the constant)
        m.migrate_coef = {tuple(k.split("|")): tuple(v)
                          for k, v in blob.get("migrate_coef", {}).items()}
        m.kv_bytes = dict(blob.get("kv_bytes", {}))
        # paged-KV tier profile (absent in pre-paging profile files:
        # fetch_cost falls back to migrate lines, capacities to unbounded)
        m.fetch_coef = {tuple(k.split("|")): tuple(v)
                        for k, v in blob.get("fetch_coef", {}).items()}
        m.kv_tiers = dict(blob.get("kv_tiers", {}))
        # speculative-decoding profile (absent in pre-spec profile files:
        # the spec queries return None/() and spec scoring is skipped)
        m.spec_table = {
            tuple(k.split("|")): {tuple(int(x) for x in wr.split(",")):
                                  tuple(v) for wr, v in tab.items()}
            for k, tab in blob.get("spec_table", {}).items()}
        m.spec_pair = {
            tuple(k.split("|")): {tuple(int(x) for x in wr.split(",")):
                                  tuple(v) for wr, v in tab.items()}
            for k, tab in blob.get("spec_pair", {}).items()}
        m.spec_accept0 = {tuple(k.split("|")): float(v)
                          for k, v in blob.get("spec_accept0", {}).items()}
        m._tiles = blob["tiles"]
        m._b0 = blob["b0"]
        return m

    def p0(self, stage: str, pu: str, batch: int) -> float:
        hit = self.table.get((stage, pu), {}).get(int(batch))
        if hit is not None:
            return hit[0]                    # profiled grid point: exact
        X = self._feats(np.array([batch]), self._tiles[pu])
        return float(np.exp((X @ self.coef[(stage, pu)])[0]))

    def bandwidth(self, stage: str, pu: str, batch: int) -> float:
        hit = self.table.get((stage, pu), {}).get(int(batch))
        if hit is not None:
            return hit[1]
        X = self._feats(np.array([batch]), self._tiles[pu])
        return float(np.exp((X @ self.bw_coef[(stage, pu)])[0]))

    def p0_decode(self, stage: str, pu: str, width: int, group: int) -> float:
        """Base latency of one token-group pass of a width-``width`` resident
        decode batch (continuous cross-query batching).  width 1 degrades to
        the ordinary stream profile."""
        if width <= 1:
            return self.p0(stage, pu, group)
        hit = self.decode_table.get((stage, pu), {}).get((int(width),
                                                          int(group)))
        if hit is not None:
            return hit[0]
        if (stage, pu) not in self.decode_coef:
            # profile saved before the decode-batching grid existed: decode
            # is memory-bound on the per-step weight sweep, so the
            # single-stream pass cost is the first-order width-w estimate
            return self.p0(stage, pu, group)
        X = self._dfeats(np.array([width]), np.array([group]),
                         self._tiles[pu])
        return float(np.exp((X @ self.decode_coef[(stage, pu)])[0]))

    def bandwidth_decode(self, stage: str, pu: str, width: int,
                         group: int) -> float:
        """Shared-domain demand of a batched decode pass: the weight sweep is
        read once per step regardless of width, so per-sequence pressure
        drops as the batch widens."""
        if width <= 1:
            return self.bandwidth(stage, pu, group)
        hit = self.decode_table.get((stage, pu), {}).get((int(width),
                                                          int(group)))
        if hit is not None:
            return hit[1]
        if (stage, pu) not in self.decode_bw_coef:
            return self.bandwidth(stage, pu, group)   # pre-serving profile
        X = self._dfeats(np.array([width]), np.array([group]),
                         self._tiles[pu])
        return float(np.exp((X @ self.decode_bw_coef[(stage, pu)])[0]))

    # -- profiled-grid queries (adaptive batching policy) -----------------
    # The batching policy enumerates these grids the way Eq. 3 enumerates
    # n*: caps and windows are *derived* from the profiled sweet spot per
    # (stage, PU) instead of hand-picked constants (ROADMAP item 1).

    def batch_grid(self, stage: str, pu: str) -> Tuple[int, ...]:
        """Profiled batch sizes for ``(stage, pu)`` (the measured table
        points — the only shapes the policy trusts for cap derivation)."""
        return tuple(sorted(self.table.get((stage, pu), {})))

    def decode_width_grid(self, stage: str, pu: str) -> Tuple[int, ...]:
        """Profiled resident widths of the decode ``(width, group)`` grid
        (empty for non-decode stages / pre-serving profile files)."""
        return tuple(sorted({w for (w, _g)
                             in self.decode_table.get((stage, pu), {})}))

    def decode_group_grid(self, stage: str, pu: str) -> Tuple[int, ...]:
        """Profiled token groups of the decode ``(width, group)`` grid."""
        return tuple(sorted({g for (_w, g)
                             in self.decode_table.get((stage, pu), {})}))

    # -- speculative-decoding queries (spec_decode subsystem) -------------

    @staticmethod
    def _spec_nearest(tab: Dict[Tuple[int, int], Tuple[float, float]],
                      w: int, rw: int) -> Optional[Tuple[float, float]]:
        """Exact grid hit, else the nearest profiled (draft_width, width)
        point — the policy only enumerates grid widths, so off-grid
        queries are rare corrective paths, not hot ones."""
        hit = tab.get((int(w), int(rw)))
        if hit is not None:
            return hit
        if not tab:
            return None
        key = min(tab, key=lambda k: (abs(k[0] - w) + abs(k[1] - rw),
                                      k[0], k[1]))
        return tab[key]

    def spec_verify_p0(self, stage: str, pu: str, draft_width: int,
                       width: int = 1) -> Optional[float]:
        """Modeled latency of one verify pass (one target sweep scoring
        ``draft_width + 1`` positions per resident).  ``None`` when this
        profile predates the spec grid or the stage has no companion."""
        hit = self._spec_nearest(self.spec_table.get((stage, pu), {}),
                                 draft_width, width)
        return None if hit is None else hit[0]

    def spec_bandwidth(self, stage: str, pu: str, draft_width: int,
                       width: int = 1) -> Optional[float]:
        """Shared-domain demand of one verify pass (one weight sweep over
        the pass time — speculation amortizes bytes over ~1+α·w tokens)."""
        hit = self._spec_nearest(self.spec_table.get((stage, pu), {}),
                                 draft_width, width)
        return None if hit is None else hit[1]

    def spec_pair_time(self, draft_stage: str, verify_stage: str,
                       draft_pu: str, verify_pu: str, draft_width: int,
                       width: int = 1
                       ) -> Optional[Tuple[float, float]]:
        """``(t_draft, t_verify)`` of one coupled pass on the PU pair
        (``None`` when the pair was never profiled)."""
        tab = self.spec_pair.get(
            (draft_stage, verify_stage, draft_pu, verify_pu))
        if tab is None:
            return None
        return self._spec_nearest(tab, draft_width, width)

    def spec_throughput(self, draft_stage: str, verify_stage: str,
                        draft_pu: str, verify_pu: str, draft_width: int,
                        alpha: float, width: int = 1) -> Optional[float]:
        """Accept-rate-aware effective token rate of the coupled pair:
        ``width * (1 + α·w) / cost`` tokens/s, where cost is the
        pipelined ``max(t_draft, t_verify)`` on distinct PUs (draft
        streams the next candidates while the target verifies the
        previous group) and the serial sum on a shared PU."""
        pair = self.spec_pair_time(draft_stage, verify_stage, draft_pu,
                                   verify_pu, draft_width, width)
        if pair is None:
            return None
        td, tv = pair
        cost = max(td, tv) if draft_pu != verify_pu else td + tv
        a = max(min(float(alpha), 1.0), 0.0)
        w = max(int(draft_width), 0)
        return max(width, 1) * (1.0 + a * w) / max(cost, 1e-9)

    def spec_width_grid(self, draft_stage: str, verify_stage: str,
                        draft_pu: str, verify_pu: str) -> Tuple[int, ...]:
        """Profiled draft widths of the coupled pair (empty when the pair
        was never profiled — spec scoring then falls back to plain
        decode)."""
        tab = self.spec_pair.get(
            (draft_stage, verify_stage, draft_pu, verify_pu))
        if not tab:
            return ()
        return tuple(sorted({w for (w, _rw) in tab}))

    def spec_accept_init(self, draft_stage: str,
                         verify_stage: str) -> Optional[float]:
        """Profiled accept-rate prior for the pair (EWMA init), ``None``
        for profiles that predate the spec grid."""
        return self.spec_accept0.get((draft_stage, verify_stage))

    def per_item(self, stage: str, pu: str, batch: int) -> float:
        """Per-member latency of one pass at ``batch`` — the curve whose
        knee the coalesce cap sits at (Fig. 2's "larger batches do not
        always yield better per-item efficiency")."""
        return self.p0(stage, pu, batch) / max(batch, 1)

    def per_member_decode(self, stage: str, pu: str, width: int,
                          group: int) -> float:
        """Per-resident latency of one width-``width`` token-group pass.
        Width 1 degrades to the ordinary single-stream profile."""
        return self.p0_decode(stage, pu, width, group) / max(width, 1)

    def decode_marginal_gains(self, stage: str, pu: str, group: int
                              ) -> List[Tuple[int, float]]:
        """``[(width, gain)]`` over the profiled width grid: ``gain`` is the
        drop in per-member latency when the resident batch widens from the
        previous grid width (positive while sharing the per-step weight
        sweep still pays, negative past the spill knee)."""
        widths = self.decode_width_grid(stage, pu)
        out: List[Tuple[int, float]] = []
        prev = self.p0(stage, pu, group)      # width-1 solo baseline
        for w in widths:
            cur = self.per_member_decode(stage, pu, w, group)
            out.append((w, prev - cur))
            prev = cur
        return out

    def batch_marginal_gains(self, stage: str, pu: str
                             ) -> List[Tuple[int, float]]:
        """``[(batch, gain)]`` over the profiled batch grid — the coalesce
        width profile for batchable stages (the dual of the decode grid)."""
        grid = self.batch_grid(stage, pu)
        out: List[Tuple[int, float]] = []
        prev = None
        for n in grid:
            cur = self.per_item(stage, pu, n)
            out.append((n, 0.0 if prev is None else prev - cur))
            prev = cur
        return out

    def dispatch_overhead(self, stage: str, pu: str) -> float:
        """Fitted per-dispatch overhead: extrapolate the profiled latency
        line to batch → 0 via the two smallest grid points (p0 ≈ o + c·n
        ⇒ o = 2·p0(n1) − p0(2·n1) when n2 = 2·n1; clamped ≥ 0).  This is
        the invocation cost one coalesced member *saves* by riding a fused
        dispatch instead of paying its own."""
        grid = self.batch_grid(stage, pu)
        if not grid:
            return 0.0
        if len(grid) == 1:
            return self.p0(stage, pu, grid[0])
        n1, n2 = grid[0], grid[1]
        p1, p2 = self.p0(stage, pu, n1), self.p0(stage, pu, n2)
        slope = (p2 - p1) / max(n2 - n1, 1)
        return max(p1 - slope * n1, 0.0)

    def phi(self, stage: str, B: float) -> float:
        """Monotone projection of the fitted quadratic: a convex parabola is
        flat at its minimum below the vertex (the ground truth is monotone;
        the raw fit may dip)."""
        c0, c1, c2 = self.phi_coef[stage]
        x = B / self._b0
        if c2 > 1e-12:
            x = max(x, -c1 / (2 * c2))
        val = c0 + c1 * x + c2 * x * x
        return float(max(1.0, val))
