"""Event-driven SoC simulator — the execution substrate for validation.

Plays the role of the phones in paper §6: executes a (dynamic) RAG DAG
against the *ground-truth* hardware model, with time-varying bandwidth
contention — node progress rates are rescaled by 1/φ(B(t)) whenever the
active set changes, so the realized latency is p⁰·φ̄ exactly as in Eq. 2.

The scheduler under test only sees the fitted LinearPerfModel; modelling
error is therefore part of the experiment, as on real hardware.

Fault-tolerance hooks: ``straggler_prob``/``fail_prob`` perturb node
execution; the scheduler's speculative re-dispatch (straggler_factor) and
retry close the loop — exercised by tests/test_fault_tolerance.py.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core.dag import DONE, READY, DynamicDAG, Node
from repro.core.events import (EV_CANCELLED, EV_DONE, EV_KV_FETCH,
                               EV_KV_MIGRATE, EV_PREEMPT, EV_REDISPATCH,
                               EV_START, EV_TOKENS, SPILL_TIERS)
from repro.core.partitioner import (ceil_passes, dispatch_passes,
                                    fused_boundary_index)
from repro.core.perf_model import Config, GroundTruthPerf
from repro.core.scheduler import Dispatch, HeroScheduler
from repro.core.spec_decode import spec_passes


@dataclass
class ActiveTask:
    node: Node
    pu: str
    batch: int
    work_left: float          # seconds of uncontended work remaining
    bandwidth: float          # ground-truth demand b_v(c)
    rate: float = 1.0         # 1/φ(B(t)) — updated on every event
    dispatched_at: float = 0.0
    predicted: float = 0.0    # scheduler's ETA (straggler detection)
    work_total: float = 0.0   # seconds at dispatch (progress = 1 - left/total)


@dataclass
class SimResult:
    makespan: float
    timeline: List[Tuple[float, str, str]]         # (t, event, node)
    pu_busy: Dict[str, float]
    dispatches: int = 0
    redispatches: int = 0
    failures: int = 0

    def utilization(self, pu: str) -> float:
        return self.pu_busy.get(pu, 0.0) / max(self.makespan, 1e-9)


Observer = Callable[[float, str, "Node"], None]


class Simulator:
    def __init__(self, gt: GroundTruthPerf, scheduler: HeroScheduler,
                 straggler_prob: float = 0.0, straggler_slow: float = 4.0,
                 fail_prob: float = 0.0, seed: int = 0,
                 observer: Optional[Observer] = None):
        self.gt = gt
        self.sched = scheduler
        self.rng = np.random.default_rng(seed)
        self.straggler_prob = straggler_prob
        self.straggler_slow = straggler_slow
        self.fail_prob = fail_prob
        # streaming hook: (sim time, "start"|"done"|"redispatch", node) —
        # what HeroSession's per-query callbacks attach to
        self.observer = observer

    def _note(self, timeline, t: float, event: str, node: Node):
        timeline.append((t, event, node.id))
        if self.observer is not None:
            self.observer(t, event, node)
        # a fused (cross-query coalesced) dispatch is every member's
        # lifecycle event too: per-query timelines and streaming callbacks
        # see member ids, not the synthetic fused id.  A decode-round
        # boundary is "done" only for members that left; residents that
        # merely advanced emit a token-group "tokens" event instead.
        is_round = bool(node.payload.get("decode_round"))
        for m in node.payload.get("members", ()):
            ev = event
            if is_round and event == EV_DONE and m.status != DONE:
                ev = EV_TOKENS
            self._note(timeline, t, ev, m)

    # -- main loop -----------------------------------------------------------
    def run(self, dag: DynamicDAG, max_time: float = 3600.0) -> SimResult:
        t = 0.0
        active: Dict[str, ActiveTask] = {}       # node id -> task
        pu_free: Dict[str, bool] = {p: True for p in self.sched.pus}
        pu_free.setdefault("io", True)
        busy_acc: Dict[str, float] = {p: 0.0 for p in pu_free}
        timeline: List[Tuple[float, str, str]] = []
        result = SimResult(0.0, timeline, busy_acc)

        def B_total() -> float:
            return sum(a.bandwidth for a in active.values())

        def refresh_rates():
            B = B_total()
            for a in active.values():
                stage = self.gt.stages.get(a.node.stage)
                phi = self.gt.phi(stage, B) if stage is not None else 1.0
                a.rate = 1.0 / phi

        def busy_until(now: float) -> Dict[str, float]:
            # scheduler-visible queue estimates (its own predictions)
            return {a.pu: a.dispatched_at + a.predicted
                    for a in active.values()}

        def dispatch(now: float):
            while True:
                if dag._cancel_pending:
                    self._reap(dag, active, pu_free, timeline, now)
                    refresh_rates()   # aborted tasks left the active set
                idle = [p for p, f in pu_free.items() if f]
                if not idle:
                    return
                decisions = self.sched.dispatch_pass(dag, now, idle,
                                                     B_total(),
                                                     busy_until(now))
                for d in decisions:
                    self._start(d, now, active, pu_free, timeline)
                    result.dispatches += 1
                if decisions:
                    refresh_rates()
                # boundary splits release READY members mid-pass: loop so
                # they can take a still-idle PU at the same instant.  Each
                # split strictly shrinks a fused membership, so this
                # terminates; with preempt off the body runs exactly once.
                if not (self.sched.cfg.preempt and self._apply_preemptions(
                        dag, active, now, timeline)):
                    return

        dispatch(t)
        guard = 0
        while dag.unfinished() and t < max_time:
            guard += 1
            if guard > 200_000:
                raise RuntimeError("simulator livelock")
            if not active:
                if dag._cancel_pending:
                    self._reap(dag, active, pu_free, timeline, t)
                    if not dag.unfinished():
                        break
                # nothing running but work remains: deadlock unless new
                # dispatch succeeds (e.g. after elastic PU change)
                decisions = self.sched.dispatch_pass(
                    dag, t, [p for p, f in pu_free.items() if f], 0.0)
                if not decisions:
                    raise RuntimeError(
                        f"deadlock at t={t:.3f}: "
                        f"{[n.id for n in dag.unfinished()][:6]}")
                for d in decisions:
                    self._start(d, t, active, pu_free, timeline)
                    result.dispatches += 1
                refresh_rates()
                continue
            # next completion event under current rates
            nid, task = min(active.items(),
                            key=lambda kv: kv[1].work_left / kv[1].rate)
            dt = task.work_left / task.rate
            # straggler detection across ALL active tasks: re-dispatch any
            # task whose φ-adjusted ETA is exceeded (capped per node so
            # mispredictions cannot loop)
            spec_nid, dt_spec = None, math.inf
            for anid, a in active.items():
                if a.node.payload.get("redispatches", 0) >= 4:
                    continue
                phi_now = 1.0 / max(a.rate, 1e-6)
                deadline = (a.predicted * phi_now
                            * self.sched.cfg.straggler_factor + 1e-3)
                remaining_to_deadline = deadline - (t - a.dispatched_at)
                will_complete_in = a.work_left / max(a.rate, 1e-12)
                if will_complete_in <= max(remaining_to_deadline, 0.0):
                    continue               # finishes before its deadline
                cand = max(remaining_to_deadline, 0.0)
                if cand < dt_spec:
                    spec_nid, dt_spec = anid, cand
            step = min(dt, dt_spec)
            # advance time
            for a in active.values():
                a.work_left -= step * a.rate
                busy_acc[a.pu] = busy_acc.get(a.pu, 0.0) + step
            t += step
            if dt_spec < dt:
                # speculative re-dispatch: cancel and retry elsewhere
                self._cancel(spec_nid, active, pu_free, timeline, t)
                result.redispatches += 1
                dispatch(t)
                continue
            # completion — mark_done BEFORE emitting "done", mirroring
            # HeroRuntime: observers must see final node state (and fused
            # fan-out metadata) identically on both substrates
            done = active.pop(nid)
            pu_free[done.pu] = True
            prog = done.node.payload.get("on_progress")
            dag.mark_done(nid, t)
            if prog is not None and done.node.kind == "stream_decode":
                prog(dag, done.node, done.node.workload)
            self._note(timeline, t, EV_DONE, done.node)
            refresh_rates()
            dispatch(t)
        result.makespan = dag.makespan()
        return result

    # -- internals -----------------------------------------------------------
    def _start(self, d: Dispatch, now: float, active, pu_free, timeline):
        # io-kind nodes (web calls, admission timers) need no stage model
        stage = self.gt.stages.get(d.node.stage)
        pu = self.gt.soc.pu(d.pu) if d.pu != "io" else None
        # resident decode batches execute at their current width: the
        # ground truth shares the per-step weight sweep across members
        c = Config(d.pu, d.batch,
                   width=(d.node.payload.get("decode_width", 1)
                          if (d.node.payload.get("decode_round")
                              or d.node.payload.get("draft_round"))
                          else 1))
        if d.node.kind == "io":
            # the scheduler's io prediction (0.35 s round trip, or the
            # remaining admission delay for arrival-timer nodes)
            work, bw = d.predicted_p0, 0.0
        else:
            sds = d.node.payload.get("spec_draft_stage")
            if sds is not None and sds in self.gt.stages:
                # speculative verify round: the ground-truth accept rate
                # (not the scheduler's EWMA estimate) decides how many
                # verify sweeps the token group really takes; each sweep
                # scores w+1 positions in one weight pass, pipelined
                # against the draft stream (max) cross-PU or serialized
                # (sum) on a shared PU.  The true pass count is stamped
                # back so boundary accept counters reflect reality.
                w = d.node.payload.get("spec_width", 1)
                dpu = d.node.payload.get("spec_draft_pu", d.pu)
                dsm = self.gt.stages[sds]
                n_true = spec_passes(d.node.workload, w,
                                     self.gt.spec_accept(dsm, stage))
                d.node.payload["spec_passes"] = n_true
                tv = self.gt.spec_verify_p0(stage, pu, w, c.width)
                td = self.gt.p0(dsm, self.gt.soc.pu(dpu),
                                Config(dpu, w, width=c.width))
                work = n_true * (td + tv if dpu == d.pu else max(td, tv))
            else:
                passes = ceil_passes(d.node.workload, d.batch)
                work = passes * self.gt.p0(stage, pu, c)
            bw = self.gt.bandwidth(stage, pu, c)
            if (d.node.kind == "stream_decode"
                    and not d.node.payload.get("draft_round")
                    and self.sched.kv is not None):
                # KV migration is real physics once residency is tracked:
                # streams (round members or a solo token-group chain)
                # whose caches live on another PU pay the ground-truth
                # transfer before the first step (contention scales it
                # like the rest of the work).  The paged tracker gathers
                # page-granularly and may source from the spill tiers
                # ("dram"/"disk" — a fetch, priced by the tier model);
                # tier_transfer_cost is migrate_cost exactly on PU pairs
                migrated = set()
                for m, src, ctx, _by in self.sched.kv.migrate_for_dispatch(
                        d.node, d.pu):
                    sm = self.gt.stages.get(m.stage, stage)
                    work += self.gt.tier_transfer_cost(sm, src, d.pu, ctx)
                    if src in SPILL_TIERS:
                        self._note(timeline, now, EV_KV_FETCH, m)
                    elif m.id not in migrated:
                        # one event per stream per dispatch: a gather from
                        # several PU arenas is still one cache move, so the
                        # timeline matches kv_migrations exactly
                        migrated.add(m.id)
                        self._note(timeline, now, EV_KV_MIGRATE, m)
            if getattr(self.sched.kv, "paged", False):
                # paged KV accounting accrued since the last dispatch:
                # spill transfers (evictions cascading down the tiers) are
                # charged ground-truth seconds to this dispatch — the
                # arena-pressure physics — and page events land on the
                # timeline (kv_page_hit / kv_evict)
                for sname, src, dst, toks in \
                        self.sched.kv.drain_transfers():
                    sm = self.gt.stages.get(sname)
                    if sm is not None:
                        work += self.gt.tier_transfer_cost(sm, src, dst,
                                                           toks)
                # prefetched stagings were issued under a compute-overlap
                # credit: only the ground-truth residual beyond it lands
                # on this dispatch (the min(issue + fetch, round_end)
                # completion model)
                for sname, src, dst, toks, credit in \
                        self.sched.kv.drain_prefetches():
                    sm = self.gt.stages.get(sname)
                    if sm is not None:
                        work += max(0.0, self.gt.tier_transfer_cost(
                            sm, src, dst, toks) - credit)
                for ev, n2 in self.sched.kv.drain_events():
                    self._note(timeline, now, ev, n2)
        # fault injection (admission timers are control nodes — a gated
        # arrival must stay exact under injected faults)
        is_timer = d.node.payload.get("arrival") is not None
        if not is_timer and self.rng.random() < self.straggler_prob:
            work *= self.straggler_slow
        if not is_timer and self.rng.random() < self.fail_prob:
            work *= 1e6  # never completes; straggler detection reaps it
        # dispatch_passes: a decode round's predicted drain is one pass at
        # the current group, same as the live runtime's heartbeat ETA
        # (value-identical to ceil_passes on every non-round dispatch)
        active[d.node.id] = ActiveTask(
            node=d.node, pu=d.pu, batch=d.batch, work_left=work,
            bandwidth=bw, dispatched_at=now,
            # migrate_s: the scheduler's modeled one-off transfer charge —
            # in the ETA so straggler detection and busy_until see the
            # same total the physics above actually pays
            predicted=(d.predicted_p0 * dispatch_passes(d.node, d.batch)
                       + d.migrate_s),
            work_total=work)
        if d.pu != "io":              # io = network, unbounded concurrency
            pu_free[d.pu] = False
        self._note(timeline, now, EV_START, d.node)

    def _apply_preemptions(self, dag: DynamicDAG, active, t,
                           timeline) -> List[Node]:
        """Execute the boundary splits the scheduler flagged
        (``payload["preempt_split"]``): true progress (1 − left/total of
        ground-truth work) picks the member boundary, the released
        members return READY with their state in place, and the kept
        slice's remaining work / ETA shrink proportionally — the
        in-progress member is inside the kept slice by construction, so
        no executed seconds are discarded."""
        released_all: List[Node] = []
        for a in list(active.values()):
            n = a.node
            if not n.payload.pop("preempt_split", False):
                continue
            done_frac = (1.0 - a.work_left / a.work_total
                         if a.work_total > 0 else 0.0)
            w_before = max(n.workload, 1)
            keep = fused_boundary_index(
                [m.workload for m in n.payload["members"]], done_frac)
            released = dag.preempt_fused(n, keep, prefer_pu=a.pu, t=t)
            if not released:
                continue
            scale = max(n.workload, 1) / w_before
            done_s = a.work_total - a.work_left
            a.work_total *= scale
            a.work_left = max(a.work_total - done_s, 0.0)
            a.predicted *= scale
            for m in released:
                self._note(timeline, t, EV_PREEMPT, m)
            released_all.extend(released)
        return released_all

    def _reap(self, dag: DynamicDAG, active, pu_free, timeline, t):
        """Finalize cancel-requested work at a scheduling point: queued
        nodes collapse via ``reap_cancelled``; in-flight flagged tasks
        are aborted (PU freed, node finalized as cancelled) — then one
        more sweep catches successors the aborts just readied."""
        for n in dag.reap_cancelled(t):
            self._note(timeline, t, EV_CANCELLED, n)
        for nid in [k for k, a in active.items()
                    if a.node.payload.get("cancel_requested")]:
            a = active.pop(nid)
            if a.pu != "io":
                pu_free[a.pu] = True
            n = a.node
            n.status, n.finish = DONE, t
            n.expander = None
            n.payload["cancelled"] = True
            if dag.kv is not None and n.kind == "stream_decode":
                dag.kv.release(n)
            for s in dag._succ.get(nid, ()):
                dag._refresh_status(dag.nodes[s])
            self._note(timeline, t, EV_CANCELLED, n)
        if dag._cancel_pending:
            for n in dag.reap_cancelled(t):
                self._note(timeline, t, EV_CANCELLED, n)

    def _cancel(self, nid: str, active, pu_free, timeline, t):
        task = active.pop(nid)
        if task.pu != "io":
            pu_free[task.pu] = True
        n = task.node
        n.status = READY     # back to the pool; scheduler will remap
        n.start, n.config = -1.0, None
        n.payload["redispatches"] = n.payload.get("redispatches", 0) + 1
        self._note(timeline, t, EV_REDISPATCH, n)
