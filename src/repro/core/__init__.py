# HeRo core: heterogeneous performance modeling + the adaptive online
# scheduler (paper §3-§4), plus the event-driven validation simulator.
from repro.core.dag import DynamicDAG, Node, WorkflowTemplate  # noqa: F401
from repro.core.perf_model import (  # noqa: F401
    PU, SoCSpec, StageModel, GroundTruthPerf, LinearPerfModel, Config,
    snapdragon_8gen3, snapdragon_8gen4, tpu_v5e_slices)
from repro.core.batch_policy import (  # noqa: F401
    AdaptiveBatchPolicy, ArrivalTracker, FixedBatchPolicy, make_policy)
from repro.core.kv_pages import (  # noqa: F401
    KVPage, PagedKVCache, PagedStream, page_keys)
from repro.core.kv_residency import KVResidency, StreamKV  # noqa: F401
from repro.core.scheduler import (  # noqa: F401
    HeroScheduler, SchedulerConfig, strategy_config)
from repro.core.simulator import Simulator, SimResult  # noqa: F401
