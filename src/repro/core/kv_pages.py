"""Paged KV cache: page-table residency, tiered eviction, prefix reuse.

PR 5's :class:`~repro.core.kv_residency.KVResidency` made KV placement
first-class scheduler state, but tracked each decode stream as ONE
monolithic footprint: migration was all-or-nothing, capacity was
unbounded, and the dominant serving pattern — many queries re-prefilling
the *same* retrieved chunks from a shared corpus — paid full prefill
every time.  This module supersedes the monolith with a page table, the
way vLLM-style paged attention and PerCache's hierarchical on-device
cache organize KV state:

- Each decode stream's cache is a list of fixed-size pages
  (``SchedulerConfig.kv_page_tokens`` tokens; page bytes follow from the
  profiled GQA cache shape, ``LinearPerfModel.kv_bytes``) held in a
  tiered store: PU-local arenas (tier 0), a shared-DRAM spill pool
  (tier 1) and disk (tier 2), with per-tier capacities from the
  profiled ``kv_tiers``.
- Eviction is LRU-with-pin: pages referenced by a live stream
  (``refs > 0``) are never demoted; unpinned prefix-cache pages demote
  down the tiers in last-use order.  When every page is pinned the
  arena soft-overflows (streams are never corrupted to satisfy a
  capacity model).
- Migration is page-granular and priced through the same
  ``link_bandwidth`` model as the monolith: a decode dispatch gathers
  only the pages *not* already on its PU, so partial moves, the
  prefill→first-decode hop and busy-PU ETA migration terms all become
  first-class (PU↔PU hops are ``kv_migrations``/``kv_bytes_moved``,
  spill-tier hops are fetches, priced by the fitted tier lines).
- On top of the table sits a content-hash prefix cache: prefill nodes
  whose token-prefix (retrieved-chunk ids + system/query segments,
  chain-hashed per page boundary) matches resident pages skip that
  prefix's prefill workload (``apply_prefix_hits``), and the resident
  pages are re-referenced for the new stream at prefill completion
  (``on_prefill_done``).

On top of paging sits a **predictive prefetch** layer (PerCache's
hierarchical staging, RAGDoll's fetch/compute overlap): the scheduler's
lookahead hook (``HeroScheduler._prefetch_pass``) calls :meth:`prefetch`
when it commits a round, pre-staging spill-resident pages up the tiers
*during* the committed compute window instead of fetching them on the
dispatch critical path.  Each prefetch carries the overlap credit it was
issued with, so the simulator charges only the residual
(``max(0, fetch_s - credit)`` — the ``min(issue + fetch_s,
prev_round_end)`` completion model).  With prefetch enabled, eviction is
hit-frequency-weighted instead of plain LRU: cold private pages demote
before shared prefix pages that keep earning hits.

Both backends drain the same event/transfer/prefetch queues
(``kv_page_hit`` / ``kv_evict`` / ``kv_prefetch`` / ``kv_soft_overflow``
events; spill transfers priced by the simulator through
``GroundTruthPerf.tier_transfer_cost``), so accounting is
backend-independent.  The subsystem is gated by
``SchedulerConfig.kv_pages`` — off, the scheduler keeps the monolithic
tracker (or none), bit-identical to the PR 2/3/5 goldens — and the
prefetch layer by ``SchedulerConfig.kv_prefetch`` (off = bit-identical
to the PR 6 paging behaviour).
"""
from __future__ import annotations

import hashlib
import warnings
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Set, Tuple

from repro.core.dag import Node
from repro.core.events import (EV_KV_EVICT, EV_KV_HIT_DECLINED,
                               EV_KV_PAGE_HIT, EV_KV_PREFETCH,
                               EV_KV_SOFT_OVERFLOW)
from repro.core.kv_residency import _kv_members, stream_key
from repro.core.perf_model import LinearPerfModel

DRAM, DISK = "dram", "disk"
# stream-key suffix of a speculative draft-model cache mirror: a second,
# smaller footprint per decode stream (see spec_draft_sync / release)
DRAFT_KEY = "#draft"


def decode_stage_of(stage: str) -> str:
    """The decode stage whose profiled KV shape denominates pages produced
    by ``stage`` (``chat_prefill`` fills ``chat_decode``'s cache — the
    builtin specs all follow the ``*_prefill``/``*_decode`` convention)."""
    if stage.endswith("_prefill"):
        return stage[: -len("_prefill")] + "_decode"
    return stage


def decode_stage_for(n: Node) -> str:
    """Resolve the decode stage denominating ``n``'s KV pages: the typed
    ``DecodeSpec`` stamped by ``spec.build_dag`` (``payload["decode_spec"]
    .kv_stage``) wins, then the legacy raw ``payload["kv_decode_stage"]``
    stamp (hand-built nodes), then the ``*_prefill``/``*_decode`` naming
    convention.  Custom specs whose stage names do not follow the
    convention MUST override — paging a prefill under a guessed decode
    shape mischarges every byte it touches (the trap the override
    closes)."""
    spec = n.payload.get("decode_spec")
    kvs = getattr(spec, "kv_stage", None)
    if kvs:
        return str(kvs)
    override = n.payload.get("kv_decode_stage")
    if override:
        return str(override)
    return decode_stage_of(n.stage)


def chain_hash(prev: Optional[str], content: str) -> str:
    """Hash of one page given the chain hash of the pages before it — two
    prefixes share page ``i`` iff they agree on ALL content up to and
    including page ``i``, which is exactly KV-cache validity."""
    h = hashlib.sha1()
    h.update((prev or "").encode())
    h.update(b"\x00")
    h.update(content.encode())
    return h.hexdigest()


def page_keys(segments: Sequence[Tuple[str, int]], page_tokens: int
              ) -> List[Tuple[str, int]]:
    """Split a token-prefix described by ``segments`` (``(content_key,
    tokens)`` in prompt order) at page boundaries: ``[(chain_hash,
    tokens_in_page), ...]``.  A page spanning a segment boundary hashes
    both keys, so e.g. the page mixing shared context with the per-query
    question is (correctly) only reusable by the identical query."""
    pages: List[Tuple[str, int]] = []
    prev: Optional[str] = None
    fill: List[str] = []
    used = 0
    for key, tok in segments:
        tok = int(tok)
        off = 0
        while off < tok:
            take = min(page_tokens - used, tok - off)
            fill.append(f"{key}[{off}:{off + take}]")
            used += take
            off += take
            if used == page_tokens:
                prev = chain_hash(prev, "|".join(fill))
                pages.append((prev, page_tokens))
                fill, used = [], 0
    if used:
        prev = chain_hash(prev, "|".join(fill))
        pages.append((prev, used))
    return pages


@dataclass
class KVPage:
    """One fixed-size page of some stream's KV cache."""

    pid: int
    stage: str                 # decode-stage key (profiled bytes/token)
    tokens: int
    tier: str                  # PU name, "dram", or "disk"
    hash: Optional[str] = None  # content id (prefix-cacheable); None=private
    refs: int = 0              # live streams holding this page (pin)
    last_use: int = 0          # LRU clock
    hits: int = 0              # prefix-cache reuses (frequency weight)
    # speculative draft-model cache page: never pinned (refs stays 0)
    # and evicted before ANY non-draft page in the same arena — draft
    # cache must not push verify pages out
    draft: bool = False


@dataclass
class PagedStream:
    """Page-table record of one decode stream's KV cache."""

    stage: str
    pu: Optional[str]          # anchor PU (None until first serve)
    ctx_tokens: int            # context resident so far (prefill + decoded)
    pages: List[int] = field(default_factory=list)
    # tokens counted in ctx_tokens but not yet backed by pages: a stream
    # seen before any serve has nowhere to live yet — they materialize as
    # private pages on the adopted PU at first dispatch, free of charge
    # (the monolith's first-serve semantics)
    pending: int = 0
    charged: Set[str] = field(default_factory=set)


class PagedKVCache:
    """Page-table KV tracker — a drop-in for :class:`KVResidency` (same
    scheduler/DAG/backend protocol) plus the paged-only hooks
    (``apply_prefix_hits`` / ``on_prefill_done`` / drain queues).
    ``paged`` marks the extended protocol for backends."""

    paged = True

    def __init__(self, perf: LinearPerfModel, page_tokens: int = 64,
                 prefetch: bool = False):
        self.perf = perf
        self.page_tokens = max(int(page_tokens), 1)
        # predictive prefetch + hit-frequency-weighted eviction; off = the
        # PR 6 paging behaviour, bit-identical (plain LRU, no staging)
        self.prefetch_on = bool(prefetch)
        self._streams: Dict[str, PagedStream] = {}
        self._pages: Dict[int, KVPage] = {}
        self._tier_pages: Dict[str, Set[int]] = {}
        self._tier_used: Dict[str, float] = {}
        self._index: Dict[str, int] = {}        # content hash -> pid
        self._next_pid = 0
        self._clock = 0
        # pages staged ahead of a dispatch and not yet consumed: a gather
        # finding one resident on a PU arena is a prefetch hit
        self._prefetched: Set[int] = set()
        # (prefill stage, decode stage) pairs already warned about an
        # unprofiled KV shape — warn once, then silently fall back
        self._warned_stages: Set[Tuple[str, str]] = set()
        # run totals (BackendRun accounting)
        self.migrations = 0
        self.bytes_moved = 0.0
        self.hits = 0
        self.hit_tokens = 0
        self.hit_declined = 0
        self.evictions = 0
        self.evicted_bytes = 0.0
        self.fetches = 0
        self.fetched_bytes = 0.0
        self.prefetches = 0
        self.prefetch_bytes = 0.0
        self.prefetch_hits = 0
        self.soft_overflows = 0
        # drainable queues, consumed by whichever backend dispatches next:
        # (event_name, node) pairs, (stage, src_tier, dst_tier, tokens)
        # spill transfers (the simulator charges them ground-truth seconds;
        # the live runtime records them), and (stage, src, dst, tokens,
        # credit_s) prefetches — the credit is the compute-overlap window
        # the scheduler issued the staging under, so the simulator charges
        # only the residual beyond it
        self._events: List[Tuple[str, Node]] = []
        self._transfers: List[Tuple[str, str, str, int]] = []
        self._prefetch_q: List[Tuple[str, str, str, int, float]] = []

    # -- page primitives -----------------------------------------------------
    def _touch(self, pg: KVPage) -> None:
        self._clock += 1
        pg.last_use = self._clock

    def _page_bytes(self, pg: KVPage) -> float:
        return pg.tokens * self.perf.kv_bytes.get(pg.stage, 0.0)

    def _place(self, pg: KVPage, tier: str) -> None:
        by = self._page_bytes(pg)
        old = pg.tier
        self._tier_pages.setdefault(old, set()).discard(pg.pid)
        self._tier_used[old] = self._tier_used.get(old, 0.0) - by
        pg.tier = tier
        self._tier_pages.setdefault(tier, set()).add(pg.pid)
        self._tier_used[tier] = self._tier_used.get(tier, 0.0) + by

    def _alloc(self, stage: str, tokens: int, tier: str,
               content: Optional[str], node: Node) -> KVPage:
        by = tokens * self.perf.kv_bytes.get(stage, 0.0)
        self._make_room(tier, by, node)
        pg = KVPage(pid=self._next_pid, stage=stage, tokens=int(tokens),
                    tier=tier, hash=content)
        self._next_pid += 1
        self._pages[pg.pid] = pg
        self._tier_pages.setdefault(tier, set()).add(pg.pid)
        self._tier_used[tier] = self._tier_used.get(tier, 0.0) + by
        if content is not None:
            self._index[content] = pg.pid
        self._touch(pg)
        return pg

    def _free(self, pg: KVPage) -> None:
        self._tier_pages.setdefault(pg.tier, set()).discard(pg.pid)
        self._tier_used[pg.tier] = (self._tier_used.get(pg.tier, 0.0)
                                    - self._page_bytes(pg))
        if pg.hash is not None and self._index.get(pg.hash) == pg.pid:
            del self._index[pg.hash]
        self._prefetched.discard(pg.pid)
        del self._pages[pg.pid]

    def _grow_page(self, pg: KVPage, tokens: int) -> None:
        by = tokens * self.perf.kv_bytes.get(pg.stage, 0.0)
        pg.tokens += int(tokens)
        self._tier_used[pg.tier] = self._tier_used.get(pg.tier, 0.0) + by
        self._touch(pg)

    def _capacity(self, tier: str) -> float:
        return self.perf.kv_capacity(tier)

    def _spill_target(self, tier: str) -> Optional[str]:
        if tier == DISK:
            return None
        return DISK if tier == DRAM else DRAM

    def _make_room(self, tier: str, need: float, node: Node) -> None:
        """Demote unpinned pages out of ``tier`` until ``need`` bytes fit
        (plain LRU; hit-frequency-weighted under ``prefetch`` — cold
        private pages go before shared prefix pages that keep earning
        hits).  Pinned pages (``refs > 0``) are never moved — when only
        pinned pages remain the arena soft-overflows instead (live
        streams beat the capacity model), and the breach is counted and
        emitted as a ``kv_soft_overflow`` event rather than passing
        silently; ``release`` demotes the excess once the pins drop."""
        cap = self._capacity(tier)
        if cap == float("inf"):
            return
        dst = self._spill_target(tier)
        while self._tier_used.get(tier, 0.0) + need > cap:
            victims = [self._pages[pid]
                       for pid in self._tier_pages.get(tier, ())
                       if self._pages[pid].refs <= 0]
            if not victims:
                self.soft_overflows += 1      # all pinned: soft overflow
                self._events.append((EV_KV_SOFT_OVERFLOW, node))
                return
            # draft pages always go first (the key's leading bool): with
            # no draft pages present the ordering is exactly the
            # pre-spec LRU, bit-identical with the mode off
            if self.prefetch_on:
                pg = min(victims, key=lambda p: (not p.draft, p.hits,
                                                 p.last_use, p.pid))
            else:
                pg = min(victims, key=lambda p: (not p.draft,
                                                 p.last_use, p.pid))
            if dst is None:
                self._free(pg)                # nowhere lower: drop
            else:
                self._make_room(dst, self._page_bytes(pg), node)
                self._transfers.append((pg.stage, tier, dst, pg.tokens))
                self._place(pg, dst)
            self.evictions += 1
            self.evicted_bytes += self._page_bytes(pg)
            self._events.append((EV_KV_EVICT, node))

    # -- stream bookkeeping --------------------------------------------------
    def _ensure(self, m: Node) -> PagedStream:
        key = stream_key(m)
        st = self._streams.get(key)
        if st is None:
            st = self._streams[key] = PagedStream(
                stage=decode_stage_for(m), pu=None, ctx_tokens=0)
        # reconcile against the node's own accounting: context the stream
        # should hold (prefill ctx + decoded so far) beyond what pages /
        # pending already cover becomes pending growth — this covers
        # un-stamped prefills and fine-grained chains whose decode kv_ctx
        # exceeds the sum of linked prefill pieces
        want = (int(m.payload.get("kv_ctx", 0))
                + int(m.payload.get("decode_served", 0)))
        if want > st.ctx_tokens:
            st.pending += want - st.ctx_tokens
            st.ctx_tokens = want
        return st

    def _materialize(self, st: PagedStream, node: Node) -> None:
        """Back ``st.pending`` tokens with private pages on the anchor PU
        (free: this is cache the stream produced in place)."""
        if st.pu is None or st.pending <= 0:
            return
        self._grow_tail(st, st.pending, st.pu, node)
        st.pending = 0

    def _grow_tail(self, st: PagedStream, tokens: int, tier: str,
                   node: Node) -> None:
        """Append ``tokens`` to the stream: fill the private tail page,
        then allocate fresh private pages on ``tier``."""
        left = int(tokens)
        if st.pages:
            tail = self._pages[st.pages[-1]]
            if (tail.hash is None and tail.tier == tier
                    and tail.tokens < self.page_tokens):
                take = min(self.page_tokens - tail.tokens, left)
                self._make_room(tier, take * self.perf.kv_bytes.get(
                    tail.stage, 0.0), node)
                self._grow_page(tail, take)
                left -= take
        while left > 0:
            take = min(self.page_tokens, left)
            pg = self._alloc(st.stage, take, tier, None, node)
            pg.refs = 1
            st.pages.append(pg.pid)
            left -= take

    # -- KVResidency protocol ------------------------------------------------
    def footprint_bytes(self, m: Node) -> float:
        """Resident KV bytes of stream ``m`` (ctx × profiled bytes/token —
        the same unit the monolith reports)."""
        st = self._ensure(m)
        return st.ctx_tokens * self.perf.kv_bytes.get(st.stage, 0.0)

    def resident_bytes(self, tier: Optional[str] = None) -> float:
        """Total page bytes, optionally restricted to one tier (PU name,
        "dram" or "disk"); stream-pending (not yet materialized) bytes
        count toward the no-tier total."""
        if tier is not None:
            return max(self._tier_used.get(tier, 0.0), 0.0)
        total = sum(self._page_bytes(pg) for pg in self._pages.values())
        total += sum(st.pending * self.perf.kv_bytes.get(st.stage, 0.0)
                     for st in self._streams.values())
        return total

    def tracked(self, m: Node) -> Optional[PagedStream]:
        return self._streams.get(stream_key(m))

    def resident_pu(self, m: Node) -> Optional[str]:
        """The PU holding most of ``m``'s stream's page bytes — the
        anchor preempted-member re-placement prefers.  Spill tiers
        ("dram"/"disk") are not placement anchors and are excluded;
        with no PU-resident pages the stream's nominal PU stands in.
        Deterministic tie-break by PU name, as in ``prefer_pu``."""
        st = self._streams.get(stream_key(m))
        if st is None:
            return None
        totals: Dict[str, float] = {}
        for pid in st.pages:
            pg = self._pages[pid]
            if pg.tier not in (DRAM, DISK):
                totals[pg.tier] = (totals.get(pg.tier, 0.0)
                                   + self._page_bytes(pg))
        if totals:
            return max(sorted(totals), key=lambda p: totals[p])
        return st.pu

    def prefer_pu(self, members: Sequence[Node]) -> Optional[str]:
        """Same anchor-resolution contract as the monolith: the PU holding
        the largest resident footprint, deterministic tie-breaks."""
        totals: Dict[str, float] = {}
        for m in members:
            st = self._streams.get(stream_key(m))
            pu = (st.pu if st is not None and st.pu is not None
                  else m.payload.get("batch_pu"))
            if pu is None:
                continue
            totals[pu] = totals.get(pu, 0.0) + self.footprint_bytes(m)
        if not totals:
            return None
        return max(sorted(totals), key=lambda p: totals[p])

    def _move_groups(self, st: PagedStream, m: Node, dst_pu: str
                     ) -> Dict[str, int]:
        """Tokens of ``st``'s pages NOT resident on ``dst_pu``, grouped by
        the tier they currently live on (pending tokens count at the
        anchor PU — they exist, just unmaterialized)."""
        groups: Dict[str, int] = {}
        for pid in st.pages:
            pg = self._pages[pid]
            if pg.tier != dst_pu:
                groups[pg.tier] = groups.get(pg.tier, 0) + pg.tokens
        if st.pending > 0 and st.pu is not None and st.pu != dst_pu:
            groups[st.pu] = groups.get(st.pu, 0) + st.pending
        return groups

    def migrate_penalty(self, node: Node, dst_pu: str,
                        B: float = 0.0) -> Optional[Tuple[int, float]]:
        """``(n_streams_moving, modeled_seconds)`` for serving ``node`` on
        ``dst_pu`` — page-granular: only non-resident pages pay, PU hops
        through the migration lines and spill-tier fetches through the
        fitted tier lines, φ-scaled.  ``None`` when the profile predates
        the migration grid (callers keep the legacy constant)."""
        moving, cost = 0, 0.0
        for m in _kv_members(node):
            st = self._streams.get(stream_key(m))
            if st is None:
                src = m.payload.get("batch_pu")
                if src is None or src == dst_pu:
                    continue
                ctx = self._ensure(m).ctx_tokens
                c = self.perf.migrate_cost(m.stage, src, dst_pu, ctx)
                if c is None:
                    return None
                moving += 1
                cost += c
                continue
            groups = self._move_groups(st, m, dst_pu)
            if not groups:
                continue
            any_move = False
            for tier, toks in sorted(groups.items()):
                if tier in (DRAM, DISK):
                    c = self.perf.fetch_cost(st.stage, tier, dst_pu, toks)
                else:
                    c = self.perf.migrate_cost(st.stage, tier, dst_pu, toks)
                if c is None:
                    return None
                cost += c
                any_move = True
            moving += 1 if any_move else 0
        if moving:
            cost *= self.perf.phi(node.stage, B)
        return moving, cost

    # -- backend hooks -------------------------------------------------------
    def migrate_for_dispatch(self, node: Node, pu: str
                             ) -> List[Tuple[Node, str, int, float]]:
        """Register decode work starting on ``pu`` and gather every member
        page onto it.  Returns ``(member, src_tier, tokens, bytes)`` per
        source tier actually moved — PU sources are migrations (counted
        in ``kv_migrations``/``kv_bytes_moved``, like the monolith),
        "dram"/"disk" sources are fetches.  Streams never served adopt
        ``pu`` free (legacy first-serve semantics); solo dispatches grow
        their stream by the served group, idempotently per piece."""
        moved: List[Tuple[Node, str, int, float]] = []
        is_round = bool(node.payload.get("decode_round"))
        for m in _kv_members(node):
            st = self._ensure(m)
            first_serve = st.pu is None
            if first_serve:
                st.pu = m.payload.get("batch_pu") or pu
            self._materialize(st, m)
            # gather non-resident pages page-granularly
            gather: Dict[str, Tuple[int, List[int]]] = {}
            for pid in st.pages:
                pg = self._pages[pid]
                if pid in self._prefetched:
                    # staged ahead of this dispatch: resident here = a
                    # prefetch hit; elsewhere = thrash, and the page
                    # falls through to the on-path gather below
                    self._prefetched.discard(pid)
                    if pg.tier == pu:
                        self.prefetch_hits += 1
                        m.payload["kv_prefetch_hits"] = (
                            m.payload.get("kv_prefetch_hits", 0) + 1)
                if pg.tier != pu:
                    toks, pids = gather.get(pg.tier, (0, []))
                    gather[pg.tier] = (toks + pg.tokens, pids + [pid])
            stream_moved = False
            for tier in sorted(gather):
                toks, pids = gather[tier]
                by = toks * self.perf.kv_bytes.get(st.stage, 0.0)
                self._make_room(pu, by, m)
                for pid in pids:
                    self._place(self._pages[pid], pu)
                    self._touch(self._pages[pid])
                moved.append((m, tier, toks, by))
                if tier in (DRAM, DISK):
                    # tier fetches are attributed like migrations: on the
                    # tracker for run totals AND on the member payload for
                    # per-query results — the orphaned-counter violation
                    # repro.analysis.lint rule CNT001 exists to catch
                    self.fetches += 1
                    self.fetched_bytes += by
                    m.payload["kv_fetches"] = (
                        m.payload.get("kv_fetches", 0) + 1)
                    m.payload["kv_fetched_bytes"] = (
                        m.payload.get("kv_fetched_bytes", 0.0) + by)
                else:
                    stream_moved = True
                    self.bytes_moved += by
                    m.payload["kv_bytes_moved"] = (
                        m.payload.get("kv_bytes_moved", 0.0) + by)
            if stream_moved:
                self.migrations += 1
                m.payload["kv_migrations"] = (
                    m.payload.get("kv_migrations", 0) + 1)
            st.pu = pu
            if not is_round and m.id not in st.charged:
                st.charged.add(m.id)
                served = max(int(m.workload), 0)
                st.ctx_tokens += served
                self._grow_tail(st, served, pu, m)
        return moved

    def on_boundary(self, m: Node, pu: str, served: int,
                    left: bool = False) -> None:
        """One decode-round boundary: the member's cache grew by ``served``
        tokens on ``pu``; a leaver frees its footprint."""
        if left:
            self.release(m)
            return
        st = self._ensure(m)
        st.pu = pu
        self._materialize(st, m)
        served = max(int(served), 0)
        st.ctx_tokens += served
        self._grow_tail(st, served, pu, m)

    def release(self, m: Node) -> None:
        """Terminal release of ``m``'s stream: private pages free, hashed
        (prefix-cache) pages stay resident at ``refs == 0`` — evictable,
        reusable by the next query with the same prefix.  Tiers that an
        earlier all-pinned soft overflow left above capacity demote
        their (now unpinned) excess here — the conservation guarantee
        that every tier returns under capacity once streams release.
        The stream's speculative draft mirror (``<stream>#draft``), when
        one exists, releases with it — its private draft pages free
        outright."""
        for key in (stream_key(m), stream_key(m) + DRAFT_KEY):
            st = self._streams.pop(key, None)
            if st is None:
                continue
            touched: Set[str] = set()
            for pid in st.pages:
                pg = self._pages.get(pid)
                if pg is None:
                    continue
                pg.refs = max(pg.refs - 1, 0)
                if pg.refs == 0 and pg.hash is None:
                    self._free(pg)
                elif pg.refs == 0:
                    touched.add(pg.tier)
            for tier in sorted(touched):
                if (self._tier_used.get(tier, 0.0) > self._capacity(tier)
                        and any(self._pages[pid].refs <= 0
                                for pid in self._tier_pages.get(tier, ()))):
                    self._make_room(tier, 0.0, m)

    # -- runtime invariants (REPRO_CHECK=1) ----------------------------------
    def check_quiescent(self) -> None:
        """Assert the paged store's end-of-run conservation guarantees.
        Unlike the monolithic tracker, resident bytes do NOT return to
        zero — hashed prefix pages stay resident at ``refs == 0`` by
        design, reusable by the next query — so quiescence here means:
        no stream is still tracked, no page is still pinned, every
        tier's byte accounting matches its page table, and no tier is
        left over capacity (the soft-overflow demote-on-release
        guarantee).  Called by both backends at end of run when
        ``REPRO_CHECK=1`` (see ``core/checks.py``)."""
        from repro.core.checks import invariant
        invariant(not self._streams,
                  "PagedKVCache quiescence: streams still tracked at end "
                  f"of run: {sorted(self._streams)[:6]}")
        pinned = [pg.pid for pg in self._pages.values() if pg.refs > 0]
        invariant(not pinned,
                  "PagedKVCache quiescence: pages still pinned at end of "
                  f"run: {pinned[:8]}")
        tiers = set(self._tier_pages) | set(self._tier_used)
        for tier in sorted(tiers):
            want = sum(self._page_bytes(self._pages[pid])
                       for pid in self._tier_pages.get(tier, ()))
            got = self._tier_used.get(tier, 0.0)
            invariant(abs(got - want) <= 1e-6 * max(want, 1.0),
                      f"PagedKVCache tier {tier!r}: _tier_used={got} "
                      f"disagrees with page table total {want}")
            invariant(got <= self._capacity(tier)
                      + 1e-6 * max(self._capacity(tier), 1.0)
                      or not self._tier_pages.get(tier),
                      f"PagedKVCache tier {tier!r}: {got} bytes resident "
                      f"above capacity {self._capacity(tier)} after all "
                      "streams released")

    def spec_draft_sync(self, m: Node, draft_stage: Optional[str],
                        pu: str) -> None:
        """Speculative-decoding boundary hook: mirror member ``m``'s
        draft-model cache — a second, smaller per-stream footprint keyed
        ``<stream>#draft`` whose pages are flagged ``draft`` and never
        pinned (``refs`` stays 0), making them the first eviction
        victims in any arena: draft cache can never push a verify page
        out.  The mirror grows to the verify stream's served context or
        trims the rejected speculative tail back down to it — never
        below, so rollback cannot move a served boundary backwards."""
        if not draft_stage or draft_stage not in self.perf.kv_bytes:
            return
        vst = self._streams.get(stream_key(m))
        target = vst.ctx_tokens if vst is not None else 0
        key = stream_key(m) + DRAFT_KEY
        st = self._streams.get(key)
        if st is None:
            if target <= 0:
                return
            st = self._streams[key] = PagedStream(stage=draft_stage,
                                                  pu=pu, ctx_tokens=0)
        st.pu = pu
        if target > st.ctx_tokens:
            left = target - st.ctx_tokens
            if st.pages:
                tail = self._pages.get(st.pages[-1])
                if (tail is not None and tail.tier == pu
                        and tail.tokens < self.page_tokens):
                    take = min(self.page_tokens - tail.tokens, left)
                    self._make_room(pu, take * self.perf.kv_bytes.get(
                        tail.stage, 0.0), m)
                    self._grow_page(tail, take)
                    left -= take
            while left > 0:
                take = min(self.page_tokens, left)
                pg = self._alloc(st.stage, take, pu, None, m)
                pg.draft = True
                st.pages.append(pg.pid)
                left -= take
        elif target < st.ctx_tokens:
            need = st.ctx_tokens - target
            while need > 0 and st.pages:
                pg = self._pages.get(st.pages[-1])
                if pg is None:
                    st.pages.pop()
                    continue
                if pg.tokens <= need:
                    st.pages.pop()
                    need -= pg.tokens
                    self._free(pg)
                else:
                    by = need * self.perf.kv_bytes.get(pg.stage, 0.0)
                    pg.tokens -= need
                    self._tier_used[pg.tier] = (
                        self._tier_used.get(pg.tier, 0.0) - by)
                    need = 0
        st.ctx_tokens = target

    # -- prefix cache --------------------------------------------------------
    def apply_prefix_hits(self, n: Node) -> None:
        """Scheduler first-seen hook for a ``stream_prefill`` node: trim
        the node's workload by the longest resident page-aligned prefix
        *worth taking* — the hit-or-recompute rule: a resident run only
        trims workload up to the length where the modeled spill-fetch
        cost still undercuts the prefill compute it skips (a
        disk-resident "hit" can lose; the losing tail is declined and
        counted in ``kv_hit_declined``).  Hits keep ≥ 1 token so the
        node still anchors its successors, and hit pages are referenced
        immediately (pinned) so they cannot evict before
        ``on_prefill_done`` adopts them for the stream."""
        segs = n.payload.get("prefix_segments")
        if not segs or n.payload.get("kv_prefix_done"):
            return
        n.payload["kv_prefix_done"] = True
        stage = decode_stage_for(n)
        if stage not in self.perf.kv_bytes:
            key = (n.stage, stage)
            if key not in self._warned_stages:
                self._warned_stages.add(key)
                warnings.warn(
                    f"stage {n.stage!r} resolves to decode stage "
                    f"{stage!r}, which has no profiled KV shape — set "
                    "StageSpec.kv_stage to page its cache under the "
                    "right profile (prefix reuse disabled for it)",
                    RuntimeWarning, stacklevel=2)
            return
        hits: List[int] = []
        for h, _tok in page_keys(segs, self.page_tokens):
            pid = self._index.get(h)
            if pid is None:
                break
            hits.append(pid)
        if not hits:
            return
        keep, toks = self._hit_or_recompute(n, stage, hits)
        if keep < len(hits):
            declined = len(hits) - keep
            self.hit_declined += declined
            n.payload["kv_hit_declined"] = (
                n.payload.get("kv_hit_declined", 0) + declined)
            self._events.append((EV_KV_HIT_DECLINED, n))
            hits = hits[:keep]
        if not hits:
            return
        trim = min(toks, max(int(n.workload) - 1, 0))
        if trim <= 0:
            return
        n.workload = int(n.workload) - trim
        for pid in hits:
            pg = self._pages[pid]
            pg.refs += 1
            pg.hits += 1
            self._touch(pg)
        n.payload["kv_page_hits"] = len(hits)
        n.payload["kv_hit_tokens"] = trim
        n.payload["kv_hit_pages"] = tuple(hits)
        self.hits += len(hits)
        self.hit_tokens += trim
        self._events.append((EV_KV_PAGE_HIT, n))

    def _min_fetch(self, stage: str, src: str, tokens: int
                   ) -> Optional[float]:
        """Cheapest fitted fetch line out of spill tier ``src`` for
        ``tokens`` of ``stage``'s pages (``None`` when no line fits —
        callers fall back to the legacy always-hit behaviour)."""
        best: Optional[float] = None
        for (s, a, b) in sorted(self.perf.fetch_coef):
            if s != stage or a != src:
                continue
            c = self.perf.fetch_cost(stage, src, b, tokens)
            if c is not None and (best is None or c < best):
                best = c
        return best

    def _hit_or_recompute(self, n: Node, stage: str,
                          hits: Sequence[int]) -> Tuple[int, int]:
        """Hit-or-recompute: the longest resident prefix is only worth
        taking up to the page count maximizing (modeled prefill compute
        skipped) − (modeled spill-fetch cost paid).  PU-resident pages
        are free to hit; a run reaching into disk can cost more to
        fetch than to re-prefill.  Returns ``(pages_kept,
        tokens_kept)``; any unprofiled piece (no prefill grid for the
        stage, no fetch line for a spill tier) keeps the legacy
        always-hit behaviour so handcrafted profiles stay exact."""
        total_tok = sum(self._pages[pid].tokens for pid in hits)
        cum_tok = 0
        spill: Dict[str, int] = {}
        best_k, best_tok, best_net = 0, 0, 0.0
        for k, pid in enumerate(hits, start=1):
            pg = self._pages[pid]
            cum_tok += pg.tokens
            if pg.tier in (DRAM, DISK):
                spill[pg.tier] = spill.get(pg.tier, 0) + pg.tokens
            saved = self.perf.prefill_cost(n.stage, cum_tok)
            if saved is None:
                return len(hits), total_tok
            fetch = 0.0
            for src in sorted(spill):
                c = self._min_fetch(stage, src, spill[src])
                if c is None:
                    return len(hits), total_tok
                fetch += c
            net = saved - fetch
            if net > best_net:
                best_k, best_tok, best_net = k, cum_tok, net
        return best_k, best_tok

    def on_prefill_done(self, n: Node, pu: Optional[str]) -> None:
        """DAG completion hook for a ``stream_prefill`` node: materialize
        its prefix pages on ``pu`` (reusing resident hashed pages — the
        hit — and allocating the misses), then link them to the decode
        stream stamped as ``payload["kv_stream"]``."""
        if n.payload.get("kv_paged_done"):
            return
        n.payload["kv_paged_done"] = True
        segs = n.payload.get("prefix_segments")
        stage = decode_stage_for(n)
        if not segs or stage not in self.perf.kv_bytes or pu is None:
            return
        pages: List[int] = []
        total = 0
        for h, tok in page_keys(segs, self.page_tokens):
            pid = self._index.get(h)
            if pid is not None:
                pg = self._pages[pid]
                pg.refs += 1
                pg.hits += 1
                self._touch(pg)
            else:
                pg = self._alloc(stage, tok, pu, h, n)
                pg.refs = 1
            pages.append(pg.pid)
            total += tok
        # drop the apply_prefix_hits holds (stream refs now pin the hits)
        for pid in n.payload.pop("kv_hit_pages", ()):
            pg = self._pages.get(pid)
            if pg is not None:
                pg.refs = max(pg.refs - 1, 0)
        skey = n.payload.get("kv_stream")
        if skey is None:
            for pid in pages:                # no linked stream: cache only
                self._pages[pid].refs = max(self._pages[pid].refs - 1, 0)
            return
        st = self._streams.get(skey)
        if st is None:
            st = self._streams[skey] = PagedStream(stage=stage, pu=pu,
                                                   ctx_tokens=0)
        st.pages.extend(pages)
        st.ctx_tokens += total
        if st.pu is None:
            st.pu = pu

    # -- predictive prefetch ---------------------------------------------------
    def _headroom(self, tier: str) -> float:
        """Bytes ``tier`` can absorb without touching a pinned page or a
        page staged this pass: free capacity plus evictable (unpinned,
        un-prefetched) page bytes.  Speculative staging must fit inside
        this — prefetch never forces a soft overflow and never thrashes
        its own stagings."""
        cap = self._capacity(tier)
        if cap == float("inf"):
            return float("inf")
        free = cap - self._tier_used.get(tier, 0.0)
        evictable = sum(self._page_bytes(self._pages[pid])
                        for pid in self._tier_pages.get(tier, ())
                        if self._pages[pid].refs <= 0
                        and pid not in self._prefetched)
        return free + evictable

    def prefetch(self, node: Node, dst_pu: str, budget_s: float,
                 pids: Optional[Sequence[int]] = None) -> float:
        """Pre-stage ``node``'s spill-resident (dram/disk) pages onto
        ``dst_pu`` under a compute-overlap window of ``budget_s``
        modeled seconds; returns the modeled transfer seconds consumed
        (the scheduler debits its window — the transfer queue is
        serial, so groups split one budget sequentially).  ``pids``
        restricts the page set (e.g. a prefill's ``kv_hit_pages``);
        default is the node's tracked stream.  PU-resident pages never
        move (that is the dispatch gather's migration to price), and a
        group is clipped — not forced — to the destination's evictable
        headroom (staging what fits, leaving the tail for the on-path
        gather) and skipped when it has no fitted fetch line.
        Each staged group queues ``(stage, src, dst, tokens, credit)``
        for the backends: the simulator charges only the ground-truth
        residual beyond the credit; the live runtime records it."""
        if not self.prefetch_on or budget_s <= 0.0:
            return 0.0
        if pids is None:
            st = self.tracked(node)
            pids = tuple(st.pages) if st is not None else ()
        groups: Dict[Tuple[str, str], Tuple[int, List[int]]] = {}
        for pid in pids:
            pg = self._pages.get(pid)
            if (pg is None or pg.tier not in (DRAM, DISK)
                    or pid in self._prefetched):
                continue
            toks, lst = groups.get((pg.tier, pg.stage), (0, []))
            groups[(pg.tier, pg.stage)] = (toks + pg.tokens, lst + [pid])
        spent = 0.0
        for (tier, stage) in sorted(groups):
            if budget_s - spent <= 0.0:
                break
            _toks, lst = groups[(tier, stage)]
            head = self._headroom(dst_pu)
            take: List[int] = []
            take_toks, by = 0, 0.0
            for pid in lst:
                pby = self._page_bytes(self._pages[pid])
                if by + pby > head:
                    break
                take.append(pid)
                take_toks += self._pages[pid].tokens
                by += pby
            if not take:
                continue
            cost = self.perf.fetch_cost(stage, tier, dst_pu, take_toks)
            if cost is None:
                continue
            credit = min(cost, budget_s - spent)
            self._make_room(dst_pu, by, node)
            for pid in take:
                self._place(self._pages[pid], dst_pu)
                self._touch(self._pages[pid])
                self._prefetched.add(pid)
            self.prefetches += 1
            self.prefetch_bytes += by
            node.payload["kv_prefetches"] = (
                node.payload.get("kv_prefetches", 0) + 1)
            node.payload["kv_prefetch_bytes"] = (
                node.payload.get("kv_prefetch_bytes", 0.0) + by)
            self._events.append((EV_KV_PREFETCH, node))
            self._prefetch_q.append(
                (stage, tier, dst_pu, take_toks, credit))
            spent += credit
        return spent

    # -- drain queues (backend accounting) -----------------------------------
    def drain_events(self) -> List[Tuple[str, Node]]:
        ev, self._events = self._events, []
        return ev

    def drain_transfers(self) -> List[Tuple[str, str, str, int]]:
        t, self._transfers = self._transfers, []
        return t

    def drain_prefetches(self) -> List[Tuple[str, str, str, int, float]]:
        q, self._prefetch_q = self._prefetch_q, []
        return q
