"""Opt-in runtime invariant checks, gated by ``REPRO_CHECK=1``.

The scheduler core carries invariants that hold by construction but
that nothing re-verifies at runtime — most importantly KV-residency
quiescence: once a run drains, every tracked stream has been released
and (for the monolithic tracker) total resident bytes are back to zero;
for the paged store, no page is still pinned and every tier's
accounting is self-consistent.

Checks cost time on hot paths, so they are off by default and enabled
by the ``REPRO_CHECK=1`` environment variable — tests and the CI
bench-smoke legs run with it set, production benchmarking does not.
A failed check raises :class:`InvariantError` (never a silent log), so
CI turns an accounting leak into a red job instead of a drifting
counter.
"""
from __future__ import annotations

import os


class InvariantError(AssertionError):
    """A ``REPRO_CHECK``-guarded runtime invariant was violated."""


def enabled() -> bool:
    """True when ``REPRO_CHECK`` is set to a truthy value."""
    return os.environ.get("REPRO_CHECK", "") not in ("", "0", "false",
                                                     "False")


def invariant(cond: bool, message: str) -> None:
    """Raise :class:`InvariantError` unless ``cond`` (checks enabled
    only — callers guard the *computation* of ``cond`` with
    :func:`enabled` themselves when it is expensive)."""
    if not cond:
        raise InvariantError(message)
