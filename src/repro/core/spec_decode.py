"""Speculative-decoding primitives shared by the scheduler, the perf
model and both backends.

Speculation splits every decode round into a coupled (draft, verify)
pair: a small draft model streams ``w`` candidate tokens per verify
pass while the target model scores the previous group of ``w + 1``
positions in ONE weight sweep (the spec win — the sweep is what a
memory-bound decode pays per token).  With accept rate ``alpha`` a
verify pass lands ``1 + alpha*w`` tokens on average, so a token group
of ``g`` needs ``ceil(g / (1 + alpha*w))`` passes instead of ``g``
steps.

This module is a leaf (no repro imports): naming conventions for the
paired draft stages, the pass-count arithmetic, and the online
accept-rate tracker (:class:`SpecTracker`) whose totals are the
backend-independent ``drafted_tokens`` / ``accepted_tokens`` counters
surfaced on :class:`~repro.api.backends.BackendRun`.
"""
from __future__ import annotations

import math
from typing import Dict, Optional

# draft stages are named by convention off their verify stage:
# "chat_decode" -> "chat_draft" (see rag.stages.build_stages, which
# appends one draft StageModel per decode stage from the draft config)
DRAFT_SUFFIX = "_draft"
VERIFY_SUFFIX = "_decode"

# the in-tree small config the draft stages are built from (the only
# sub-1B config shipped; SessionOptions.draft_model validates against
# the registry in rag.stages.DRAFT_MODELS)
DEFAULT_DRAFT_MODEL = "qwen1p5_0p5b"


def draft_stage_of(verify_stage: str) -> Optional[str]:
    """Perf-stage name of the draft companion of ``verify_stage``
    (``None`` when the stage is not a ``*_decode`` verify target —
    including draft stages themselves, which never recurse)."""
    if not verify_stage.endswith(VERIFY_SUFFIX):
        return None
    return verify_stage[: -len(VERIFY_SUFFIX)] + DRAFT_SUFFIX


def is_draft_stage(stage: str) -> bool:
    return stage.endswith(DRAFT_SUFFIX)


def spec_passes(group: int, draft_width: int, alpha: float) -> int:
    """Expected verify passes to land a ``group``-token round when every
    pass drafts ``draft_width`` candidates at accept rate ``alpha``:
    ``ceil(g / (1 + alpha*w))``, never above ``g`` (alpha = 0 degrades
    to plain one-token-per-pass decode) and never below 1."""
    g = max(int(group), 1)
    w = max(int(draft_width), 0)
    per = 1.0 + max(min(float(alpha), 1.0), 0.0) * w
    return max(1, min(g, math.ceil(g / per)))


class SpecTracker:
    """Online accept-rate state + run totals (counter protocol).

    Per-stream accept rate is an EWMA over observed per-round accept
    fractions — streams differ (a rewriter's constrained output drafts
    better than open chat), and the scheduler prices each round with
    the stream's own ``alpha``.  Run totals follow the kv-tracker
    pattern: both backends read ``drafted_tokens`` / ``accepted_tokens``
    off the scheduler's tracker, and per-node payload stamps sum to the
    same totals (the ``preemptions`` contract)."""

    def __init__(self, init: float = 0.6, weight: float = 0.3):
        self.init = float(init)
        self.weight = float(weight)
        self._alpha: Dict[str, float] = {}
        self.drafted_tokens = 0
        self.accepted_tokens = 0
        self.rounds = 0

    def alpha(self, key: str, init: Optional[float] = None) -> float:
        """Current accept-rate estimate for one decode stream.  ``init``
        overrides the tracker-wide prior for streams never observed —
        the scheduler passes the profiled pair prior when the perf model
        has one."""
        return self._alpha.get(key, self.init if init is None else init)

    def observe(self, key: str, drafted: int, accepted: int) -> None:
        """Fold one round's accept counts into the stream's EWMA and the
        run totals.  ``accepted`` is clamped into [0, drafted]."""
        if drafted <= 0:
            return
        accepted = max(0, min(int(accepted), int(drafted)))
        self.drafted_tokens += int(drafted)
        self.accepted_tokens += accepted
        self.rounds += 1
        r = accepted / drafted
        prev = self._alpha.get(key, self.init)
        self._alpha[key] = (1.0 - self.weight) * prev + self.weight * r

    @property
    def accept_rate(self) -> float:
        """Run-wide observed accept fraction (0 when nothing drafted)."""
        if self.drafted_tokens <= 0:
            return 0.0
        return self.accepted_tokens / self.drafted_tokens
