"""Per-stream KV-cache residency tracking + modeled migration pricing.

HeRo's decode-round PU stickiness used to be priced by a constant
(``SchedulerConfig.decode_migrate_cost``) — and *solo* decode chains
(a stream served one token group at a time through ``_take_substage``
rest siblings) were not priced at all, hopping PUs freely between
groups.  Both mis-rank PU candidates exactly when context is long and
migration is genuinely expensive.  This module makes KV placement
first-class scheduler state, the way Agent.xpu argues it must be on
heterogeneous SoCs:

- :class:`KVResidency` tracks, per decode *stream* (keyed by
  ``node.group or node.id`` so identity survives both sub-stage
  chaining and round re-fusion), the PU holding its KV cache and the
  context length resident there: the prefill context stamped by the
  workflow spec as ``payload["kv_ctx"]``, grown by decode-round
  boundary events (``DynamicDAG._finish_decode_round`` via the
  ``dag.kv`` hook) and by solo token-group dispatches.
- Moving resident work to another PU is priced by the *modeled*
  migration cost: footprint (ctx × KV-bytes/token) ÷ the profiled
  PU-pair link bandwidth (``LinearPerfModel.migrate_cost``), with the
  shared-memory contention multiplier φ applied since the copy rides
  the same bus as everything else.
- Both backends call :meth:`migrate_for_dispatch` when decode work
  starts, so migrations are counted (and, on the simulator, charged
  ground-truth transfer seconds) identically: ``kv_migrations`` and
  ``kv_bytes_moved`` land on the node payloads for per-query results
  and on the tracker for run totals.

The subsystem is gated by ``SchedulerConfig.kv_residency`` — off, the
scheduler keeps the legacy constant and migration stays free physics,
bit-identical to the PR 2/3/4 goldens.

``core/kv_pages.py`` supersedes this monolithic footprint with a
page-table tracker (tiered store + prefix cache) behind the same
protocol; this class remains the ``kv_residency`` implementation and
the shared vocabulary (``stream_key`` / ``_kv_members``) both use.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Set, Tuple

from repro.core.dag import Node
from repro.core.perf_model import LinearPerfModel


def stream_key(n: Node) -> str:
    """Stable identity of the decode stream ``n`` belongs to: sub-stage
    chaining mints fresh node ids per rest piece but preserves ``group``;
    round members keep their node id across boundaries."""
    return n.group or n.id


def _kv_members(node: Node) -> Sequence[Node]:
    """The decode streams a dispatch of ``node`` serves: the members of a
    decode round, the node itself for a solo stream, nothing for fused
    batchable work (no KV)."""
    if node.payload.get("decode_round"):
        return node.payload.get("members", ())
    if node.kind == "stream_decode" and "members" not in node.payload:
        return (node,)
    return ()


@dataclass
class StreamKV:
    """Residency record of one decode stream's KV cache."""

    stage: str
    pu: Optional[str]          # PU holding the cache (None until first serve)
    ctx_tokens: int            # context resident so far (prefill + decoded)
    # solo dispatches whose decoded tokens were already counted into
    # ctx_tokens (idempotency across straggler re-dispatches)
    charged: Set[str] = field(default_factory=set)


class KVResidency:
    """Tracks resident KV footprints per stream / per PU and prices moves.

    One tracker per :class:`HeroScheduler`; the scheduler attaches it to
    the DAG under execution (``dag.kv``) so boundary events reach it from
    either backend.
    """

    def __init__(self, perf: LinearPerfModel):
        self.perf = perf
        self._streams: Dict[str, StreamKV] = {}
        # run totals (BackendRun.kv_migrations / kv_bytes_moved)
        self.migrations = 0
        self.bytes_moved = 0.0

    # -- footprint accounting ------------------------------------------------
    def _ensure(self, m: Node) -> StreamKV:
        key = stream_key(m)
        st = self._streams.get(key)
        if st is None:
            base = (int(m.payload.get("kv_ctx", 0))
                    + int(m.payload.get("decode_served", 0)))
            st = self._streams[key] = StreamKV(stage=m.stage, pu=None,
                                              ctx_tokens=base)
        return st

    def footprint_bytes(self, m: Node) -> float:
        """Resident KV bytes of stream ``m`` (ctx × profiled bytes/token)."""
        st = self._ensure(m)
        return st.ctx_tokens * self.perf.kv_bytes.get(st.stage, 0.0)

    def resident_bytes(self, pu: Optional[str] = None) -> float:
        """Total tracked KV bytes, optionally restricted to one PU."""
        return sum(st.ctx_tokens * self.perf.kv_bytes.get(st.stage, 0.0)
                   for st in self._streams.values()
                   if pu is None or st.pu == pu)

    def tracked(self, m: Node) -> Optional[StreamKV]:
        return self._streams.get(stream_key(m))

    def resident_pu(self, m: Node) -> Optional[str]:
        """The PU holding ``m``'s stream's KV cache right now — the
        anchor preempted-member re-placement prefers (the released
        member's state stayed put).  ``None`` when nothing is tracked."""
        st = self._streams.get(stream_key(m))
        return st.pu if st is not None else None

    # -- placement preference ------------------------------------------------
    def prefer_pu(self, members: Sequence[Node]) -> Optional[str]:
        """The PU holding the largest resident footprint among ``members``
        — the anchor a forming decode round should stick to when member
        histories conflict.  Deterministic: byte totals tie-break by PU
        name (sorted ascending, max wins), never set iteration order."""
        totals: Dict[str, float] = {}
        for m in members:
            st = self._streams.get(stream_key(m))
            pu = (st.pu if st is not None and st.pu is not None
                  else m.payload.get("batch_pu"))
            if pu is None:
                continue
            totals[pu] = totals.get(pu, 0.0) + self.footprint_bytes(m)
        if not totals:
            return None
        return max(sorted(totals), key=lambda p: totals[p])

    # -- migration pricing (Eq. 5 addend) ------------------------------------
    def migrate_penalty(self, node: Node, dst_pu: str,
                        B: float = 0.0) -> Optional[Tuple[int, float]]:
        """``(n_streams_moving, modeled_seconds)`` for serving ``node`` on
        ``dst_pu``: every stream whose cache resides elsewhere pays
        footprint ÷ link-bandwidth, φ-scaled (the copy contends for the
        same bus).  ``None`` when the profile has no migration grid — the
        caller falls back to the legacy constant."""
        moving, cost = 0, 0.0
        for m in _kv_members(node):
            st = self._streams.get(stream_key(m))
            src = (st.pu if st is not None and st.pu is not None
                   else m.payload.get("batch_pu"))
            if src is None or src == dst_pu:
                continue
            ctx = (st.ctx_tokens if st is not None
                   else self._ensure(m).ctx_tokens)
            c = self.perf.migrate_cost(m.stage, src, dst_pu, ctx)
            if c is None:
                return None
            moving += 1
            cost += c
        if moving:
            cost *= self.perf.phi(node.stage, B)
        return moving, cost

    # -- backend hooks -------------------------------------------------------
    def migrate_for_dispatch(self, node: Node, pu: str
                             ) -> List[Tuple[Node, str, int, float]]:
        """Register decode work starting on ``pu`` and return the streams
        whose caches actually move: ``(member, src_pu, ctx_tokens,
        bytes)`` per migration.  Called by BOTH backends at dispatch
        start (simulator charges ground-truth transfer seconds; the live
        runtime emits the events), so counters are backend-independent.
        First serves adopt ``pu`` free of charge — the legacy stickiness
        semantics.  Solo dispatches also grow the stream's context by the
        token group they serve (idempotent per piece, so straggler
        re-dispatches do not double-count)."""
        moved: List[Tuple[Node, str, int, float]] = []
        is_round = bool(node.payload.get("decode_round"))
        for m in _kv_members(node):
            st = self._ensure(m)
            if st.pu is None:
                st.pu = m.payload.get("batch_pu") or pu
            if st.pu != pu:
                by = st.ctx_tokens * self.perf.kv_bytes.get(st.stage, 0.0)
                moved.append((m, st.pu, st.ctx_tokens, by))
                st.pu = pu
                self.migrations += 1
                self.bytes_moved += by
                m.payload["kv_migrations"] = (
                    m.payload.get("kv_migrations", 0) + 1)
                m.payload["kv_bytes_moved"] = (
                    m.payload.get("kv_bytes_moved", 0.0) + by)
            if not is_round and m.id not in st.charged:
                # a solo dispatch decodes its (trimmed) workload here;
                # round members instead grow at the boundary fan-out
                st.charged.add(m.id)
                st.ctx_tokens += max(int(m.workload), 0)
        return moved

    def on_boundary(self, m: Node, pu: str, served: int,
                    left: bool = False) -> None:
        """One decode-round boundary for member ``m``: its cache now holds
        ``served`` more tokens on ``pu``; a member that *left* (finished)
        frees its footprint."""
        if left:
            self.release(m)
            return
        st = self._ensure(m)
        st.pu = pu
        st.ctx_tokens += max(int(served), 0)

    def release(self, m: Node) -> None:
        """Terminal release of ``m``'s stream.  ``mark_done`` calls this
        unconditionally for every finished stream — including members of
        an un-configured round and streams whose final boundary never
        fired — so no stream identity can keep its footprint registered
        until session end (total resident bytes return to zero once every
        stream has finished)."""
        self._streams.pop(stream_key(m), None)

    # -- runtime invariants (REPRO_CHECK=1) ----------------------------------
    def check_quiescent(self) -> None:
        """Assert the release guarantee above actually held: once a run
        drains, no stream is still tracked and total resident bytes are
        back to zero.  Called by both backends at end of run when
        ``REPRO_CHECK=1`` (see ``core/checks.py``)."""
        from repro.core.checks import invariant
        invariant(not self._streams,
                  "KVResidency quiescence: streams still tracked at end "
                  f"of run: {sorted(self._streams)[:6]}")
        invariant(self.resident_bytes() == 0.0,
                  "KVResidency quiescence: resident bytes nonzero at end "
                  f"of run: {self.resident_bytes()}")
