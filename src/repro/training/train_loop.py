"""Training loop: jit'd train_step builder + checkpointed driver.

``make_train_step`` builds the per-step function the dry-run lowers:
loss -> grads (with remat per the model config) -> optional int8-compressed
pod all-reduce -> AdamW update.  Gradient accumulation runs as a lax.scan
over microbatches (constant memory in accumulation steps).
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import build_model
from repro.training.optimizer import (AdamWConfig, AdamWState, adamw_init,
                                      adamw_update)


@dataclass(frozen=True)
class TrainConfig:
    optimizer: AdamWConfig = AdamWConfig()
    grad_accum: int = 1
    # quantize the data-parallel gradient all-reduce over the pod axis
    compress_pod_grads: bool = False
    pod_axis: str = "pod"


def make_train_step(cfg: ModelConfig, tcfg: TrainConfig = TrainConfig()):
    model = build_model(cfg)

    def loss_fn(params, batch):
        loss, metrics = model.loss_fn(params, batch)
        return loss, metrics

    def train_step(params, opt_state: AdamWState, batch
                   ) -> Tuple[Any, AdamWState, Dict[str, Any]]:
        if tcfg.grad_accum > 1:
            # microbatch scan: batch leading dim reshaped to
            # (accum, B/accum, ...)
            def micro(c, mb):
                (l, m), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    params, mb)
                acc_g, acc_l = c
                return (jax.tree.map(jnp.add, acc_g, g), acc_l + l), None

            mb = jax.tree.map(
                lambda x: x.reshape((tcfg.grad_accum,
                                     x.shape[0] // tcfg.grad_accum)
                                    + x.shape[1:]), batch)
            zero = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32),
                                params)
            (grads, loss), _ = jax.lax.scan(micro, (zero, 0.0), mb)
            grads = jax.tree.map(lambda g: g / tcfg.grad_accum, grads)
            loss = loss / tcfg.grad_accum
            metrics = {}
        else:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
        new_params, new_state, opt_metrics = adamw_update(
            grads, opt_state, params, tcfg.optimizer)
        metrics = {**metrics, **opt_metrics, "loss": loss}
        return new_params, new_state, metrics

    def init(rng):
        params = model.init(rng)
        return params, adamw_init(params, tcfg.optimizer)

    return init, train_step


def train(cfg: ModelConfig, data_iter, *, steps: int,
          tcfg: TrainConfig = TrainConfig(), seed: int = 0,
          checkpointer=None, checkpoint_every: int = 0,
          log_every: int = 10, restore: bool = False):
    """Single-host training driver (examples / integration tests).  The
    multi-pod path goes through launch/train.py with pjit shardings."""
    init, step_fn = make_train_step(cfg, tcfg)
    step_fn = jax.jit(step_fn)
    params, opt_state = init(jax.random.PRNGKey(seed))
    start = 0
    if restore and checkpointer is not None:
        restored = checkpointer.restore_latest((params, opt_state))
        if restored is not None:
            (params, opt_state), start = restored
    history = []
    t0 = time.time()
    for step in range(start, steps):
        batch = next(data_iter)
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        if step % log_every == 0 or step == steps - 1:
            history.append({"step": step,
                            "loss": float(metrics["loss"]),
                            "grad_norm": float(metrics["grad_norm"]),
                            "wall": time.time() - t0})
        if checkpointer is not None and checkpoint_every and \
                (step + 1) % checkpoint_every == 0:
            checkpointer.save((params, opt_state), step + 1)
    if checkpointer is not None:
        checkpointer.wait()
    return params, opt_state, history
