from repro.training.grad_compression import (  # noqa: F401
    compress_tree_psum, compressed_psum)
from repro.training.optimizer import (  # noqa: F401
    AdamWConfig, AdamWState, adamw_init, adamw_update, global_norm)
from repro.training.train_loop import (  # noqa: F401
    TrainConfig, make_train_step, train)
