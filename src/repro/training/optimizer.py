"""AdamW with ZeRO-style sharded states + gradient utilities.

Pure-pytree implementation (no optax dependency).  Optimizer moments adopt
the parameter sharding (params are already fully sharded over data+model in
this framework — see models/sharding.py), so states never replicate: the
ZeRO-1 property falls out of GSPMD.  ``state_dtype`` controls moment
precision — bf16 moments halve optimizer HBM for the 671B config
(the deepseek-v3 train_4k memory note in DESIGN.md §5).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    state_dtype: str = "float32"      # "bfloat16" halves optimizer memory
    warmup_steps: int = 100
    total_steps: int = 10_000


def adamw_init(params: Any, cfg: AdamWConfig) -> AdamWState:
    dt = jnp.dtype(cfg.state_dtype)
    zeros = lambda p: jnp.zeros_like(p, dtype=dt)  # noqa: E731
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      m=jax.tree.map(zeros, params),
                      v=jax.tree.map(zeros, params))


def _schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (0.1 + 0.9 * cos)


def global_norm(tree: Any) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(grads: Any, state: AdamWState, params: Any,
                 cfg: AdamWConfig) -> Tuple[Any, AdamWState, Dict[str, Any]]:
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    step = state.step + 1
    lr = _schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)
    dt = jnp.dtype(cfg.state_dtype)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m1 = b1 * m.astype(jnp.float32) + (1 - b1) * g
        v1 = b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(g)
        mh, vh = m1 / bc1, v1 / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * \
            p.astype(jnp.float32)
        return ((p.astype(jnp.float32) - lr * delta).astype(p.dtype),
                m1.astype(dt), v1.astype(dt))

    flat = jax.tree.map(upd, params, grads, state.m, state.v)
    new_p = jax.tree.map(lambda t: t[0], flat,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], flat,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], flat,
                         is_leaf=lambda t: isinstance(t, tuple))
    return new_p, AdamWState(step, new_m, new_v), {
        "grad_norm": gnorm, "lr": lr}
