"""INT8 gradient compression for data-parallel all-reduce.

``compressed_psum`` quantizes a tensor to int8 with a shared (max-based)
scale, all-reduces the int8 payload in int32 accumulation, and dequantizes —
an 8x reduction in DP all-reduce bytes, applied over the ``pod`` axis where
inter-pod bandwidth (DCN) is the scarce resource.  Used under shard_map in
train_step when ``compress_pod_grads`` is enabled.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp


def compressed_psum(x: jax.Array, axis_name: str) -> jax.Array:
    """Quantize -> int8 all-reduce (int32 accum) -> dequantize.

    The scale is the max |x| across the axis so every participant uses the
    same quantization grid (one extra f32 psum of a scalar)."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)))
    amax = jax.lax.pmax(amax, axis_name)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale),
                 -127, 127).astype(jnp.int8)
    total = jax.lax.psum(q.astype(jnp.int32), axis_name)
    n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
    return (total.astype(jnp.float32) * scale / n).astype(x.dtype)


def compress_tree_psum(tree: Any, axis_name: str) -> Any:
    return jax.tree.map(lambda x: compressed_psum(x, axis_name), tree)
